"""Continuous-batching serving: paged numerics, scheduler behavior,
queue admission, lease lifecycle, and the static engine's zero-cost /
early-exit guarantees."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.phi3_5_moe import SMOKE as MOE_SMOKE
from repro.configs.qwen1_5_0_5b import SMOKE
from repro.core.queues import Queue
from repro.core.session import get_session
from repro.models.model import build_model
from repro.serve import (ContinuousEngine, PageAllocator, ServeClient,
                         ServeEngine)

EOS = 1


@pytest.fixture(scope="module")
def model_params():
    m = build_model(SMOKE)
    return m, m.init(jax.random.PRNGKey(0))


def _static_row(m, params, toks, max_new, eos=None, max_len=64):
    eng = ServeEngine(m, params, max_len=max_len, eos_id=eos)
    return np.asarray(eng.generate(jnp.asarray([toks], jnp.int32),
                                   max_new_tokens=max_new))[0]


# ------------------------------------------------------------------ paging


class TestPageAllocator:
    def test_page_zero_reserved(self):
        a = PageAllocator(8, 4)
        got = a.alloc(7)
        assert got is not None and 0 not in got
        assert a.alloc(1) is None          # exhausted
        a.free(got)
        assert a.free_pages == 7

    def test_all_or_nothing(self):
        a = PageAllocator(4, 4)
        assert a.alloc(5) is None
        assert a.free_pages == 3           # untouched after failed alloc

    def test_double_free_rejected(self):
        a = PageAllocator(4, 4)
        p = a.alloc(1)
        a.free(p)
        with pytest.raises(ValueError):
            a.free(p)

    def test_pages_for(self):
        a = PageAllocator(8, 16)
        assert a.pages_for(1) == 1
        assert a.pages_for(16) == 1
        assert a.pages_for(17) == 2


# ------------------------------------------------------ paged model numerics


class TestPagedNumerics:
    def test_decode_paged_matches_contiguous(self, model_params):
        """Per-step decode math through the page table must equal the
        contiguous cache path (the PR's numerics gate)."""
        m, params = model_params
        B, page, M = 3, 8, 4
        S = page * M
        lens = [5, 1, 12]
        rng = np.random.default_rng(0)
        toks = rng.integers(2, SMOKE.vocab_size, (B, max(lens)))

        caches, ref_next = [], []
        for b in range(B):
            lg, cache = m.prefill(
                params, {"tokens": jnp.asarray(toks[b:b + 1, :lens[b]])}, S)
            caches.append(cache)
            ref_next.append(int(np.argmax(np.asarray(lg[0]))))

        pages = m.init_paged_cache(B * M + 1, page)
        table = np.arange(1, B * M + 1, dtype=np.int32).reshape(B, M)
        C = 4
        for b in range(B):
            start = 0
            while start < lens[b]:
                n = min(C, lens[b] - start)
                chunk = np.zeros((1, C), np.int32)
                chunk[0, :n] = toks[b, start:start + n]
                lg, pages = m.prefill_paged_chunk(
                    params, pages, jnp.asarray(chunk),
                    jnp.asarray(table[b]), jnp.int32(start), jnp.int32(n))
                start += n
            assert int(np.argmax(np.asarray(lg[0]))) == ref_next[b]

        nxt = jnp.asarray(ref_next, jnp.int32)
        lg_p, _ = m.decode_paged(params, pages, nxt, jnp.asarray(table),
                                 jnp.asarray(lens, jnp.int32),
                                 jnp.ones((B,), bool))
        for b in range(B):
            lg_c, _ = m.decode(params, caches[b], nxt[b:b + 1])
            np.testing.assert_allclose(np.asarray(lg_p[b]),
                                       np.asarray(lg_c[0]),
                                       atol=2e-4, rtol=2e-4)

    def test_masked_slots_do_not_perturb_live_ones(self, model_params):
        m, params = model_params
        B, page, M = 3, 8, 2
        pages = m.init_paged_cache(B * M + 1, page)
        table = np.arange(1, B * M + 1, dtype=np.int32).reshape(B, M)
        toks = jnp.asarray([4, 5, 6], jnp.int32)
        lens = jnp.asarray([3, 2, 1], jnp.int32)
        all_on, _ = m.decode_paged(params, pages, toks, jnp.asarray(table),
                                   lens, jnp.ones((B,), bool))
        # re-run from the SAME slab with slot 1 masked off
        one_off, _ = m.decode_paged(params, pages, toks, jnp.asarray(table),
                                    lens, jnp.asarray([True, False, True]))
        np.testing.assert_allclose(np.asarray(one_off[0]),
                                   np.asarray(all_on[0]), atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(one_off[2]),
                                   np.asarray(all_on[2]), atol=2e-4, rtol=2e-4)

    def test_paged_unsupported_family_raises(self):
        m = build_model(SMOKE.replace(family="ssm"))
        with pytest.raises(ValueError, match="KV-cache family"):
            m.init_paged_cache(4, 8)


# ------------------------------------------------------- continuous engine


class TestContinuousEngine:
    def test_outputs_match_static_engine(self, model_params):
        """Mixed prompt/output lengths batched continuously produce the
        exact tokens the static engine produces per request."""
        m, params = model_params
        eng = ContinuousEngine(m, params, max_slots=3, page_size=8,
                               max_len=64, prefill_chunk=4, eos_id=EOS)
        rng = np.random.default_rng(7)
        reqs = [(rng.integers(2, SMOKE.vocab_size,
                              int(rng.integers(1, 12))).tolist(),
                 int(rng.integers(1, 10))) for _ in range(6)]
        rids = [eng.submit(t, mn) for t, mn in reqs]
        eng.run_until_idle()
        for rid, (toks, mn) in zip(rids, reqs):
            got = eng.results[rid]["tokens"]
            row = _static_row(m, params, toks, mn, eos=EOS)
            assert list(row[:len(got)]) == got
            assert all(t == EOS for t in row[len(got):])

    def test_join_mid_flight_single_compile(self, model_params):
        """A request joining a live batch changes array contents only:
        no recompilation, and in-flight outputs are unperturbed."""
        m, params = model_params
        eng = ContinuousEngine(m, params, max_slots=4, page_size=8,
                               max_len=64, prefill_chunk=4, eos_id=None)
        r1 = eng.submit([5, 6, 7, 8], 12)
        for _ in range(5):
            eng.step()
        assert eng.active == 1            # r1 mid-decode
        r2 = eng.submit([9, 10, 11], 6)   # joins the live batch
        eng.run_until_idle()
        assert eng.decode_compiles == 1
        for rid, toks, mn in [(r1, [5, 6, 7, 8], 12), (r2, [9, 10, 11], 6)]:
            row = _static_row(m, params, toks, mn)
            assert eng.results[rid]["tokens"] == list(row)

    def test_eviction_returns_pages(self, model_params):
        m, params = model_params
        eng = ContinuousEngine(m, params, max_slots=2, page_size=8,
                               max_len=64, prefill_chunk=8, eos_id=None)
        total = eng.alloc.num_pages - 1
        eng.submit([3, 4, 5], 4)
        eng.step()
        assert eng.alloc.free_pages < total   # pages held while active
        eng.run_until_idle()
        assert eng.alloc.free_pages == total  # all freed at eviction
        assert all(t == 0 for t in np.asarray(eng._tables).ravel())

    def test_preemption_by_recompute(self, model_params):
        """Slab too small for both requests: the youngest is preempted,
        re-queued, and still produces exactly the static tokens."""
        m, params = model_params
        eng = ContinuousEngine(m, params, max_slots=2, page_size=4,
                               max_len=32, num_pages=5, prefill_chunk=4,
                               eos_id=None)
        r1 = eng.submit([5, 6, 7], 8)
        r2 = eng.submit([9, 10, 11], 8)
        eng.run_until_idle()
        assert eng.metrics["preempted"] >= 1
        for rid, toks in [(r1, [5, 6, 7]), (r2, [9, 10, 11])]:
            row = _static_row(m, params, toks, 8, max_len=32)
            assert eng.results[rid]["tokens"] == list(row)

    def test_oversize_request_rejected(self, model_params):
        m, params = model_params
        eng = ContinuousEngine(m, params, max_slots=2, page_size=8,
                               max_len=32, eos_id=None)
        rid = eng.submit(list(range(2, 30)), 16)  # 28 + 16 > 32
        eng.run_until_idle()
        assert "error" in eng.results[rid]
        assert eng.metrics["rejected"] == 1

    def test_result_latency_fields(self, model_params):
        m, params = model_params
        eng = ContinuousEngine(m, params, max_slots=2, page_size=8,
                               max_len=64, eos_id=None)
        rid = eng.submit([3, 4, 5], 4)
        eng.run_until_idle()
        res = eng.results[rid]
        assert res["ttft_s"] is not None
        assert 0 <= res["ttft_s"] <= res["completion_s"]

    def test_moe_family(self):
        """MoE decode over the slab: generous capacity so idle slots
        cannot steal expert capacity from live rows."""
        cfg = MOE_SMOKE.replace(capacity_factor=float(MOE_SMOKE.num_experts))
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        eng = ContinuousEngine(m, params, max_slots=2, page_size=8,
                               max_len=32, prefill_chunk=4, eos_id=None)
        rid = eng.submit([3, 4, 5, 6], 5)
        eng.run_until_idle()
        row = _static_row(m, params, [3, 4, 5, 6], 5, max_len=32)
        assert eng.results[rid]["tokens"] == list(row)


# ------------------------------------------------------------ queue plane


class TestQueueAdmission:
    def test_client_round_trip(self, model_params):
        m, params = model_params
        q = Queue(maxsize=4)
        client = ServeClient(q)
        eng = ContinuousEngine(m, params, max_slots=2, page_size=8,
                               max_len=64, prefill_chunk=4, eos_id=EOS,
                               request_queue=q)
        rid = client.submit([3, 4, 5, 6], 6)
        eng.run_until_idle()
        res = client.result(rid, timeout=2.0)
        row = _static_row(m, params, [3, 4, 5, 6], 6, eos=EOS)
        assert res["tokens"] == list(row[:len(res["tokens"])])

    def test_bounded_queue_backpressures_submit(self, model_params):
        m, params = model_params
        q = Queue(maxsize=1)
        client = ServeClient(q)
        client.submit([3, 4], 2)
        with pytest.raises(TimeoutError):
            client.submit([5, 6], 2, timeout=0.05)  # queue full, no engine

    def test_two_engines_share_one_queue_exactly_once(self, model_params):
        m, params = model_params
        q = Queue(maxsize=8)
        client = ServeClient(q)
        mk = lambda: ContinuousEngine(m, params, max_slots=2, page_size=8,
                                      max_len=64, prefill_chunk=4,
                                      eos_id=EOS, request_queue=q)
        ea, eb = mk(), mk()
        rids = [client.submit([7, 8, 9, i + 2], 4) for i in range(6)]
        while q.qsize() or ea.active or eb.active:
            ea.step()
            eb.step()
        results = [client.result(r, timeout=2.0) for r in rids]
        assert all(r["tokens"] for r in results)
        assert ea.metrics["completed"] + eb.metrics["completed"] == 6

    def test_lease_lifecycle(self, model_params):
        """Lease mode: the request is visible in the inflight hash while
        being served (reclaimable by lease_reap if we crash) and the
        lease is released — not expired — on completion."""
        m, params = model_params
        q = Queue(maxsize=4)
        client = ServeClient(q)
        eng = ContinuousEngine(m, params, max_slots=2, page_size=8,
                               max_len=64, prefill_chunk=2, eos_id=None,
                               request_queue=q, lease=True, lease_ttl_s=30.0)
        rid = client.submit([3, 4, 5, 6, 7, 8], 6)
        store = get_session().store
        inflight = q._key("inflight")
        eng.step()                          # admits + starts prefill
        held = store.hgetall(inflight)
        assert rid in held
        deadline, attempt, worker, _payload = held[rid]
        assert attempt == 0 and worker == eng.worker_id
        eng.run_until_idle()
        assert not store.hgetall(inflight)  # released, not leaked
        assert store.metrics.commands.get("LEASERELEASE", 0) >= 1
        assert client.result(rid, timeout=2.0)["tokens"]

    def test_lease_unaware_producer_still_served(self, model_params):
        """A plain Queue.put (serialized blob, no lease triple) is still
        admitted — it just doesn't get crash protection."""
        m, params = model_params
        q = Queue()
        q.put({"id": "plain", "tokens": [4, 5, 6],
               "max_new_tokens": 3, "submitted_at": None})
        eng = ContinuousEngine(m, params, max_slots=2, page_size=8,
                               max_len=64, eos_id=None, request_queue=q,
                               lease=True)
        eng.run_until_idle()
        client = ServeClient(q)
        res = client.result("plain", timeout=2.0)
        row = _static_row(m, params, [4, 5, 6], 3)
        assert res["tokens"] == list(row)


# --------------------------------------------------- static engine contract


class TestZeroCostWhenOff:
    def test_static_engine_issues_no_kv_commands(self, model_params):
        """The legacy static path must stay byte-identical when the
        continuous machinery is unused: zero store commands, no slab."""
        m, params = model_params
        store = get_session().store
        base = store.metrics.total_commands()
        eng = ServeEngine(m, params, max_len=32, eos_id=EOS)
        eng.generate(jnp.asarray([[3, 4, 5]], jnp.int32), max_new_tokens=4)
        assert store.metrics.total_commands() == base
        assert not hasattr(eng, "_pages") and not hasattr(eng, "alloc")

    def test_local_continuous_engine_issues_no_kv_commands(self, model_params):
        """Queue-less ContinuousEngine never touches the store either."""
        m, params = model_params
        store = get_session().store
        base = store.metrics.total_commands()
        eng = ContinuousEngine(m, params, max_slots=2, page_size=8,
                               max_len=32, eos_id=None)
        eng.submit([3, 4], 2)
        eng.run_until_idle()
        assert store.metrics.total_commands() == base


class TestServeEngineEarlyExit:
    def test_stops_stepping_after_all_eos(self, model_params):
        """Once every row has emitted eos the decode loop must break,
        not keep stepping to max_new_tokens (the PR 10 bug fix)."""
        m, params = model_params
        prompts = jnp.asarray([[3, 4, 5]], jnp.int32)
        probe = ServeEngine(m, params, max_len=64, eos_id=None)
        row = np.asarray(probe.generate(prompts, max_new_tokens=30))[0]
        assert probe._steps_run == 29      # no eos: full budget
        eos = int(row[2])                  # guaranteed to appear by step 2
        eng = ServeEngine(m, params, max_len=64, eos_id=eos)
        out = np.asarray(eng.generate(prompts, max_new_tokens=30))[0]
        assert eng._steps_run <= 2         # early exit fired
        assert out.shape == (30,)
        first = int(np.argmax(row == eos))
        assert list(out[:first + 1]) == list(row[:first + 1])
        assert all(t == eos for t in out[first:])

    def test_on_first_token_fires_before_decode(self, model_params):
        m, params = model_params
        seen = []
        eng = ServeEngine(m, params, max_len=64, eos_id=None)
        out = eng.generate(jnp.asarray([[3, 4, 5]], jnp.int32),
                           max_new_tokens=4,
                           on_first_token=lambda t: seen.append(np.asarray(t)))
        assert len(seen) == 1
        assert int(seen[0][0]) == int(np.asarray(out)[0, 0])
