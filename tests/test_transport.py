"""Unit tests for ``repro.core.transport``: endpoint scheme parsing and
preference ordering, and the shared-memory SPSC ring transport (framing
integrity, wraparound, blocking semantics, doorbell park/wake, fork
guard, teardown)."""

import os
import socket
import threading
import time

import pytest

from repro.core import transport as T


# ---------------------------------------------------------------------------
# Endpoint scheme
# ---------------------------------------------------------------------------


class TestEndpointParsing:
    def test_tcp_url(self):
        ep = T.parse_endpoint("tcp://127.0.0.1:6379")
        assert (ep.scheme, ep.host, ep.port) == ("tcp", "127.0.0.1", 6379)
        assert ep.url == "tcp://127.0.0.1:6379"

    def test_uds_and_shm_urls(self):
        for scheme in ("uds", "shm"):
            ep = T.parse_endpoint(f"{scheme}:///tmp/x/kv.sock")
            assert ep.scheme == scheme and ep.path == "/tmp/x/kv.sock"
            assert ep.url == f"{scheme}:///tmp/x/kv.sock"

    def test_legacy_tuple_is_tcp(self):
        ep = T.parse_endpoint(("localhost", 1234))
        assert ep.url == "tcp://localhost:1234"

    @pytest.mark.parametrize("bad", [
        "127.0.0.1:6379",        # no scheme
        "tcp://nohost",          # no port
        "uds://",                # no path
        "ftp://x:1",             # unknown scheme
        ("host",),               # not (host, port)
        42,
    ])
    def test_rejects_malformed(self, bad):
        with pytest.raises(ValueError):
            T.parse_endpoint(bad)

    def test_normalize_shapes(self):
        one = T.normalize_endpoints(("h", 1))
        assert [e.url for e in one] == ["tcp://h:1"]
        many = T.normalize_endpoints(
            ["tcp://h:1", "uds:///s", ("h2", 2)])
        assert [e.url for e in many] == ["tcp://h:1", "uds:///s",
                                         "tcp://h2:2"]
        with pytest.raises(ValueError):
            T.normalize_endpoints([])


class TestEndpointOrdering:
    EPS = [T.parse_endpoint(u) for u in
           ("tcp://h:1", "uds:///s", "shm:///s")]

    def test_auto_prefers_cheapest_carrier(self):
        got = [e.scheme for e in T.order_endpoints(self.EPS)]
        want = [s for s in ("shm", "uds", "tcp")
                if s == "tcp"
                or (s == "uds" and T.uds_supported())
                or (s == "shm" and T.ring_supported())]
        assert got == want

    def test_pin_selects_only_that_scheme(self):
        got = T.order_endpoints(self.EPS, transport="tcp")
        assert [e.scheme for e in got] == ["tcp"]

    def test_pin_unadvertised_scheme_raises(self):
        with pytest.raises(ValueError):
            T.order_endpoints([self.EPS[0]], transport="shm")

    def test_pin_unknown_scheme_raises(self):
        with pytest.raises(ValueError):
            T.order_endpoints(self.EPS, transport="rfc1149")


# ---------------------------------------------------------------------------
# Shared-memory rings
# ---------------------------------------------------------------------------


needs_rings = pytest.mark.skipif(not T.ring_supported(),
                                 reason="shm rings unsupported here")


@pytest.fixture
def ring_pair():
    """A connected (client RingConn, server RingConn) pair over a
    socketpair rendezvous, torn down afterwards."""
    a, b = socket.socketpair(socket.AF_UNIX, socket.SOCK_STREAM)
    out = {}

    def accept():
        out["server"] = T.accept_ring(b)

    t = threading.Thread(target=accept, daemon=True)
    t.start()
    client = T.create_ring(a, capacity=1 << 16)
    t.join(10)
    server = out["server"]
    yield client, server
    client.close()
    server.close()


@needs_rings
class TestRingConn:
    def test_roundtrip_small(self, ring_pair):
        client, server = ring_pair
        client.sendall(b"hello")
        buf = bytearray(16)
        n = server.recv_into(buf)
        assert bytes(buf[:n]) == b"hello"
        server.sendall(b"world")
        assert client.recv(5, socket.MSG_WAITALL) == b"world"

    def test_capacity_reported_as_buffer_size(self, ring_pair):
        client, _ = ring_pair
        assert client.getsockopt(
            socket.SOL_SOCKET, socket.SO_SNDBUF) == 1 << 16

    def test_wraparound_integrity(self, ring_pair):
        """Many odd-sized records crossing the ring boundary repeatedly
        arrive byte-identical and in order."""
        client, server = ring_pair
        records = [bytes([i & 0xFF]) * (977 + 64 * i) for i in range(96)]
        total = sum(len(r) for r in records)
        assert total > 3 * (1 << 16)   # guarantees several wraps

        def produce():
            for r in records:
                client.sendall(r)

        t = threading.Thread(target=produce, daemon=True)
        t.start()
        got = bytearray()
        buf = bytearray(8192)
        mv = memoryview(buf)
        while len(got) < total:
            n = server.recv_into(mv)
            assert n > 0
            got += buf[:n]
        t.join(10)
        assert bytes(got) == b"".join(records)

    def test_send_larger_than_capacity_streams(self, ring_pair):
        client, server = ring_pair
        blob = os.urandom(5 * (1 << 16))   # 5x the ring capacity
        got = bytearray(len(blob))

        def consume():
            server.recv_into(memoryview(got), len(blob), socket.MSG_WAITALL)

        t = threading.Thread(target=consume, daemon=True)
        t.start()
        client.sendall(blob)
        t.join(10)
        assert bytes(got) == blob

    def test_sendmsg_gather_single_publish(self, ring_pair):
        client, server = ring_pair
        parts = [b"\x00\x00\x00\x0a", b"0123456789"]
        assert client.sendmsg(parts) == 14
        buf = bytearray(14)
        server.recv_into(buf, 14, socket.MSG_WAITALL)
        assert bytes(buf) == b"".join(parts)

    def test_msg_waitall_blocks_for_exact_count(self, ring_pair):
        client, server = ring_pair
        out = {}

        def consume():
            buf = bytearray(8)
            server.recv_into(buf, 8, socket.MSG_WAITALL)
            out["got"] = bytes(buf)

        t = threading.Thread(target=consume, daemon=True)
        t.start()
        client.sendall(b"1234")
        time.sleep(0.05)
        assert "got" not in out          # only half arrived: still blocked
        client.sendall(b"5678")
        t.join(10)
        assert out["got"] == b"12345678"

    def test_close_gives_peer_eof(self, ring_pair):
        client, server = ring_pair
        client.sendall(b"bye")
        client.close()
        buf = bytearray(8)
        assert server.recv_into(buf) == 3      # drains buffered bytes...
        assert server.recv_into(buf) == 0      # ...then clean EOF

    def test_doorbell_park_and_wake(self, ring_pair, monkeypatch):
        """With no spin/yield budget the consumer parks on the doorbell
        socket; a produce must set it running again."""
        client, server = ring_pair
        monkeypatch.setattr(T, "_YIELD_WAITS", 0)
        server._spin = 1
        server._spin_fixed = True   # keep adaptation out of the way
        out = {}

        def consume():
            buf = bytearray(4)
            server.recv_into(buf, 4, socket.MSG_WAITALL)
            out["got"] = bytes(buf)

        t = threading.Thread(target=consume, daemon=True)
        t.start()
        deadline = time.time() + 5
        while not T._load(server._mv, server._csleep_off):
            assert time.time() < deadline, "consumer never parked"
            time.sleep(0.01)
        client.sendall(b"ding")       # sleeping flag set -> doorbell byte
        t.join(10)
        assert out["got"] == b"ding"

    def test_fork_guard(self, ring_pair):
        """A ring used from a different process than the one that opened
        it must refuse to run (SPSC indices would corrupt) — same
        contract as the client mux's pid guard."""
        client, _ = ring_pair
        client.pid -= 1   # simulate: ring opened by the parent pre-fork
        with pytest.raises(ConnectionError, match="fork"):
            client.sendall(b"x")
        with pytest.raises(ConnectionError, match="fork"):
            client.recv(1)

    def test_threaded_stress_bidirectional(self, ring_pair):
        """Concurrent request/response traffic with varying sizes stays
        framed and ordered in both directions."""
        client, server = ring_pair
        N = 300

        def echo():
            buf = bytearray(1 << 15)
            mv = memoryview(buf)
            for _ in range(N):
                server.recv_into(mv, 4, socket.MSG_WAITALL)
                n = int.from_bytes(buf[:4], "big")
                server.recv_into(mv, n, socket.MSG_WAITALL)
                server.sendmsg([bytes(buf[:4]), bytes(buf[:n])])

        t = threading.Thread(target=echo, daemon=True)
        t.start()
        buf = bytearray(1 << 15)
        for i in range(N):
            payload = bytes([i & 0xFF]) * (1 + (i * 37) % 9000)
            client.sendmsg([len(payload).to_bytes(4, "big"), payload])
            client.recv_into(memoryview(buf), 4 + len(payload),
                             socket.MSG_WAITALL)
            assert bytes(buf[4:4 + len(payload)]) == payload
        t.join(10)

    def test_close_unlinks_segment(self):
        a, b = socket.socketpair(socket.AF_UNIX, socket.SOCK_STREAM)
        out = {}
        t = threading.Thread(
            target=lambda: out.update(s=T.accept_ring(b)), daemon=True)
        t.start()
        client = T.create_ring(a, capacity=1 << 16)
        t.join(10)
        name = client._shm.name
        out["s"].close()
        client.close()
        from multiprocessing import shared_memory
        with pytest.raises(FileNotFoundError):
            shared_memory.SharedMemory(name=name)


# ---------------------------------------------------------------------------
# Socket tuning regression (satellite: _tune vs AF_UNIX)
# ---------------------------------------------------------------------------


class TestTuneGuards:
    def test_tune_skips_nodelay_on_af_unix(self):
        from repro.core.kvserver import _tune
        a, b = socket.socketpair(socket.AF_UNIX, socket.SOCK_STREAM)
        try:
            _tune(a)   # must not raise (TCP_NODELAY is an AF_INET option)
        finally:
            a.close()
            b.close()

    def test_tune_still_sets_nodelay_on_tcp(self):
        from repro.core.kvserver import _tune
        ls = socket.socket()
        ls.bind(("127.0.0.1", 0))
        ls.listen(1)
        c = socket.create_connection(ls.getsockname())
        s, _ = ls.accept()
        try:
            _tune(s)
            assert s.getsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY)
        finally:
            c.close()
            s.close()
            ls.close()

    def test_tune_accepts_ring(self):
        """Rings advertise family == -1; _tune must treat them as
        non-INET — SOL_SOCKET sizing is a harmless no-op on a ring, but
        TCP options must never be attempted."""
        from repro.core.kvserver import _tune

        class FakeRing:
            family = -1

            def setsockopt(self, level, *a):
                assert level == socket.SOL_SOCKET, \
                    f"non-INET conn got level {level} option"

        _tune(FakeRing())


# ---------------------------------------------------------------------------
# PR 7: fault injection + replication interop across dialects/transports
# ---------------------------------------------------------------------------


class _DelayInjector(T.FaultInjector):
    def __init__(self, delay_s):
        self.delay_s = delay_s
        self.calls = 0

    def send_delay(self, endpoint, nbytes):
        self.calls += 1
        return self.delay_s


class _SeverInjector(T.FaultInjector):
    def __init__(self, after):
        self.after = after
        self.sends = 0

    def should_sever(self, endpoint):
        self.sends += 1
        return self.sends > self.after


class TestFaultInjection:
    def teardown_method(self):
        T.set_fault_injector(None)

    @pytest.mark.parametrize("transport", ["tcp", "uds", "shm"])
    def test_send_delay_applies_per_transport(self, transport):
        from repro.core import KVClient, KVServer
        with KVServer() as srv:
            inj = _DelayInjector(0.05)
            T.set_fault_injector(inj)
            try:
                c = KVClient(srv.endpoints, transport=transport)
                t0 = time.monotonic()
                c.set("d", 1)
                assert c.get("d") == 1
                elapsed = time.monotonic() - t0
                c.close()
            finally:
                T.set_fault_injector(None)
            assert inj.calls > 0
            assert elapsed >= 0.05  # at least one delayed send

    @pytest.mark.parametrize("transport", ["tcp", "uds", "shm"])
    def test_sever_mid_stream_raises_connection_error(self, transport):
        from repro.core import KVClient, KVServer
        with KVServer() as srv:
            inj = _SeverInjector(after=2)
            T.set_fault_injector(inj)
            try:
                c = KVClient(srv.endpoints, transport=transport)
                with pytest.raises((ConnectionError, OSError)):
                    for i in range(50):
                        c.set(f"s{i}", i)
            finally:
                T.set_fault_injector(None)
                c.close()

    def test_injector_swap_returns_previous(self):
        a, b = _DelayInjector(0), _DelayInjector(0)
        assert T.set_fault_injector(a) is None
        assert T.set_fault_injector(b) is a
        assert T.set_fault_injector(None) is b
        assert T.get_fault_injector() is None


class TestReplicationInterop:
    """The replication stream rides the SAME wire as clients: every
    dialect (v1 pickle .. v4 raw) and every carrier must deliver the
    admin commands and the log chunks."""

    @pytest.mark.parametrize("transport", ["tcp", "uds", "shm"])
    @pytest.mark.parametrize("legacy,mux,raw", [
        (True, False, False),   # v1: legacy pickle, one socket
        (False, False, False),  # v2: multi-part OOB, per-thread sockets
        (False, True, False),   # v3: tagged mux
        (False, True, True),    # v4: raw struct-packed fast path
    ], ids=["v1", "v2", "v3", "v4"])
    def test_repl_admin_commands_all_dialects(self, transport, legacy,
                                              mux, raw):
        from repro.core import KVClient, KVServer
        from repro.core.kvstore import KVStore
        with KVServer(KVStore(name="pri")) as pri, \
                KVServer(KVStore(name="rep"), replica=True) as rep:
            c = KVClient(pri.endpoints, legacy_protocol=legacy, mux=mux,
                         raw=raw, transport=transport)
            rc = KVClient(rep.endpoints, legacy_protocol=legacy, mux=mux,
                          raw=raw, transport=transport)
            try:
                info = rc.repl_info()
                assert info["role"] == "replica" and info["seq"] == 0
                assert c.repl_attach(list(rep.endpoints)) is True
                c.set("ri:k", 11)
                c.rpush("ri:q", b"x")
                deadline = time.monotonic() + 5
                while rc.repl_info()["seq"] < 2:
                    assert time.monotonic() < deadline, "stream stalled"
                    time.sleep(0.01)
                assert rc.get("ri:k") == 11
                assert rc.lrange("ri:q", 0, -1) == [b"x"]
                assert c.repl_detach(list(rep.endpoints)) is True
            finally:
                c.close()
                rc.close()
