"""Fault-tolerant training loop: crash/resume determinism + serverless DP."""

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import DataPipeline, SyntheticLM, shard_registry
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.runtime.trainer import DataParallelTrainer, ServerlessTrainer
from repro.train import init_train_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("qwen1.5-0.5b")
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3)
    ds = SyntheticLM(cfg.vocab_size, 32, 4)
    return cfg, model, opt, ds


class TestServerlessTrainer:
    def test_crash_resume_is_bit_identical(self, setup):
        cfg, model, opt, ds = setup
        step_fn = make_train_step(model, opt)
        mk = lambda: init_train_state(model, opt, jax.random.PRNGKey(0))  # noqa

        t1 = ServerlessTrainer(step_fn, mk, lambda s: ds.batch(s),
                               ckpt_prefix="ta", checkpoint_every=5)
        t1.run(10, log_every=5)
        # "crash": new trainer object resumes from storage
        t2 = ServerlessTrainer(step_fn, mk, lambda s: ds.batch(s),
                               ckpt_prefix="ta", checkpoint_every=5)
        assert t2.step == 10
        m_resumed = t2.run(5, log_every=5)

        t3 = ServerlessTrainer(step_fn, mk, lambda s: ds.batch(s),
                               ckpt_prefix="tb", checkpoint_every=100)
        m_straight = t3.run(15, log_every=5)
        assert m_resumed["loss"] == pytest.approx(m_straight["loss"],
                                                  abs=1e-5)

    def test_metrics_logged_to_kv(self, setup):
        cfg, model, opt, ds = setup
        from repro.core import get_session
        step_fn = make_train_step(model, opt)
        t = ServerlessTrainer(
            step_fn,
            lambda: init_train_state(model, opt, jax.random.PRNGKey(0)),
            lambda s: ds.batch(s), ckpt_prefix="tm", checkpoint_every=100)
        t.run(4, log_every=2)
        logged = get_session().store.llen("{tm}:metrics")
        assert logged >= 2


class TestDataParallel:
    def test_dp_trains(self, setup):
        cfg, model, opt, ds = setup

        def grad_fn(params, batch):
            return jax.grad(lambda p, b: model.loss(p, b)[0])(params, batch)

        def apply_fn(state, grads):
            p2, o2, m = adamw_update(opt, grads, state["opt"],
                                     state["params"])
            return {"params": p2, "opt": o2}, m

        def mk():
            p = model.init(jax.random.PRNGKey(0))
            return {"params": p, "opt": adamw_init(opt, p)}

        dp = DataParallelTrainer(grad_fn, apply_fn, mk,
                                 lambda s, w: ds.batch(s * 100 + w),
                                 n_workers=2)
        try:
            hist = dp.train_steps(3)
            assert len(hist) == 3
            assert all(np.isfinite(h["grad_norm"]) for h in hist)
            assert dp.bytes_moved > 0
        finally:
            dp.shutdown()


class TestDataPipeline:
    def test_prefetch_order_and_determinism(self):
        ds = SyntheticLM(100, 16, 2, seed=3)
        pipe = DataPipeline(ds, prefetch=2)
        got = {}
        it = iter(pipe)
        for _ in range(4):
            step, batch = next(it)
            got[step] = batch["tokens"]
        pipe.stop()
        for step, toks in got.items():
            np.testing.assert_array_equal(toks, ds.batch(step)["tokens"])

    def test_labels_are_shifted_tokens(self):
        ds = SyntheticLM(50, 8, 2)
        b = ds.batch(0)
        assert b["tokens"].shape == b["labels"].shape == (2, 8)

    def test_shard_registry_exactly_once(self):
        claim = shard_registry("ep1", n_shards=5)
        got = [claim() for _ in range(8)]
        assert sorted(x for x in got if x is not None) == [0, 1, 2, 3, 4]
        assert got[5:] == [None, None, None]
