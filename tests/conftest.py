import pytest

from repro.core import reset_session


@pytest.fixture(autouse=True)
def fresh_session():
    """Isolate each test: fresh in-process KV store + object store."""
    yield reset_session()
