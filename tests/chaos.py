"""Chaos-injection harness for the replicated KV plane and the task plane.

Storage plane (PR 7): drives a seeded, reproducible fault schedule
against a live ``KVCluster(replicas=1, ack="quorum", watchdog=True)``
while writer threads hammer it, then audits the damage:

- **SIGKILL primaries** mid-workload: the watchdog must promote the
  freshest replica and clients must resume through the promotion; the
  harness measures each failover's latency as the wall-clock stall of an
  idempotent write issued the instant the primary dies.
- **SIGKILL a replica**: the primary's streamer must detach and the
  (now-degraded) shard must keep acking writes.
- **Delay / sever transports**: a seeded :class:`ChaosInjector`
  installed in the client process randomly slows sends and kills
  connections mid-stream; idempotent commands must retry transparently,
  non-idempotent ones must surface typed ``ShardUnavailableError``.
- **Duplicate deliveries**: ``REPRO_REPL_DUP_EVERY`` makes every shard's
  replication streamer re-send already-acked log chunks; replicas must
  deduplicate by sequence number (the audit would see doubled list
  entries otherwise).

The invariant asserted is the acceptance criterion: **zero lost
acknowledged writes**. A ``set`` that returned is checked key-by-key
after the storm; a ``rpush`` that returned must appear in its list (a
``rpush`` that raised may legitimately appear too — the reply was lost
after the write applied, at-least-once — counted as ``dup_pushes``,
never as lost).

Not collected by pytest (no ``test_`` prefix): this is a harness, run
via ``benchmarks/bench_chaos.py`` or directly::

    PYTHONPATH=src python tests/chaos.py --seed 7 --quick
"""

from __future__ import annotations

import argparse
import os
import random
import sys
import threading
import time
from typing import Any, Dict, List

from repro.core import transport as _transport
from repro.core.errors import ShardUnavailableError
from repro.core.kvcluster import KVCluster

__all__ = ["ChaosInjector", "run_chaos", "run_pool_chaos"]


class ChaosInjector(_transport.FaultInjector):
    """Seeded random faults on the calling process's transports.

    All probabilities are per-send; the RNG is private to the injector
    so a given seed replays the same fault schedule (modulo thread
    interleaving, which only shifts WHICH command eats each fault)."""

    def __init__(self, seed: int, delay_p: float = 0.02,
                 max_delay_s: float = 0.002, sever_p: float = 0.004,
                 dup_p: float = 0.05):
        self.rng = random.Random(seed)
        self.delay_p = delay_p
        self.max_delay_s = max_delay_s
        self.sever_p = sever_p
        self.dup_p = dup_p
        self.delays = 0
        self.severs = 0
        self._lock = threading.Lock()

    def send_delay(self, endpoint, nbytes) -> float:
        with self._lock:
            if self.rng.random() < self.delay_p:
                self.delays += 1
                return self.rng.uniform(0.0, self.max_delay_s)
        return 0.0

    def should_sever(self, endpoint) -> bool:
        with self._lock:
            if self.rng.random() < self.sever_p:
                self.severs += 1
                return True
        return False

    def should_duplicate(self, endpoint=None) -> bool:
        with self._lock:
            return self.rng.random() < self.dup_p


def _key_on_shard(client, shard: int, prefix: str) -> str:
    return next(f"{prefix}{i}" for i in range(10000)
                if client._hash(f"{prefix}{i}") % len(client.shards) == shard)


def _writer(cluster, wid: int, n_ops: int, out: Dict[str, Any]) -> None:
    c = cluster.client(failover_timeout_s=30.0)
    acked_sets: Dict[str, int] = {}
    acked_pushes: Dict[str, int] = {}
    typed_errors = 0
    try:
        for i in range(n_ops):
            k = f"c:{wid}:{i}"
            try:
                c.set(k, i)
                acked_sets[k] = i
            except ShardUnavailableError:
                typed_errors += 1
            if i % 4 == 0:
                lk = f"log:{wid}:{i % 8}"
                try:
                    c.rpush(lk, i)
                    acked_pushes[lk] = acked_pushes.get(lk, 0) + 1
                except ShardUnavailableError:
                    typed_errors += 1
    finally:
        c.close()
    out["sets"] = acked_sets
    out["pushes"] = acked_pushes
    out["typed_errors"] = typed_errors


def run_chaos(seed: int = 7, quick: bool = False) -> Dict[str, Any]:
    """One seeded chaos run. Returns the audit as a dict (see keys
    below); raises AssertionError on any lost acknowledged write."""
    n_shards = 2 if quick else 3
    n_writers = 2 if quick else 4
    n_ops = 150 if quick else 500

    # delivery-level duplication inside the shard children (inherited
    # via environ): every 5th replication chunk is sent twice
    os.environ["REPRO_REPL_DUP_EVERY"] = "5"
    cluster = KVCluster(shards=n_shards, replicas=1, ack="quorum",
                        watchdog=True, heartbeat_s=0.2)
    cluster.start()
    injector = ChaosInjector(seed)
    prev = _transport.set_fault_injector(injector)
    failovers_ms: List[float] = []
    try:
        writer_out: List[Dict[str, Any]] = [{} for _ in range(n_writers)]
        threads = [threading.Thread(target=_writer,
                                    args=(cluster, w, n_ops, writer_out[w]),
                                    name=f"chaos-writer-{w}")
                   for w in range(n_writers)]
        for t in threads:
            t.start()

        # the fault schedule: with replicas=1 each shard absorbs exactly
        # one primary kill, so kill primaries of shards 0..n-2 and a
        # REPLICA of the last shard (streamer detach, degraded quorum)
        probe = cluster.client(failover_timeout_s=30.0)
        rng = random.Random(seed ^ 0x5EED)
        time.sleep(0.3)
        for s in range(n_shards - 1):
            time.sleep(rng.uniform(0.1, 0.4))
            pk = _key_on_shard(probe, s, f"probe:{s}:")
            cluster.kill_shard(s)
            t0 = time.monotonic()
            probe.set(pk, t0)  # idempotent: blocks across the promotion
            failovers_ms.append((time.monotonic() - t0) * 1e3)
        time.sleep(rng.uniform(0.1, 0.4))
        cluster.kill_replica(n_shards - 1, 0)
        probe.close()

        for t in threads:
            t.join(120)
            assert not t.is_alive(), "writer wedged"
    finally:
        _transport.set_fault_injector(prev)
        os.environ.pop("REPRO_REPL_DUP_EVERY", None)

    # -- audit: every acked write must be readable -------------------------
    try:
        audit = cluster.client(failover_timeout_s=30.0)
        lost: List[str] = []
        acked_sets = 0
        for out in writer_out:
            for k, v in out["sets"].items():
                acked_sets += 1
                if audit.get(k) != v:
                    lost.append(k)
        acked_pushes = 0
        dup_pushes = 0
        lost_pushes = 0
        merged: Dict[str, int] = {}
        for out in writer_out:
            for lk, n in out["pushes"].items():
                merged[lk] = merged.get(lk, 0) + n
                acked_pushes += n
        for lk, n in merged.items():
            have = audit.llen(lk)
            if have < n:
                lost_pushes += n - have
            else:
                dup_pushes += have - n  # reply lost after apply, or a
                # retried-at-least-once delivery: never a LOST ack
        audit.close()
    finally:
        cluster.stop()

    result = {
        "seed": seed,
        "quick": quick,
        "shards": n_shards,
        "writers": n_writers,
        "acked_sets": acked_sets,
        "acked_pushes": acked_pushes,
        "lost_acked_writes": len(lost) + lost_pushes,
        "lost_keys": lost[:10],
        "dup_pushes": dup_pushes,
        "typed_errors": sum(o["typed_errors"] for o in writer_out),
        "client_severs": injector.severs,
        "client_delays": injector.delays,
        "kills_primary": n_shards - 1,
        "kills_replica": 1,
        "failover_ms": [round(f, 2) for f in failovers_ms],
    }
    assert result["lost_acked_writes"] == 0, (
        f"lost acknowledged writes under chaos: {result}")
    return result


# ---------------------------------------------------------------------------
# Compute-plane chaos (PR 8): SIGKILL real pool workers mid-map / mid-imap
# ---------------------------------------------------------------------------


def run_pool_chaos(seed: int = 7, quick: bool = False) -> Dict[str, Any]:
    """One seeded task-plane chaos run against a fault-tolerant
    :class:`~repro.core.pool.Pool` over the ``subprocess`` backend
    (workers are real OS processes reached only via TCP).

    Fault schedule (seeded, reproducible):

    - worker 1 is scripted (``REPRO_POOL_CHAOS=die:1``) to SIGKILL
      *itself* immediately after acquiring its first lease — before its
      first heartbeat renewal, the nastiest window;
    - worker 2 is scripted (``zombie:2``) to stop renewing one lease,
      sleep past ``2 x lease_ttl_s`` (so the reaper re-enqueues the
      task and another worker settles it), then push its now-stale
      result — which fencing must discard;
    - at seeded times mid-``map`` and mid-``imap_unordered`` the
      harness SIGKILLs further live workers picked from
      :meth:`Pool.worker_pids`, and measures detection + respawn
      latency from the pool's fault counters.

    Audit (the acceptance criterion): every task settles **exactly
    once** — ``map`` returns the exact expected list, ``imap`` yields
    each result exactly once, nothing is dead-lettered, and the only
    duplicates anywhere are in the *discarded* counter. A per-execution
    side-effect ledger (an ``rpush`` per task attempt) proves the
    at-least-once part was actually exercised (re-executions > 0).
    """
    from repro.core import pool as pool_mod
    from repro.core import session as S
    from repro.core.kvserver import KVClient, KVServer
    from repro.core.pool import Pool
    from repro.core.storage import KVObjectStore

    n_workers = 4
    n_map = 48 if quick else 96
    n_imap = 24 if quick else 48
    task_sleep = 0.05
    lease_ttl = 1.0

    rng = random.Random(seed ^ 0xBEEF)
    server = KVServer().start()
    client = KVClient(server.address)
    sess = S.Session(store=client, storage=KVObjectStore(client),
                     kv_address=server.address)
    sess.executor_defaults["backend"] = "subprocess"

    exec_key = "{chaospool}:execs"

    def task(x, _k=exec_key, _s=task_sleep):
        import os as _os
        import time as _t
        from repro.core import session as _S
        _S.get_session().store.rpush(_k, (x, _os.getpid()))
        _t.sleep(_s)
        return 3 * x + 1

    killed_pids: List[int] = []
    kill_lat_ms: List[Dict[str, float]] = []
    killer_stop = threading.Event()

    def _kill_one(pool) -> None:
        """SIGKILL one live worker not yet killed; record latencies."""
        deadline = time.monotonic() + 5.0
        victim = None
        while time.monotonic() < deadline and not killer_stop.is_set():
            # wid 2 is the scripted zombie: leave it alive so its stale
            # late settle actually happens and exercises the fencing
            pids = {w: p for w, p in pool.worker_pids().items()
                    if p not in killed_pids and w != 2}
            if pids:
                victim = rng.choice(sorted(pids.items()))
                break
            time.sleep(0.05)
        if victim is None:
            return
        wid, pid = victim
        base = pool.fault_stats()
        try:
            os.kill(pid, 9)
        except ProcessLookupError:
            return
        killed_pids.append(pid)
        t0 = time.monotonic()
        lat = {"detect_ms": -1.0, "respawn_ms": -1.0}
        while time.monotonic() - t0 < 15.0 and not killer_stop.is_set():
            st = pool.fault_stats()
            if (lat["detect_ms"] < 0
                    and st["workers_lost"] > base["workers_lost"]):
                lat["detect_ms"] = (time.monotonic() - t0) * 1e3
            if st["workers_respawned"] > base["workers_respawned"]:
                lat["respawn_ms"] = (time.monotonic() - t0) * 1e3
                break
            time.sleep(0.02)
        kill_lat_ms.append({k: round(v, 1) for k, v in lat.items()})

    def _killer(pool, n_kills: int, first_delay: float) -> None:
        time.sleep(first_delay)
        for _ in range(n_kills):
            if killer_stop.is_set():
                return
            _kill_one(pool)
            time.sleep(rng.uniform(0.1, 0.3))

    # scripted chaos is read by the worker from its inherited environ,
    # so it must be exported BEFORE the Pool spawns its workers
    os.environ["REPRO_POOL_CHAOS"] = "die:1;zombie:2"
    grace_prev = pool_mod._HB_SPAWN_GRACE_S
    pool_mod._HB_SPAWN_GRACE_S = 2.0  # workers boot in <2 s here; detect fast
    pool = None
    try:
        pool = Pool(processes=n_workers, session=sess,
                    max_retries=3, lease_ttl_s=lease_ttl, heartbeat_s=0.25)

        # -- phase 1: map, with 1 external SIGKILL (+ the scripted two) ----
        killer = threading.Thread(
            target=_killer, args=(pool, 1, rng.uniform(0.3, 0.6)),
            name="pool-chaos-killer")
        killer.start()
        t_map = time.monotonic()
        got = pool.map(task, range(n_map), chunksize=1)
        map_s = time.monotonic() - t_map
        killer.join(30)
        assert got == [3 * x + 1 for x in range(n_map)], (
            "map lost or corrupted results under worker kills")

        # -- phase 2: imap_unordered, 1 more external SIGKILL mid-stream ---
        killer2 = threading.Thread(
            target=_killer, args=(pool, 1, rng.uniform(0.1, 0.3)),
            name="pool-chaos-killer-2")
        killer2.start()
        t_imap = time.monotonic()
        seen = sorted(pool.imap_unordered(task, range(n_imap), chunksize=1))
        imap_s = time.monotonic() - t_imap
        killer2.join(30)
        assert seen == sorted(3 * x + 1 for x in range(n_imap)), (
            "imap lost or duplicated results under worker kills")

        stats = pool.fault_stats()
        pool.close()
        pool.join(timeout=30)
    finally:
        killer_stop.set()
        pool_mod._HB_SPAWN_GRACE_S = grace_prev
        os.environ.pop("REPRO_POOL_CHAOS", None)
        if pool is not None:
            try:
                pool.terminate()
                pool.join(timeout=10)
            except Exception:
                pass

    n_total = n_map + n_imap
    executions = client.llen(exec_key)
    client.delete(exec_key)
    client.close()
    server.stop()

    result = {
        "seed": seed,
        "quick": quick,
        "plane": "pool",
        "workers": n_workers,
        "tasks": n_total,
        "map_s": round(map_s, 3),
        "imap_s": round(imap_s, 3),
        "kills_external": len(killed_pids),
        "kills_scripted": 2,  # die:1 (pre-first-heartbeat) + zombie:2
        "executions": executions,
        "re_executions": max(0, executions - n_total),
        "workers_lost": stats["workers_lost"],
        "workers_respawned": stats["workers_respawned"],
        "leases_requeued": stats["leases_requeued"],
        "duplicate_results_discarded": stats["duplicate_results_discarded"],
        "tasks_dead_lettered": stats["tasks_dead_lettered"],
        "all_dead_failures": stats["all_dead_failures"],
        "lost_tasks": 0,  # both asserts above passed to get here
        "kill_latency_ms": kill_lat_ms,
    }
    assert result["kills_external"] >= 1, "no external kill landed"
    assert result["workers_lost"] >= 2, (
        f"expected >=2 worker deaths (scripted die + external), got {result}")
    assert result["re_executions"] >= 1, (
        "no task was ever re-executed: the kills missed every lease window")
    assert result["duplicate_results_discarded"] >= 1, (
        "the zombie's stale settle was never fenced — fencing untested")
    assert result["tasks_dead_lettered"] == 0, (
        f"tasks exceeded max_retries under chaos: {result}")
    assert result["all_dead_failures"] == 0, result
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--pool", action="store_true",
                    help="run the task-plane (Pool worker-kill) chaos "
                         "instead of the storage-plane chaos")
    args = ap.parse_args(argv)
    fn = run_pool_chaos if args.pool else run_chaos
    res = fn(seed=args.seed, quick=args.quick)
    for k, v in sorted(res.items()):
        print(f"{k}: {v}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
