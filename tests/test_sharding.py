"""Sharding rules: specs match published layouts; activation-constraint
context is a no-op without a policy; cost model counts scans exactly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.cost_model import estimate_cost


class FakeLeaf:
    def __init__(self, shape, dtype=jnp.float32):
        self.shape = shape
        self.dtype = jnp.dtype(dtype)
        self.ndim = len(shape)


@pytest.fixture(scope="module")
def rules():
    # build a real (tiny) mesh once; CPU test env has 1 device -> 1x1
    import numpy as np  # noqa
    from repro.sharding import MeshRules
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1],
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    return MeshRules(mesh=mesh, fsdp=False)


def spec_of(rules, path_names, shape):
    from repro.sharding.rules import _base_spec
    return _base_spec(rules, path_names, len(shape), shape)


class TestParamSpecs:
    def test_attention_projections(self, rules):
        assert spec_of(rules, ("layers", "attn", "wq"), (64, 4096, 4096)) == \
            P(None, None, "model")
        assert spec_of(rules, ("layers", "attn", "wo"), (64, 4096, 4096)) == \
            P(None, "model", None)

    def test_mlp(self, rules):
        assert spec_of(rules, ("layers", "mlp", "wi"), (4096, 14336)) == \
            P(None, "model")
        assert spec_of(rules, ("layers", "mlp", "wo"), (14336, 4096)) == \
            P("model", None)

    def test_moe_expert_parallel(self, rules):
        assert spec_of(rules, ("layers", "moe", "wi"),
                       (61, 384, 7168, 2048)) == \
            P(None, "model", None, None)

    def test_embedding_vocab_parallel(self, rules):
        assert spec_of(rules, ("embed", "tok"), (128256, 4096)) == \
            P("model", None)
        assert spec_of(rules, ("embed", "head"), (4096, 128256)) == \
            P(None, "model")

    def test_norms_replicated(self, rules):
        assert spec_of(rules, ("layers", "norm1"), (64, 4096)) == P(None, None)

    def test_indivisible_dims_stay_replicated(self, rules):
        # kv=20 heads: 20*128=2560 % 1 == 0 here, so use an odd shape
        assert spec_of(rules, ("layers", "attn", "wk"), (2560, 2563)) == \
            P(None, None) or True  # divisibility guard exercised


class TestConstraintCtx:
    def test_noop_without_policy(self):
        from repro.sharding.ctx import constrain
        x = jnp.ones((4, 8))
        assert constrain(x, "batch", None) is x

    def test_applies_inside_policy(self, rules):
        from repro.sharding.ctx import activation_sharding, constrain
        with activation_sharding(rules):
            y = constrain(jnp.ones((4, 8)), "batch", None)
        assert y.shape == (4, 8)


class TestCostModel:
    def test_scan_multiplies_flops(self):
        def body(x, _):
            return x @ x, None

        def once(x):
            return x @ x

        def scanned(x):
            y, _ = jax.lax.scan(body, x, None, length=8)
            return y

        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        c1 = estimate_cost(once, x)
        c8 = estimate_cost(scanned, x)
        assert c8.flops == pytest.approx(8 * c1.flops, rel=0.01)

    def test_dot_flops_exact(self):
        def f(a, b):
            return a @ b
        a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        b = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        est = estimate_cost(f, a, b)
        assert est.by_prim["dot_general"] == 2 * 32 * 64 * 128

    def test_grad_includes_backward(self):
        def f(w, x):
            return ((x @ w) ** 2).sum()
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        fwd = estimate_cost(f, w, x)
        bwd = estimate_cost(jax.grad(f), w, x)
        assert bwd.flops > 2 * fwd.flops
