"""Unified client configuration (PR 9): ``ClientOptions`` consumed
uniformly by ``KVClient``, ``ClusterClient`` and ``connect()``, legacy
kwarg spellings kept as aliases, and conflicting spellings rejected with
a clear error — the back-compat grid for the config API redesign."""

import pytest

from repro.core import ClientOptions, KVClient, KVServer
from repro.core.clientopts import UNSET, resolve_client_options
from repro.core.kvcluster import connect


@pytest.fixture
def server():
    with KVServer() as srv:
        yield srv


class TestResolution:
    def test_defaults(self):
        o = resolve_client_options(None)
        assert o == ClientOptions()
        assert o.raw is True and o.mux is True
        assert o.legacy_protocol is False
        assert o.transport is None
        assert o.failover_timeout_s == 10.0

    def test_alias_only(self):
        o = resolve_client_options(None, raw=False, transport="uds")
        assert o.raw is False and o.transport == "uds"
        assert o.mux is True  # untouched knobs keep defaults

    def test_options_only(self):
        base = ClientOptions(mux=False, failover_timeout_s=3.0)
        o = resolve_client_options(base)
        assert o.mux is False and o.failover_timeout_s == 3.0

    def test_agreeing_spellings_are_fine(self):
        base = ClientOptions(raw=False)
        o = resolve_client_options(base, raw=False)
        assert o.raw is False

    def test_conflicting_spellings_raise(self):
        base = ClientOptions(raw=True)
        with pytest.raises(ValueError, match="raw"):
            resolve_client_options(base, raw=False)

    def test_unknown_alias_raises(self):
        with pytest.raises(TypeError):
            resolve_client_options(None, bogus_knob=1)

    def test_replace_returns_new_frozen_copy(self):
        o = ClientOptions()
        o2 = o.replace(transport="shm")
        assert o2.transport == "shm" and o.transport is None
        with pytest.raises(Exception):  # frozen dataclass
            o2.transport = "tcp"

    def test_unset_sentinel_is_not_a_value(self):
        # passing UNSET is identical to not passing the kwarg at all
        o = resolve_client_options(None, raw=UNSET, mux=UNSET)
        assert o == ClientOptions()


class TestKVClientGrid:
    """Every spelling of the same configuration must behave identically
    on the wire."""

    def test_legacy_kwargs_still_work(self, server):
        c = KVClient(server.address, mux=False, raw=False)
        try:
            c.set("k", b"v")
            assert c.get("k") == b"v"
            assert c.mux_enabled is False and c.raw_enabled is False
        finally:
            c.close()

    def test_options_object(self, server):
        c = KVClient(server.address,
                     options=ClientOptions(mux=False, raw=False))
        try:
            c.set("k2", b"v2")
            assert c.get("k2") == b"v2"
            assert c.mux_enabled is False and c.raw_enabled is False
            assert c.options.mux is False
        finally:
            c.close()

    def test_conflict_raises_before_connecting_state_changes(self, server):
        with pytest.raises(ValueError, match="mux"):
            KVClient(server.address, mux=True,
                     options=ClientOptions(mux=False))

    def test_legacy_protocol_spellings_agree(self, server):
        a = KVClient(server.address, legacy_protocol=True)
        b = KVClient(server.address,
                     options=ClientOptions(legacy_protocol=True))
        try:
            a.set("x", b"1")
            assert b.get("x") == b"1"
            # legacy protocol disables both mux and raw paths
            for c in (a, b):
                assert c.mux_enabled is False and c.raw_enabled is False
        finally:
            a.close()
            b.close()

    def test_default_spelling_matrix_roundtrips(self, server):
        for kwargs in ({}, {"options": ClientOptions()},
                       {"mux": True}, {"raw": True},
                       {"options": ClientOptions(), "mux": True}):
            c = KVClient(server.address, **kwargs)
            try:
                c.set("m", b"v")
                assert c.get("m") == b"v"
                assert c.mux_enabled and c.raw_enabled
            finally:
                c.close()


class TestConnectGrid:
    def test_connect_plain_server_with_options(self, server):
        c = connect(server.address, options=ClientOptions(mux=False))
        try:
            c.set("ck", b"cv")
            assert c.get("ck") == b"cv"
            assert c.mux_enabled is False
        finally:
            c.close()

    def test_connect_alias_and_options_conflict(self, server):
        with pytest.raises(ValueError, match="raw"):
            connect(server.address, raw=False,
                    options=ClientOptions(raw=True))

    def test_connect_legacy_kwargs(self, server):
        c = connect(server.address, legacy_protocol=True)
        try:
            c.rpush("cl", b"a")
            assert c.lpop("cl") == b"a"
        finally:
            c.close()
