"""Remote-mode tests: the IPC primitives against a genuine TCP KV server,
plus the full-fidelity subprocess executor backend."""

import threading
import time

import pytest

from repro.core import (KVClient, KVServer, Session, mp, set_session)
from repro.core.executor import FunctionExecutor
from repro.core.storage import KVObjectStore


@pytest.fixture
def server():
    with KVServer() as srv:
        yield srv


class TestKVServer:
    def test_basic_commands(self, server):
        c = KVClient(server.address)
        c.set("k", b"v")
        assert c.get("k") == b"v"
        c.rpush("l", b"1", b"2")
        assert c.lrange("l", 0, -1) == [b"1", b"2"]
        assert c.incr("n") == 1
        c.hset("h", "f", b"x")
        assert c.hgetall("h") == {"f": b"x"}
        c.close()

    def test_blocking_across_connections(self, server):
        c1, c2 = KVClient(server.address), KVClient(server.address)
        out = []
        t = threading.Thread(target=lambda: out.append(c2.blpop("q", 5)))
        t.start()
        time.sleep(0.05)
        c1.rpush("q", b"msg")
        t.join(3)
        assert out == [("q", b"msg")]
        c1.close()
        c2.close()

    def test_exception_propagates(self, server):
        c = KVClient(server.address)
        c.set("k", b"v")
        with pytest.raises(TypeError):
            c.rpush("k", b"x")   # WRONGTYPE crosses the wire
        c.close()

    def test_large_payload_oob_roundtrip(self, server):
        c = KVClient(server.address)
        blob = b"z" * (1 << 20)
        c.rpush("big", blob)
        out = c.lpop("big")
        assert type(out) is bytes and out == blob
        c.close()

    def test_numpy_payload_roundtrip(self, server):
        np = pytest.importorskip("numpy")
        c = KVClient(server.address)
        arr = np.arange(65_536, dtype=np.float32)
        c.set("arr", arr)
        np.testing.assert_array_equal(c.get("arr"), arr)
        c.close()

    def test_legacy_protocol_interop(self, server):
        """v1 (seed) clients and v2 clients work against the same server."""
        legacy = KVClient(server.address, legacy_protocol=True)
        new = KVClient(server.address)
        legacy.set("k", b"v")
        assert new.get("k") == b"v"
        new.rpush("l", b"big" * 50_000)
        assert legacy.lrange("l", 0, -1) == [b"big" * 50_000]
        with pytest.raises(TypeError):
            legacy.rpush("k", b"x")
        assert legacy.incr("n") == 1  # connection still in sync
        legacy.close()
        new.close()

    def test_mp_primitives_over_tcp(self, server):
        set_session(Session(store=KVClient(server.address)))
        q = mp.Queue()
        lock = mp.Lock()
        v = mp.Value("i", 0)

        def child(q, lock, v):
            with lock:
                v.value += 5
            q.put("done")
        pr = mp.Process(target=child, args=(q, lock, v))
        pr.start()
        assert q.get(timeout=5) == "done"
        pr.join(5)
        assert v.value == 5


class TestPipeline:
    """Pipelined wire protocol: batching, error semantics, framing safety."""

    def test_transactional_pipeline(self, server):
        c = KVClient(server.address)
        with c.pipeline() as p:
            a = p.rpush("l", b"1", b"2")
            b = p.llen("l")
            n = p.incr("n")
        assert a.get() == 2 and b.get() == 2 and n.get() == 1
        c.close()

    def test_nontransactional_pipeline(self, server):
        c = KVClient(server.address)
        with c.pipeline(transactional=False) as p:
            a = p.rpush("l", b"1")
            b = p.llen("l")
        assert a.get() == 1 and b.get() == 1
        c.close()

    def test_transactional_batch_single_lock_single_frame(self, server):
        c = KVClient(server.address)
        before_eval = server.store.metrics.commands.get("EVAL", 0)
        with c.pipeline() as p:
            for _ in range(10):
                p.incr("n")
        # the whole batch ran as ONE server-side transaction
        assert server.store.metrics.commands.get("EVAL", 0) - before_eval == 1
        c.close()

    @pytest.mark.parametrize("transactional", [True, False])
    def test_error_mid_batch_does_not_desync(self, server, transactional):
        from repro.core.kvstore import PipelineError, WrongTypeError
        c = KVClient(server.address)
        c.set("str", b"v")
        p = c.pipeline(transactional=transactional)
        first = p.incr("n")
        bad = p.rpush("str", b"x")   # WRONGTYPE mid-batch
        last = p.incr("n")
        with pytest.raises(PipelineError) as ei:
            p.execute()
        assert ei.value.index == 1
        # remaining responses were drained: later commands executed...
        assert first.get() == 1 and last.get() == 2
        with pytest.raises(WrongTypeError):
            bad.get()
        # ...and the connection framing is intact for follow-up traffic
        assert c.incr("n") == 3
        assert c.get("str") == b"v"
        c.close()

    def test_pipeline_large_payloads(self, server):
        c = KVClient(server.address)
        blob = b"p" * 300_000
        with c.pipeline() as p:
            for _ in range(4):
                p.rpush("blobs", blob)
        got = c.lrange("blobs", 0, -1)
        assert [bytes(b) for b in got] == [blob] * 4
        c.close()

    def test_empty_pipeline(self, server):
        c = KVClient(server.address)
        assert c.pipeline().execute() == []
        c.close()

    def test_nontransactional_bidirectional_bulk_no_deadlock(self, server):
        """Big writes AND big reads in one multi-frame batch: the chunked
        flush drains responses between chunks, so request+response volume
        beyond the socket buffers cannot wedge the connection."""
        c = KVClient(server.address)
        blob = b"D" * (2 << 20)
        done = []

        def run():
            p = c.pipeline(transactional=False)
            reads = []
            for _ in range(6):
                p.rpush("bulk", blob)
                reads.append(p.lrange("bulk", 0, -1))
            p.execute()
            done.append([len(r.get()) for r in reads])
        t = threading.Thread(target=run, daemon=True)
        t.start()
        t.join(30)
        assert done == [[1, 2, 3, 4, 5, 6]], "pipeline deadlocked or wrong"
        c.close()

    def test_manager_shutdown_survives_dead_server(self):
        """`with Manager()` teardown must not raise once the store is gone
        (TTL backstop owns cleanup) — same contract as per-resource close."""
        from repro.core.managers import Manager
        srv = KVServer().start()
        client = KVClient(srv.address)
        set_session(Session(store=client))
        m = Manager(store=client)
        d = m.dict({"a": 1})
        lst = m.list([1, 2])
        assert d["a"] == 1 and len(lst) == 2
        srv.stop()
        client.close()  # force reconnect attempts, which will be refused
        m.shutdown()  # must swallow the connection failure
        client.close()

    def test_bounded_queue_put_get_two_commands(self, server):
        """Acceptance: a bounded put+get costs 2 KV commands, down from 4."""
        set_session(Session(store=KVClient(server.address)))
        q = mp.Queue(maxsize=4)
        baseline = server.store.metrics.total_commands()
        q.put("payload")
        after_put = server.store.metrics.total_commands()
        assert q.get(timeout=5) == "payload"
        after_get = server.store.metrics.total_commands()
        assert after_put - baseline == 1
        assert after_get - after_put == 1
        assert server.store.metrics.commands.get("BLPOPRPUSH", 0) >= 2


@pytest.mark.slow
class TestSubprocessBackend:
    def test_real_process_roundtrip(self, server):
        client = KVClient(server.address)
        set_session(Session(store=client,
                            storage=KVObjectStore(client),
                            kv_address=server.address))
        ex = FunctionExecutor(backend="subprocess")
        assert ex.call_async(lambda a, b: a * b, (6, 7)).result(90) == 42
        ex.shutdown(wait=False)

    def test_real_process_uses_ipc(self, server):
        client = KVClient(server.address)
        set_session(Session(store=client,
                            storage=KVObjectStore(client),
                            kv_address=server.address))
        q = mp.Queue()
        sess_defaults = {"backend": "subprocess"}
        from repro.core import get_session
        get_session().executor_defaults.update(sess_defaults)
        pr = mp.Process(target=lambda q: q.put(("pid-proof", 123)), args=(q,))
        pr.start()
        assert q.get(timeout=90) == ("pid-proof", 123)
        pr.join(30)


class TestByteRangeOverTCP:
    def test_byte_range_commands_roundtrip(self, server):
        c = KVClient(server.address)
        assert c.setrange("s", 0, b"Hello World") == 11
        assert c.getrange("s", 6, -1) == b"World"
        assert c.msetrange([("s", 6, b"Redis"), ("t", 1, b"x")]) == 2
        assert c.get("s") == b"Hello Redis"
        assert c.get("t") == b"\x00x"
        assert c.strlen("s") == 11
        c.close()

    def test_segment_sized_ranges_cross_oob_path(self, server):
        # 4 KiB values ride the out-of-band buffer path both directions
        c = KVClient(server.address)
        blob = bytes(range(256)) * 16
        assert c.setrange("seg", 0, blob) == 4096
        assert c.getrange("seg", 0, -1) == blob
        assert c.getrange("seg", 4000, 4095) == blob[4000:4096]
        c.close()

    def test_block_array_with_cache_over_tcp(self, server):
        set_session(Session(store=KVClient(server.address)))
        try:
            arr = mp.Array("d", [0.0] * 700)  # spans 2 segments
            commands_before = server.store.metrics.total_commands()
            with arr.get_lock():
                for i in range(700):
                    arr[i] = float(i)
                total = sum(arr[i] for i in range(700))
            in_scope = server.store.metrics.total_commands() - commands_before
            assert total == sum(range(700))
            assert arr[100:105] == [100.0, 101.0, 102.0, 103.0, 104.0]
            assert arr[::-70] == [float(i) for i in range(699, -1, -70)]
            # 1400 element accesses cost a handful of commands (lock
            # choreography + segment fetches + one flush), not 1400.
            assert in_scope <= 15, in_scope
        finally:
            from repro.core import reset_session
            reset_session()


class TestConcurrentClients:
    """PR 3 satellite: many clients interleaving on one server never
    desync framing, and the client socket registry stays bounded."""

    def test_concurrent_nontransactional_pipelines_interleave(self, server):
        n_clients, n_rounds, batch = 4, 10, 20
        errors = []

        def run(ci):
            c = KVClient(server.address)
            try:
                for r in range(n_rounds):
                    p = c.pipeline(transactional=False)
                    futs = []
                    for j in range(batch):
                        p.incr("shared-count")
                        futs.append(p.rpush(f"own-{ci}", f"{r}:{j}".encode()))
                        p.llen(f"own-{ci}")
                    p.execute()
                    # framing intact: our private list grew exactly as queued
                    assert futs[-1].get() == (r + 1) * batch
            except Exception as exc:  # pragma: no cover - failure detail
                errors.append((ci, exc))
            finally:
                c.close()

        threads = [threading.Thread(target=run, args=(i,))
                   for i in range(n_clients)]
        [t.start() for t in threads]
        [t.join(60) for t in threads]
        assert errors == []
        assert server.store.get("shared-count") == n_clients * n_rounds * batch
        for i in range(n_clients):
            assert server.store.llen(f"own-{i}") == n_rounds * batch

    def test_concurrent_transactional_pipelines_atomic(self, server):
        n_clients, n_rounds = 4, 15

        def run(ci):
            c = KVClient(server.address)
            try:
                for _ in range(n_rounds):
                    with c.pipeline() as p:
                        p.incr("a")
                        p.incr("b")
            finally:
                c.close()

        threads = [threading.Thread(target=run, args=(i,))
                   for i in range(n_clients)]
        [t.start() for t in threads]
        [t.join(60) for t in threads]
        assert server.store.get("a") == n_clients * n_rounds
        assert server.store.get("b") == n_clients * n_rounds

    def test_dead_thread_sockets_pruned(self, server):
        c = KVClient(server.address)
        for wave in range(5):
            threads = [threading.Thread(target=lambda: c.incr("n"))
                       for _ in range(4)]
            [t.start() for t in threads]
            [t.join(10) for t in threads]
        c.incr("n")  # triggers a prune pass from a live thread
        # registry holds live threads only, not one socket per dead thread
        assert len(c._socks) <= 2, len(c._socks)
        c.close()
        assert c._socks == {}

    def test_close_idempotent_under_concurrent_callers(self, server):
        c = KVClient(server.address)
        c.incr("n")
        threads = [threading.Thread(target=c.close) for _ in range(8)]
        [t.start() for t in threads]
        [t.join(10) for t in threads]
        assert c._socks == {}
        # the client remains usable: close() invalidates, _sock reconnects
        assert c.incr("n") == 2
        c.close()


class TestBufferPool:
    def test_acquire_release_reuses(self):
        from repro.core.kvserver import _BufferPool
        pool = _BufferPool()
        b = pool.acquire(1000)
        pool.release(b)
        assert pool.acquire(900) is b  # recycled, capacity >= request
        assert pool.acquire(900) is not b  # pool drained -> fresh

    def test_gross_overallocation_refused(self):
        from repro.core.kvserver import _BufferPool
        pool = _BufferPool()
        big = pool.acquire(100_000)
        pool.release(big)
        small = pool.acquire(8)
        assert small is not big  # a 100 KB buffer must not serve 8 bytes

    def test_oversize_buffers_not_hoarded(self):
        from repro.core.kvserver import _BufferPool
        pool = _BufferPool()
        huge = pool.acquire(_BufferPool._MAX_BUF_BYTES + 1)
        pool.release(huge)
        assert pool._free == []

    def test_pooled_small_frames_roundtrip_correct_values(self, server):
        """Recycled receive buffers never corrupt decoded values: distinct
        payloads over one connection (same pooled buffers) stay distinct."""
        c = KVClient(server.address)
        blobs = [bytes([i]) * 512 for i in range(16)]
        for i, blob in enumerate(blobs):
            c.set(f"pk{i}", blob)
        got = [c.get(f"pk{i}") for i in range(16)]
        assert [bytes(g) for g in got] == blobs
        c.close()


class TestTransactionKeyHintOverTCP:
    def test_joinable_queue_task_done_over_plain_client(self, server):
        """A generic-dispatch KVClient looks like it has `.shards`, so the
        IPC layer passes transaction(..., key_hint=...); the remote
        KVStore must accept and ignore the hint, not TypeError."""
        set_session(Session(store=KVClient(server.address)))
        q = mp.JoinableQueue()
        q.put("item")
        assert q.get(timeout=5) == "item"
        q.task_done()
        q.join(5)

    def test_bounded_semaphore_release_over_plain_client(self, server):
        set_session(Session(store=KVClient(server.address)))
        sem = mp.BoundedSemaphore(1)
        sem.acquire()
        sem.release()
        with pytest.raises(ValueError):
            sem.release()
