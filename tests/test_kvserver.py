"""Remote-mode tests: the IPC primitives against a genuine TCP KV server,
plus the full-fidelity subprocess executor backend."""

import threading
import time

import pytest

from repro.core import (KVClient, KVServer, Session, mp, set_session)
from repro.core.executor import FunctionExecutor
from repro.core.storage import KVObjectStore


@pytest.fixture
def server():
    with KVServer() as srv:
        yield srv


class TestKVServer:
    def test_basic_commands(self, server):
        c = KVClient(server.address)
        c.set("k", b"v")
        assert c.get("k") == b"v"
        c.rpush("l", b"1", b"2")
        assert c.lrange("l", 0, -1) == [b"1", b"2"]
        assert c.incr("n") == 1
        c.hset("h", "f", b"x")
        assert c.hgetall("h") == {"f": b"x"}
        c.close()

    def test_blocking_across_connections(self, server):
        c1, c2 = KVClient(server.address), KVClient(server.address)
        out = []
        t = threading.Thread(target=lambda: out.append(c2.blpop("q", 5)))
        t.start()
        time.sleep(0.05)
        c1.rpush("q", b"msg")
        t.join(3)
        assert out == [("q", b"msg")]
        c1.close()
        c2.close()

    def test_exception_propagates(self, server):
        c = KVClient(server.address)
        c.set("k", b"v")
        with pytest.raises(TypeError):
            c.rpush("k", b"x")   # WRONGTYPE crosses the wire
        c.close()

    def test_mp_primitives_over_tcp(self, server):
        set_session(Session(store=KVClient(server.address)))
        q = mp.Queue()
        lock = mp.Lock()
        v = mp.Value("i", 0)

        def child(q, lock, v):
            with lock:
                v.value += 5
            q.put("done")
        pr = mp.Process(target=child, args=(q, lock, v))
        pr.start()
        assert q.get(timeout=5) == "done"
        pr.join(5)
        assert v.value == 5


@pytest.mark.slow
class TestSubprocessBackend:
    def test_real_process_roundtrip(self, server):
        client = KVClient(server.address)
        set_session(Session(store=client,
                            storage=KVObjectStore(client),
                            kv_address=server.address))
        ex = FunctionExecutor(backend="subprocess")
        assert ex.call_async(lambda a, b: a * b, (6, 7)).result(90) == 42
        ex.shutdown(wait=False)

    def test_real_process_uses_ipc(self, server):
        client = KVClient(server.address)
        set_session(Session(store=client,
                            storage=KVObjectStore(client),
                            kv_address=server.address))
        q = mp.Queue()
        sess_defaults = {"backend": "subprocess"}
        from repro.core import get_session
        get_session().executor_defaults.update(sess_defaults)
        pr = mp.Process(target=lambda q: q.put(("pid-proof", 123)), args=(q,))
        pr.start()
        assert q.get(timeout=90) == ("pid-proof", 123)
        pr.join(30)
