"""Remote-mode tests: the IPC primitives against a genuine TCP KV server,
plus the full-fidelity subprocess executor backend."""

import threading
import time

import pytest

from repro.core import (KVClient, KVServer, Session, mp, set_session)
from repro.core.executor import FunctionExecutor
from repro.core.storage import KVObjectStore


@pytest.fixture
def server():
    with KVServer() as srv:
        yield srv


class TestKVServer:
    def test_basic_commands(self, server):
        c = KVClient(server.address)
        c.set("k", b"v")
        assert c.get("k") == b"v"
        c.rpush("l", b"1", b"2")
        assert c.lrange("l", 0, -1) == [b"1", b"2"]
        assert c.incr("n") == 1
        c.hset("h", "f", b"x")
        assert c.hgetall("h") == {"f": b"x"}
        c.close()

    def test_blocking_across_connections(self, server):
        c1, c2 = KVClient(server.address), KVClient(server.address)
        out = []
        t = threading.Thread(target=lambda: out.append(c2.blpop("q", 5)))
        t.start()
        time.sleep(0.05)
        c1.rpush("q", b"msg")
        t.join(3)
        assert out == [("q", b"msg")]
        c1.close()
        c2.close()

    def test_exception_propagates(self, server):
        c = KVClient(server.address)
        c.set("k", b"v")
        with pytest.raises(TypeError):
            c.rpush("k", b"x")   # WRONGTYPE crosses the wire
        c.close()

    def test_large_payload_oob_roundtrip(self, server):
        c = KVClient(server.address)
        blob = b"z" * (1 << 20)
        c.rpush("big", blob)
        out = c.lpop("big")
        assert type(out) is bytes and out == blob
        c.close()

    def test_numpy_payload_roundtrip(self, server):
        np = pytest.importorskip("numpy")
        c = KVClient(server.address)
        arr = np.arange(65_536, dtype=np.float32)
        c.set("arr", arr)
        np.testing.assert_array_equal(c.get("arr"), arr)
        c.close()

    def test_legacy_protocol_interop(self, server):
        """v1 (seed) clients and v2 clients work against the same server."""
        legacy = KVClient(server.address, legacy_protocol=True)
        new = KVClient(server.address)
        legacy.set("k", b"v")
        assert new.get("k") == b"v"
        new.rpush("l", b"big" * 50_000)
        assert legacy.lrange("l", 0, -1) == [b"big" * 50_000]
        with pytest.raises(TypeError):
            legacy.rpush("k", b"x")
        assert legacy.incr("n") == 1  # connection still in sync
        legacy.close()
        new.close()

    def test_mp_primitives_over_tcp(self, server):
        set_session(Session(store=KVClient(server.address)))
        q = mp.Queue()
        lock = mp.Lock()
        v = mp.Value("i", 0)

        def child(q, lock, v):
            with lock:
                v.value += 5
            q.put("done")
        pr = mp.Process(target=child, args=(q, lock, v))
        pr.start()
        assert q.get(timeout=5) == "done"
        pr.join(5)
        assert v.value == 5


class TestPipeline:
    """Pipelined wire protocol: batching, error semantics, framing safety."""

    def test_transactional_pipeline(self, server):
        c = KVClient(server.address)
        with c.pipeline() as p:
            a = p.rpush("l", b"1", b"2")
            b = p.llen("l")
            n = p.incr("n")
        assert a.get() == 2 and b.get() == 2 and n.get() == 1
        c.close()

    def test_nontransactional_pipeline(self, server):
        c = KVClient(server.address)
        with c.pipeline(transactional=False) as p:
            a = p.rpush("l", b"1")
            b = p.llen("l")
        assert a.get() == 1 and b.get() == 1
        c.close()

    def test_transactional_batch_single_lock_single_frame(self, server):
        c = KVClient(server.address)
        before_eval = server.store.metrics.commands.get("EVAL", 0)
        with c.pipeline() as p:
            for _ in range(10):
                p.incr("n")
        # the whole batch ran as ONE server-side transaction
        assert server.store.metrics.commands.get("EVAL", 0) - before_eval == 1
        c.close()

    @pytest.mark.parametrize("transactional", [True, False])
    def test_error_mid_batch_does_not_desync(self, server, transactional):
        from repro.core.kvstore import PipelineError, WrongTypeError
        c = KVClient(server.address)
        c.set("str", b"v")
        p = c.pipeline(transactional=transactional)
        first = p.incr("n")
        bad = p.rpush("str", b"x")   # WRONGTYPE mid-batch
        last = p.incr("n")
        with pytest.raises(PipelineError) as ei:
            p.execute()
        assert ei.value.index == 1
        # remaining responses were drained: later commands executed...
        assert first.get() == 1 and last.get() == 2
        with pytest.raises(WrongTypeError):
            bad.get()
        # ...and the connection framing is intact for follow-up traffic
        assert c.incr("n") == 3
        assert c.get("str") == b"v"
        c.close()

    def test_pipeline_large_payloads(self, server):
        c = KVClient(server.address)
        blob = b"p" * 300_000
        with c.pipeline() as p:
            for _ in range(4):
                p.rpush("blobs", blob)
        got = c.lrange("blobs", 0, -1)
        assert [bytes(b) for b in got] == [blob] * 4
        c.close()

    def test_empty_pipeline(self, server):
        c = KVClient(server.address)
        assert c.pipeline().execute() == []
        c.close()

    def test_nontransactional_bidirectional_bulk_no_deadlock(self, server):
        """Big writes AND big reads in one multi-frame batch: the chunked
        flush drains responses between chunks, so request+response volume
        beyond the socket buffers cannot wedge the connection."""
        c = KVClient(server.address)
        blob = b"D" * (2 << 20)
        done = []

        def run():
            p = c.pipeline(transactional=False)
            reads = []
            for _ in range(6):
                p.rpush("bulk", blob)
                reads.append(p.lrange("bulk", 0, -1))
            p.execute()
            done.append([len(r.get()) for r in reads])
        t = threading.Thread(target=run, daemon=True)
        t.start()
        t.join(30)
        assert done == [[1, 2, 3, 4, 5, 6]], "pipeline deadlocked or wrong"
        c.close()

    def test_manager_shutdown_survives_dead_server(self):
        """`with Manager()` teardown must not raise once the store is gone
        (TTL backstop owns cleanup) — same contract as per-resource close."""
        from repro.core.managers import Manager
        srv = KVServer().start()
        client = KVClient(srv.address)
        set_session(Session(store=client))
        m = Manager(store=client)
        d = m.dict({"a": 1})
        lst = m.list([1, 2])
        assert d["a"] == 1 and len(lst) == 2
        srv.stop()
        client.close()  # force reconnect attempts, which will be refused
        m.shutdown()  # must swallow the connection failure
        client.close()

    def test_bounded_queue_put_get_two_commands(self, server):
        """Acceptance: a bounded put+get costs 2 KV commands, down from 4."""
        set_session(Session(store=KVClient(server.address)))
        q = mp.Queue(maxsize=4)
        baseline = server.store.metrics.total_commands()
        q.put("payload")
        after_put = server.store.metrics.total_commands()
        assert q.get(timeout=5) == "payload"
        after_get = server.store.metrics.total_commands()
        assert after_put - baseline == 1
        assert after_get - after_put == 1
        assert server.store.metrics.commands.get("BLPOPRPUSH", 0) >= 2


@pytest.mark.slow
class TestSubprocessBackend:
    def test_real_process_roundtrip(self, server):
        client = KVClient(server.address)
        set_session(Session(store=client,
                            storage=KVObjectStore(client),
                            kv_address=server.address))
        ex = FunctionExecutor(backend="subprocess")
        assert ex.call_async(lambda a, b: a * b, (6, 7)).result(90) == 42
        ex.shutdown(wait=False)

    def test_warm_handler_reuse(self, server):
        """PR 9 invoker/handler split: the second sequential task re-
        attaches the parked handler process instead of forking a new one
        — one cold start, N-1 warm attaches, same PID end to end."""
        import os
        client = KVClient(server.address)
        set_session(Session(store=client,
                            storage=KVObjectStore(client),
                            kv_address=server.address))
        ex = FunctionExecutor(backend="subprocess")
        try:
            pids = {ex.call_async(os.getpid).result(90) for _ in range(3)}
            assert len(pids) == 1, f"expected one reused handler: {pids}"
            stats = ex.stats_summary()
            assert stats["cold_starts"] == 1
            assert stats["warm_attaches"] == 2
            # the handler re-parks a beat after the future settles
            deadline = time.monotonic() + 5
            while (ex.stats_summary()["parked_handlers"] != 1
                   and time.monotonic() < deadline):
                time.sleep(0.02)
            assert ex.stats_summary()["parked_handlers"] == 1
        finally:
            ex.shutdown(wait=False)

    def test_real_process_uses_ipc(self, server):
        client = KVClient(server.address)
        set_session(Session(store=client,
                            storage=KVObjectStore(client),
                            kv_address=server.address))
        q = mp.Queue()
        sess_defaults = {"backend": "subprocess"}
        from repro.core import get_session
        get_session().executor_defaults.update(sess_defaults)
        pr = mp.Process(target=lambda q: q.put(("pid-proof", 123)), args=(q,))
        pr.start()
        assert q.get(timeout=90) == ("pid-proof", 123)
        pr.join(30)


class TestByteRangeOverTCP:
    def test_byte_range_commands_roundtrip(self, server):
        c = KVClient(server.address)
        assert c.setrange("s", 0, b"Hello World") == 11
        assert c.getrange("s", 6, -1) == b"World"
        assert c.msetrange([("s", 6, b"Redis"), ("t", 1, b"x")]) == 2
        assert c.get("s") == b"Hello Redis"
        assert c.get("t") == b"\x00x"
        assert c.strlen("s") == 11
        c.close()

    def test_segment_sized_ranges_cross_oob_path(self, server):
        # 4 KiB values ride the out-of-band buffer path both directions
        c = KVClient(server.address)
        blob = bytes(range(256)) * 16
        assert c.setrange("seg", 0, blob) == 4096
        assert c.getrange("seg", 0, -1) == blob
        assert c.getrange("seg", 4000, 4095) == blob[4000:4096]
        c.close()

    def test_block_array_with_cache_over_tcp(self, server):
        set_session(Session(store=KVClient(server.address)))
        try:
            arr = mp.Array("d", [0.0] * 700)  # spans 2 segments
            commands_before = server.store.metrics.total_commands()
            with arr.get_lock():
                for i in range(700):
                    arr[i] = float(i)
                total = sum(arr[i] for i in range(700))
            in_scope = server.store.metrics.total_commands() - commands_before
            assert total == sum(range(700))
            assert arr[100:105] == [100.0, 101.0, 102.0, 103.0, 104.0]
            assert arr[::-70] == [float(i) for i in range(699, -1, -70)]
            # 1400 element accesses cost a handful of commands (lock
            # choreography + segment fetches + one flush), not 1400.
            assert in_scope <= 15, in_scope
        finally:
            from repro.core import reset_session
            reset_session()


class TestConcurrentClients:
    """PR 3 satellite: many clients interleaving on one server never
    desync framing, and the client socket registry stays bounded."""

    def test_concurrent_nontransactional_pipelines_interleave(self, server):
        n_clients, n_rounds, batch = 4, 10, 20
        errors = []

        def run(ci):
            c = KVClient(server.address)
            try:
                for r in range(n_rounds):
                    p = c.pipeline(transactional=False)
                    futs = []
                    for j in range(batch):
                        p.incr("shared-count")
                        futs.append(p.rpush(f"own-{ci}", f"{r}:{j}".encode()))
                        p.llen(f"own-{ci}")
                    p.execute()
                    # framing intact: our private list grew exactly as queued
                    assert futs[-1].get() == (r + 1) * batch
            except Exception as exc:  # pragma: no cover - failure detail
                errors.append((ci, exc))
            finally:
                c.close()

        threads = [threading.Thread(target=run, args=(i,))
                   for i in range(n_clients)]
        [t.start() for t in threads]
        [t.join(60) for t in threads]
        assert errors == []
        assert server.store.get("shared-count") == n_clients * n_rounds * batch
        for i in range(n_clients):
            assert server.store.llen(f"own-{i}") == n_rounds * batch

    def test_concurrent_transactional_pipelines_atomic(self, server):
        n_clients, n_rounds = 4, 15

        def run(ci):
            c = KVClient(server.address)
            try:
                for _ in range(n_rounds):
                    with c.pipeline() as p:
                        p.incr("a")
                        p.incr("b")
            finally:
                c.close()

        threads = [threading.Thread(target=run, args=(i,))
                   for i in range(n_clients)]
        [t.start() for t in threads]
        [t.join(60) for t in threads]
        assert server.store.get("a") == n_clients * n_rounds
        assert server.store.get("b") == n_clients * n_rounds

    def test_dead_thread_sockets_pruned(self, server):
        # mux=False: the per-thread socket registry is the PR 3 transport,
        # kept for A/B benchmarking — this test covers its pruning.
        c = KVClient(server.address, mux=False)
        for wave in range(5):
            threads = [threading.Thread(target=lambda: c.incr("n"))
                       for _ in range(4)]
            [t.start() for t in threads]
            [t.join(10) for t in threads]
        c.incr("n")  # triggers a prune pass from a live thread
        # registry holds live threads only, not one socket per dead thread
        assert len(c._socks) <= 2, len(c._socks)
        c.close()
        assert c._socks == {}

    def test_close_idempotent_under_concurrent_callers(self, server):
        c = KVClient(server.address, mux=False)
        c.incr("n")
        threads = [threading.Thread(target=c.close) for _ in range(8)]
        [t.start() for t in threads]
        [t.join(10) for t in threads]
        assert c._socks == {}
        # the client remains usable: close() invalidates, _sock reconnects
        assert c.incr("n") == 2
        c.close()


class TestBufferPool:
    def test_acquire_release_reuses(self):
        from repro.core.kvserver import _BufferPool
        pool = _BufferPool()
        b = pool.acquire(1000)
        pool.release(b)
        assert pool.acquire(900) is b  # recycled, capacity >= request
        assert pool.acquire(900) is not b  # pool drained -> fresh

    def test_gross_overallocation_refused(self):
        from repro.core.kvserver import _BufferPool
        pool = _BufferPool()
        big = pool.acquire(100_000)
        pool.release(big)
        small = pool.acquire(8)
        assert small is not big  # a 100 KB buffer must not serve 8 bytes

    def test_oversize_buffers_not_hoarded(self):
        from repro.core.kvserver import _BufferPool
        pool = _BufferPool()
        huge = pool.acquire(_BufferPool._MAX_BUF_BYTES + 1)
        pool.release(huge)
        assert pool._free == []

    def test_retention_count_capped(self):
        from repro.core.kvserver import _BufferPool
        pool = _BufferPool()
        bufs = [pool.acquire(4096) for _ in range(2 * _BufferPool._MAX_BUFS)]
        for b in bufs:
            pool.release(b)
        assert len(pool._free) <= _BufferPool._MAX_BUFS

    def test_high_water_mark_bounded_by_caps(self):
        """The audited worst case never exceeds what the two caps allow,
        no matter the release pattern."""
        from repro.core.kvserver import _BufferPool
        pool = _BufferPool()
        for n in (100, 5_000, 60_000, _BufferPool._MAX_BUF_BYTES,
                  _BufferPool._MAX_BUF_BYTES + 1, 999, 12_345):
            for b in [pool.acquire(n) for _ in range(12)]:
                pool.release(b)
        cap = _BufferPool._MAX_BUFS * _BufferPool._MAX_BUF_BYTES
        assert 0 < pool.high_water <= cap
        assert pool.retained_bytes <= pool.high_water

    def test_pooled_small_frames_roundtrip_correct_values(self, server):
        """Recycled receive buffers never corrupt decoded values: distinct
        payloads over one connection (same pooled buffers) stay distinct."""
        c = KVClient(server.address)
        blobs = [bytes([i]) * 512 for i in range(16)]
        for i, blob in enumerate(blobs):
            c.set(f"pk{i}", blob)
        got = [c.get(f"pk{i}") for i in range(16)]
        assert [bytes(g) for g in got] == blobs
        c.close()


class TestMux:
    """PR 4: the multiplexed client I/O engine — one v3 tagged-frame
    connection per server shared by every thread, a dedicated blocking
    lane, group-commit micro-batching, and futures that can never hang."""

    def test_out_of_order_correlation_under_8_threads(self, server):
        """8 threads hammer ONE client (one shared main-lane socket) with
        distinct keys; every response must land on the thread that asked
        — a single mis-correlated tag would show up as a wrong value."""
        c = KVClient(server.address)
        n_threads, n_ops = 8, 60
        errors = []

        def run(ti):
            try:
                for j in range(n_ops):
                    assert c.incr(f"mux:{ti}") == j + 1
                    c.set(f"mux:val:{ti}", f"{ti}:{j}".encode())
                    assert c.get(f"mux:val:{ti}") == f"{ti}:{j}".encode()
            except Exception as exc:  # pragma: no cover - failure detail
                errors.append((ti, exc))

        threads = [threading.Thread(target=run, args=(i,))
                   for i in range(n_threads)]
        [t.start() for t in threads]
        [t.join(60) for t in threads]
        assert errors == []
        for i in range(n_threads):
            assert server.store.get(f"mux:{i}") == n_ops
        # all of that shared ONE main-lane connection
        assert set(c._muxes) == {"main"}
        c.close()

    def test_out_of_order_responses_on_blocking_lane(self, server):
        """Two blpops parked on one blocking-lane socket: the SECOND
        submitted is answered FIRST — only tag correlation (not arrival
        order) can route the responses to the right futures."""
        c = KVClient(server.address)
        out = {}
        t1 = threading.Thread(target=lambda: out.setdefault(
            "first", c.blpop("ooo:q1", 10)))
        t1.start()
        time.sleep(0.1)
        t2 = threading.Thread(target=lambda: out.setdefault(
            "second", c.blpop("ooo:q2", 10)))
        t2.start()
        time.sleep(0.1)
        server.store.rpush("ooo:q2", b"b")   # wakes the later request
        t2.join(5)
        assert out.get("second") == ("ooo:q2", b"b")
        assert "first" not in out            # still parked, not corrupted
        server.store.rpush("ooo:q1", b"a")
        t1.join(5)
        assert out.get("first") == ("ooo:q1", b"a")
        assert len(c._muxes) <= 2  # one main + one blocking lane, at most
        c.close()

    def test_blocking_lane_isolation(self, server):
        """A parked blpop must not stall the shared main-lane socket:
        fast commands issued while it waits complete well before it."""
        c = KVClient(server.address)
        parked = []
        t = threading.Thread(target=lambda: parked.append(
            c.blpop("iso:q", 4)))
        t.start()
        time.sleep(0.1)
        t0 = time.perf_counter()
        for i in range(50):
            c.incr("iso:fast")
        elapsed = time.perf_counter() - t0
        assert c.get("iso:fast") == 50
        assert elapsed < 2.0, (
            f"fast commands took {elapsed:.1f}s behind a parked blpop")
        server.store.rpush("iso:q", b"done")
        t.join(5)
        assert parked == [("iso:q", b"done")]
        c.close()

    def test_group_commit_merges_queued_submissions(self, server):
        """Submissions enqueued before one flush coalesce into a single
        execute_batch frame: the server sees ONE transaction (EVAL),
        and every future resolves with its own result."""
        c = KVClient(server.address)
        c.incr("warm")                    # establish the main-lane mux
        m = c._mux()
        before = server.store.metrics.commands.get("EVAL", 0)
        futs = [m.submit("single", ("incr", (f"gc:{i}",), {}), flush=False)
                for i in range(10)]
        m.flush()
        assert [f.result() for f in futs] == [(True, 1)] * 10
        assert server.store.metrics.commands.get("EVAL", 0) - before == 1
        c.close()

    def test_merged_error_mid_batch_never_desyncs(self, server):
        """A WRONGTYPE inside a merged group-commit frame fails exactly
        the guilty future; every other future resolves, and the tagged
        framing stays usable for follow-up traffic."""
        c = KVClient(server.address)
        c.set("mex:str", b"v")
        m = c._mux()
        good1 = m.submit("single", ("incr", ("mex:n",), {}), flush=False)
        bad = m.submit("single", ("rpush", ("mex:str", b"x"), {}),
                       flush=False)
        good2 = m.submit("single", ("incr", ("mex:n",), {}), flush=False)
        m.flush()
        assert good1.result() == (True, 1)
        ok, exc = bad.result()
        assert not ok and isinstance(exc, TypeError)
        assert good2.result() == (True, 2)
        # connection still in sync: plain calls keep working
        assert c.incr("mex:n") == 3
        assert c.get("mex:str") == b"v"
        c.close()

    def test_concurrent_pipeline_error_storm_stays_in_sync(self, server):
        """8 threads flushing pipelines where a third of the commands
        error: every thread sees its own errors in its own batch, and
        the shared socket never desyncs."""
        from repro.core.kvstore import PipelineError
        c = KVClient(server.address)
        c.set("storm:bad", b"not-a-list")
        errors = []

        def run(ti):
            try:
                for r in range(10):
                    p = c.pipeline()
                    p.incr(f"storm:{ti}")
                    p.rpush("storm:bad", b"x")   # always WRONGTYPE
                    p.incr(f"storm:{ti}")
                    with pytest.raises(PipelineError) as ei:
                        p.execute()
                    assert ei.value.index == 1
            except Exception as exc:  # pragma: no cover - failure detail
                errors.append((ti, exc))

        threads = [threading.Thread(target=run, args=(i,)) for i in range(8)]
        [t.start() for t in threads]
        [t.join(60) for t in threads]
        assert errors == []
        for i in range(8):
            assert server.store.get(f"storm:{i}") == 20
        assert c.get("storm:bad") == b"not-a-list"
        c.close()

    def test_shutdown_reclaims_parked_futures(self, server):
        """close() while a blpop is parked: the waiter gets a prompt
        ConnectionError — no future is left hanging on a dead socket —
        and the client transparently reconnects afterwards."""
        c = KVClient(server.address)
        got = []

        def park():
            try:
                got.append(c.blpop("reclaim:q", 30))
            except ConnectionError:
                got.append("connection-error")

        t = threading.Thread(target=park)
        t.start()
        time.sleep(0.2)
        t0 = time.perf_counter()
        c.close()
        t.join(5)
        assert got == ["connection-error"]
        assert time.perf_counter() - t0 < 3.0
        assert c.incr("reclaim:n") == 1   # fresh mux on next use
        c.close()

    def test_queued_submissions_fail_when_connection_dies(self, server):
        """Unflushed submissions on a killed mux resolve with the error
        instead of waiting for a flush that can never happen."""
        c = KVClient(server.address)
        c.incr("warm")
        m = c._mux()
        fut = m.submit("single", ("incr", ("dead:n",), {}), flush=False)
        m.close()
        ok, exc = fut.result()
        assert not ok and isinstance(exc, ConnectionError)
        with pytest.raises(ConnectionError):
            m.submit("single", ("incr", ("dead:n",), {}))
        c.close()

    def test_nontransactional_pipeline_routes_blocking_ops(self, server):
        """A non-transactional pipeline mixing fast commands with a
        genuinely blocking pop: the pop parks on the blocking lane and is
        woken by the pipeline's own rpush riding the main lane."""
        c = KVClient(server.address)
        p = c.pipeline(transactional=False)
        fast = p.incr("lane:n")
        popped = p.blpop("lane:q", 10)    # blocking: rides the block lane
        p.rpush("lane:q", b"wake")        # lands on the main lane
        p.execute()
        assert fast.get() == 1
        assert popped.get() == ("lane:q", b"wake")
        c.close()

    def test_chunk_flush_keys_on_last_pending(self, server):
        """The interleaving that used to hang a non-transactional
        pipeline: a concurrent thread's flush ships the chunk's FIRST
        pending, then more commands enqueue. Flushing keyed on the LAST
        pending must ship the stragglers (keyed on the first, they were
        stranded unsent forever)."""
        c = KVClient(server.address)
        c.incr("warm")
        m = c._mux()
        p1 = m.submit("single", ("incr", ("lastkey:a",), {}), flush=False)
        m.flush()   # stand-in for another thread's traffic: ships p1
        assert p1.sent
        p2 = m.submit("single", ("incr", ("lastkey:b",), {}), flush=False)
        m.flush(p2)  # what the fixed chunk drain does: key on the LAST
        assert p1.result() == (True, 1)
        assert p2.result() == (True, 1)
        c.close()

    def test_encode_failure_fails_only_guilty_pending(self, server):
        """An unpicklable argument must fail ITS future with the pickle
        error — without killing the connection, stranding co-batched
        futures, or losing the reader baton."""
        class Boom:
            def __reduce__(self):
                raise RuntimeError("unpicklable on purpose")

        c = KVClient(server.address)
        c.incr("warm")
        m = c._mux()
        # solo bad submission: nominated as reader, then resolved by the
        # encode failure — the baton must be released, not die with it
        ok, exc = m.submit("single", ("set", ("ek", Boom()), {})).result()
        assert not ok and isinstance(exc, RuntimeError)
        assert m.alive
        # connection (and baton) still fully usable
        assert c.incr("ek:n") == 1
        # co-batched: good + bad + good in one flush — every future
        # resolves, nothing hangs
        g1 = m.submit("single", ("incr", ("ek:g",), {}), flush=False)
        bad = m.submit("single", ("set", ("ek", Boom()), {}), flush=False)
        g2 = m.submit("single", ("incr", ("ek:g",), {}), flush=False)
        m.flush(g2)
        results = [g1.result(), bad.result(), g2.result()]
        assert all(r is not None for r in results)
        assert not results[1][0]
        # the goods may have shared the bad's merged frame (then they
        # fail with it and the key is untouched) or ridden their own
        assert c.get("ek:g") in (None, 1, 2)
        assert c.incr("ek:after") == 1
        c.close()

    def test_blocking_workers_are_reused(self, server):
        """Steady-state blocking polls (the executor-collector pattern)
        must reuse the server's parked-command worker instead of
        spawning one thread per request."""
        import threading as _threading
        c = KVClient(server.address)
        for _ in range(5):
            assert c.blpop("bw:never", 0.01) is None
        before = _threading.active_count()
        for _ in range(20):
            assert c.blpop("bw:never", 0.01) is None
        after = _threading.active_count()
        # 20 blocking requests must not have minted ~20 threads
        assert after - before <= 2, (before, after)
        c.close()

    def test_fork_inherited_mux_not_shared(self, server):
        """A mux created before a fork must not be reused in the child:
        the pid guard forces a fresh connection (shared fds would
        interleave two processes' tags on one socket)."""
        import os
        c = KVClient(server.address)
        c.incr("fork:n")
        m = c._mux()
        m.pid = os.getpid() + 1   # simulate: created by another process
        m2 = c._mux()
        assert m2 is not m and m2.pid == os.getpid()
        assert c.incr("fork:n") == 2
        c.close()


class TestTransactionKeyHintOverTCP:
    def test_joinable_queue_task_done_over_plain_client(self, server):
        """A generic-dispatch KVClient looks like it has `.shards`, so the
        IPC layer passes transaction(..., key_hint=...); the remote
        KVStore must accept and ignore the hint, not TypeError."""
        set_session(Session(store=KVClient(server.address)))
        q = mp.JoinableQueue()
        q.put("item")
        assert q.get(timeout=5) == "item"
        q.task_done()
        q.join(5)

    def test_bounded_semaphore_release_over_plain_client(self, server):
        set_session(Session(store=KVClient(server.address)))
        sem = mp.BoundedSemaphore(1)
        sem.acquire()
        sem.release()
        with pytest.raises(ValueError):
            sem.release()


# ---------------------------------------------------------------------------
# PR 6: pluggable same-host transports (tcp / uds / shm rings)
# ---------------------------------------------------------------------------


TRANSPORTS = ["tcp", "uds", "shm"]


class TestTransports:
    """The full client surface over every carrier: the same frames must
    behave identically whether they cross a TCP socket, a Unix-domain
    socket, or a shared-memory ring."""

    @pytest.mark.parametrize("transport", TRANSPORTS)
    def test_basic_commands(self, server, transport):
        c = KVClient(server.endpoints, transport=transport)
        c.set("k", b"v")
        assert c.get("k") == b"v"
        c.rpush("l", b"1", b"2")
        assert c.lrange("l", 0, -1) == [b"1", b"2"]
        assert c.incr("n") == 1
        assert c._mux("main").endpoint.scheme == transport
        c.close()

    @pytest.mark.parametrize("transport", TRANSPORTS)
    def test_large_payload_oob(self, server, transport):
        c = KVClient(server.endpoints, transport=transport)
        blob = bytes(range(256)) * 4096   # 1 MiB: OOB + ring wraparound
        c.set("big", blob)
        assert c.get("big") == blob
        c.close()

    @pytest.mark.parametrize("transport", TRANSPORTS)
    def test_pipeline_both_modes(self, server, transport):
        c = KVClient(server.endpoints, transport=transport)
        for transactional in (True, False):
            p = c.pipeline(transactional=transactional)
            p.set("pk", 1)
            p.incr("pk")
            p.get("pk")
            assert p.execute()[-1] == 2
            c.delete("pk")
        c.close()

    @pytest.mark.parametrize("transport", TRANSPORTS)
    def test_blocking_across_connections(self, server, transport):
        c1 = KVClient(server.endpoints, transport=transport)
        c2 = KVClient(server.endpoints, transport=transport)
        got = []
        t = threading.Thread(
            target=lambda: got.append(c1.blpop("bq", timeout=10)))
        t.start()
        time.sleep(0.1)
        c2.rpush("bq", b"x")
        t.join(10)
        assert got == [("bq", b"x")]
        c1.close()
        c2.close()

    @pytest.mark.parametrize("transport", TRANSPORTS)
    def test_per_thread_sockets_mode(self, server, transport):
        c = KVClient(server.endpoints, transport=transport, mux=False)
        c.set("s", 41)
        assert c.incr("s") == 42
        c.close()

    def test_auto_selection_prefers_shm_same_host(self, server):
        from repro.core import transport as T
        c = KVClient(server.endpoints)
        c.set("a", 1)
        want = "shm" if T.ring_supported() else (
            "uds" if T.uds_supported() else "tcp")
        assert c._mux("main").endpoint.scheme == want
        c.close()

    def test_blocking_lane_avoids_shm_in_auto_mode(self, server):
        """A parked BLPOP must sleep in the kernel, not spin/yield on a
        ring: the blocking lane auto-selects a socket carrier."""
        c = KVClient(server.endpoints)
        got = []
        t = threading.Thread(
            target=lambda: got.append(c.blpop("lane:q", timeout=10)))
        t.start()
        time.sleep(0.1)
        lane = c._mux("blocking")
        assert lane.endpoint.scheme != "shm"
        c.rpush("lane:q", b"y")
        t.join(10)
        assert got == [("lane:q", b"y")]
        c.close()

    def test_tuple_address_still_works(self, server):
        c = KVClient(server.address)        # legacy (host, port) shape
        c.set("t", 7)
        assert c.get("t") == 7
        c.close()

    def test_unknown_transport_rejected(self, server):
        with pytest.raises(ValueError):
            KVClient(server.endpoints, transport="carrier-pigeon").incr("x")

    def test_server_stop_removes_uds_path(self):
        import glob
        import os
        srv = KVServer()
        srv.start()
        uds = [e for e in srv.endpoints if e.startswith("uds://")]
        assert uds, srv.endpoints
        path = uds[0][len("uds://"):]
        assert os.path.exists(path)
        srv.stop()
        assert not os.path.exists(path)
        assert not os.path.exists(os.path.dirname(path))

    def test_stop_closes_live_rings(self, server):
        """Server stop tears down accepted rings so client ops fail fast
        instead of spinning against a dead peer."""
        c = KVClient(server.endpoints, transport="shm")
        c.set("k", 1)
        server.stop()
        with pytest.raises(Exception):
            for _ in range(3):
                c.get("k")
                time.sleep(0.2)
        c.close()
