"""Examples run end-to-end as subprocesses (reduced sizes)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")


def run_example(script, *args, timeout=600):
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", script), *args],
        env=env, capture_output=True, text=True, timeout=timeout, cwd=ROOT)
    assert proc.returncode == 0, f"{script}: {proc.stderr[-2000:]}"
    return proc.stdout


@pytest.mark.slow
class TestExamples:
    def test_quickstart(self):
        out = run_example("quickstart.py", "--samples", "100000",
                          "--procs", "4")
        assert "pi ~= 3.1" in out
        assert "OK" in out

    def test_evolution_strategies(self):
        out = run_example("evolution_strategies.py", "--iters", "10",
                          "--pop", "24", "--procs", "4")
        assert "final error" in out

    def test_grid_search(self):
        out = run_example("grid_search.py", "--procs", "4")
        assert "best:" in out

    def test_ppo(self):
        out = run_example("ppo.py", "--envs", "2", "--iters", "2",
                          "--horizon", "16")
        assert "piped env workers" in out

    def test_train_lm_and_resume(self):
        out = run_example("train_lm.py", "--steps", "12",
                          "--ckpt-every", "6", "--batch", "2",
                          "--seq", "32")
        assert "checkpoints:" in out

    def test_train_lm_dp(self):
        out = run_example("train_lm.py", "--steps", "3", "--dp", "2",
                          "--batch", "2", "--seq", "32")
        assert "[dp]" in out

    def test_autoscale(self):
        out = run_example("autoscale.py", "--tasks", "40", "--max", "4")
        assert "graceful drains" in out
        assert "autoscale example: OK" in out

    def test_serve_lm(self):
        out = run_example("serve_lm.py", "--batch", "2",
                          "--prompt-len", "8", "--new-tokens", "8")
        assert "decode == teacher-forced argmax: OK" in out

    def test_serve_continuous(self):
        out = run_example("serve_continuous.py", "--requests", "6",
                          "--slots", "3")
        assert "1 compile OK" in out
        assert "continuous outputs == per-request static decode: OK" in out
