import time

import pytest

from repro.core import Session, set_session
from repro.core.executor import (FunctionExecutor, FunctionTimeoutError,
                                 RemoteError)
from repro.core.session import InvocationModel


class TestExecutor:
    def test_call_and_map(self):
        ex = FunctionExecutor()
        assert ex.call_async(lambda a, b: a + b, (1, 2)).result(5) == 3
        futs = ex.map(lambda x: x ** 2, range(6))
        assert [f.result(5) for f in futs] == [0, 1, 4, 9, 16, 25]
        ex.shutdown()

    def test_both_monitoring_modes(self):
        for monitoring in ("queue", "storage"):
            ex = FunctionExecutor(monitoring=monitoring)
            futs = ex.map(lambda x: x + 1, range(4))
            assert [f.result(10) for f in futs] == [1, 2, 3, 4]
            ex.shutdown()

    def test_remote_error_carries_traceback(self):
        ex = FunctionExecutor()

        def boom():
            raise ValueError("inner detail")
        fut = ex.call_async(boom)
        with pytest.raises(RemoteError, match="inner detail") as ei:
            fut.result(5)
        assert "ValueError" in ei.value.remote_traceback
        ex.shutdown()

    def test_cold_then_warm(self):
        set_session(Session())
        ex = FunctionExecutor()
        f1 = ex.call_async(lambda: 1)
        f1.result(5)
        assert f1.cold is True
        f2 = ex.call_async(lambda: 2)
        f2.result(5)
        assert f2.cold is False  # container reused
        ex.shutdown()

    def test_prewarm_pool(self):
        ex = FunctionExecutor(prewarm=3)
        futs = ex.map(lambda x: x, range(3))
        [f.result(5) for f in futs]
        assert all(f.cold is False for f in futs)
        ex.shutdown()

    def test_time_limit(self):
        ex = FunctionExecutor(time_limit_s=0.01)
        fut = ex.call_async(time.sleep, (0.1,))
        with pytest.raises(FunctionTimeoutError):
            fut.result(5)
        ex.shutdown()

    def test_invocation_model_accounting(self):
        sess = set_session(Session())
        sess.invocation = InvocationModel(
            cold_invoke_s=1.719, warm_invoke_s=0.258, setup_s=0.05,
            serialize_s=0.004, upload_s=0.002, scale=0.001)
        ex = FunctionExecutor()
        cold = ex.call_async(lambda: 0)
        cold.result(5)
        warm = ex.call_async(lambda: 0)
        warm.result(5)
        # Table 1 structure: virtual stats carry the unscaled values
        assert cold.stats["invoke_s"] == pytest.approx(1.719)
        assert warm.stats["invoke_s"] == pytest.approx(0.258)
        assert warm.stats["setup_s"] == pytest.approx(0.05)
        ex.shutdown()

    def test_payload_travels_through_storage(self):
        sess = set_session(Session())
        ex = FunctionExecutor()
        ex.call_async(lambda: None).result(5)
        assert any(k.startswith("jobs/") for k in sess.get_storage().list())
        ex.shutdown()

    def test_map_serializes_function_once(self):
        """map() reduces the function graph once, not once per item;
        per-task payload stats still carry the true upload size."""
        from repro.core import serialization as ser
        reductions = []
        orig = ser._Pickler._reduce_function

        def counting(self, fn):
            reductions.append(fn)
            return orig(self, fn)

        ser._Pickler._reduce_function = counting
        try:
            ex = FunctionExecutor()
            big = list(range(1000))  # captured: costly to re-serialize
            futs = ex.map(lambda x: x + big[0], range(8))
            assert [f.result(10) for f in futs] == list(range(8))
            assert len(reductions) == 1
            assert all(f.stats["payload_bytes"] > 1000 for f in futs)
            ex.shutdown()
        finally:
            ser._Pickler._reduce_function = orig


class TestCollectorFailover:
    def test_collector_reparks_after_shard_unavailable(self):
        """PR 7: a ShardUnavailableError under the collector's parked
        BLPOP triggers descriptor refresh + re-park (bounded), not
        job failure — the path a shard failover exercises."""
        from repro.core.errors import ShardUnavailableError

        ex = FunctionExecutor()
        real = ex._store
        calls = {"fail": 3, "refresh": 0}

        class FlakyStore:
            def __getattr__(self, name):
                return getattr(real, name)

            def blpop(self, *a, **k):
                if calls["fail"] > 0:
                    calls["fail"] -= 1
                    raise ShardUnavailableError("injected failover",
                                                shard=0)
                return real.blpop(*a, **k)

            def refresh(self, force=False):
                calls["refresh"] += 1
                return True

        ex._store = FlakyStore()
        try:
            fut = ex.call_async(lambda: 42, ())
            assert fut.result(20) == 42
            assert calls["fail"] == 0, "collector gave up before retrying"
            assert calls["refresh"] >= 1, "collector never refreshed"
        finally:
            ex._store = real
            ex.shutdown()

    def test_collector_settles_when_shard_stays_down(self):
        """A permanently unavailable result-list shard must settle
        pending futures with the typed error, not hang."""
        from repro.core.errors import ShardUnavailableError

        ex = FunctionExecutor()
        real = ex._store

        class DeadStore:
            def __getattr__(self, name):
                return getattr(real, name)

            def blpop(self, *a, **k):
                raise ShardUnavailableError("shard stayed down", shard=1)

        ex._store = DeadStore()
        try:
            fut = ex.call_async(lambda: 1, ())
            with pytest.raises(RemoteError, match="unavailable|stayed down"):
                fut.result(30)
        finally:
            ex._store = real
            ex.shutdown()
