import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_session
from repro.runtime import (CheckpointManager, ElasticPolicy, ErrorFeedback,
                           JobFailedError, JobRunner, int8_compress,
                           int8_decompress, topk_compress, topk_decompress)


class TestJobRunner:
    def test_ordered_results(self):
        r = JobRunner(n_workers=3)
        try:
            assert r.run(lambda x: x * 10, range(12)) == \
                [x * 10 for x in range(12)]
        finally:
            r.shutdown()

    def test_retry_on_transient_failure(self):
        r = JobRunner(n_workers=2, lease_ttl=0.5)

        def flaky(x):
            from repro.core import get_session
            n = get_session().store.incr(f"flk:{x}")
            if n < 3:
                raise RuntimeError("transient")
            return x
        try:
            assert r.run(flaky, [7, 8]) == [7, 8]
            assert r.stats["retries"] >= 4
        finally:
            r.shutdown()

    def test_permanent_failure_raises(self):
        r = JobRunner(n_workers=2, max_retries=1, lease_ttl=0.5)

        def always(x):
            raise ValueError("permanent")
        try:
            with pytest.raises(JobFailedError, match="permanent"):
                r.run(always, [1])
        finally:
            r.shutdown()

    def test_straggler_speculation(self):
        r = JobRunner(n_workers=4, lease_ttl=0.4, speculate_factor=3.0)

        def slow_one(x):
            time.sleep(1.2 if x == 3 else 0.03)
            return x
        try:
            assert r.run(slow_one, range(8)) == list(range(8))
            assert r.stats["speculations"] >= 1
        finally:
            r.shutdown()

    def test_elastic_resize(self):
        r = JobRunner(n_workers=1)
        try:
            r.resize(4)
            assert r.run(lambda x: x, range(8)) == list(range(8))
            r.resize(2)
        finally:
            r.shutdown()


class TestCheckpoint:
    def test_roundtrip_pytree(self):
        ck = CheckpointManager(prefix="c1")
        state = {"a": jnp.arange(6.0), "b": {"c": np.ones((2, 3)),
                                             "d": jnp.int32(5)}}
        info = ck.save(3, state)
        assert info["n_leaves"] == 3
        step, restored = ck.restore()
        assert step == 3
        np.testing.assert_array_equal(restored["a"], state["a"])
        np.testing.assert_array_equal(restored["b"]["c"], state["b"]["c"])

    def test_latest_pointer_and_gc(self):
        ck = CheckpointManager(prefix="c2", keep=2)
        st = {"x": jnp.zeros(3)}
        for s in (1, 2, 3, 4):
            ck.save(s, st)
        assert ck.latest_step() == 4
        assert ck.steps() == [3, 4]  # old ones GC'd

    def test_async_save(self):
        ck = CheckpointManager(prefix="c3")
        ck.save_async(7, {"x": jnp.ones(4)})
        ck.wait()
        step, restored = ck.restore()
        assert step == 7

    def test_parallel_io_through_runner(self):
        r = JobRunner(n_workers=3)
        try:
            ck = CheckpointManager(prefix="c4", runner=r)
            state = {f"w{i}": jnp.full((8,), float(i)) for i in range(6)}
            ck.save(1, state)
            _, restored = ck.restore(1)
            for i in range(6):
                np.testing.assert_array_equal(restored[f"w{i}"],
                                              state[f"w{i}"])
        finally:
            r.shutdown()

    def test_restore_missing_raises(self):
        ck = CheckpointManager(prefix="c5")
        with pytest.raises(FileNotFoundError):
            ck.restore()


class TestElasticPolicy:
    def test_scale_up_on_backlog(self):
        p = ElasticPolicy(min_workers=1, max_workers=16,
                          backlog_per_worker=2.0)
        assert p.decide(n_workers=2, backlog=20, idle_cycles=0) > 2

    def test_scale_down_when_idle(self):
        p = ElasticPolicy(min_workers=1, idle_cycles_before_shrink=3)
        assert p.decide(n_workers=8, backlog=0, idle_cycles=5) < 8
        assert p.decide(n_workers=8, backlog=0, idle_cycles=1) == 8

    def test_bounds(self):
        p = ElasticPolicy(min_workers=2, max_workers=4)
        assert p.decide(1000, backlog=10 ** 6, idle_cycles=0) == 4
        assert p.decide(2, backlog=0, idle_cycles=99) == 2


class TestCompression:
    def test_topk_roundtrip_keeps_largest(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal((64, 32)))
        idx, vals, shape = topk_compress(x, 0.1)
        back = topk_decompress(idx, vals, shape)
        kept = np.abs(np.asarray(back)).ravel()
        thresh = np.sort(np.abs(np.asarray(x)).ravel())[-len(vals)]
        assert (kept[kept > 0] >= thresh - 1e-6).all()

    def test_int8_error_bounded(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal((32, 256)))
        err = jnp.abs(int8_decompress(int8_compress(x)) - x)
        # row-absmax/127 quantization step bound
        assert float(err.max()) < float(jnp.abs(x).max()) / 100

    def test_error_feedback_conserves_gradient_mass(self):
        ef = ErrorFeedback(ratio=0.1)
        g = {"w": jnp.ones((100,))}
        total = jnp.zeros((100,))
        for _ in range(10):
            payload = ef.compress_tree(g)
            total = total + ef.decompress_tree(payload, g)["w"]
        residual = ef._residual["['w']"]
        # transmitted + residual == everything that was ever fed in
        np.testing.assert_allclose(float(total.sum() + residual.sum()),
                                   10 * 100, rtol=1e-5)
        # EF rotated through coordinates: most were sent at least once
        assert float((total > 0).mean()) > 0.9


class TestElasticPool:
    def test_controller_scales_pool(self):
        from repro.core import mp
        from repro.runtime import ElasticController
        pool = mp.Pool(1)
        try:
            ctl = ElasticController(
                pool, ElasticPolicy(min_workers=1, max_workers=8,
                                    backlog_per_worker=1.0,
                                    idle_cycles_before_shrink=100),
                interval=0.05)
            with ctl:
                res = pool.map_async(lambda x: time.sleep(0.05) or x,
                                     range(40), chunksize=1)
                res.get(30)
            assert ctl.decisions, "controller never scaled"
            assert max(d[2] for d in ctl.decisions) > 1
        finally:
            pool.terminate()
            pool.join(5)
