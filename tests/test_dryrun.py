"""Multi-pod dry-run machinery smoke: run launch/dryrun.py in a subprocess
with 8 forced host devices and tiny shape cells (lower+compile+analyze end
to end on a real multi-axis mesh, without the 512-device cost)."""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.models import SHAPES
from repro.models.config import ShapeConfig
from repro.sharding import MeshRules
from repro.launch.specs import build_cell
from repro.launch.dryrun import parse_collectives
from repro.launch.cost_model import estimate_cost

SHAPES["t_train"] = ShapeConfig("t_train", "train", 128, 4)
SHAPES["t_decode"] = ShapeConfig("t_decode", "decode", 128, 4)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     devices=jax.devices(),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
rules = MeshRules(mesh=mesh, fsdp=True)
out = {}
for arch, shape in [("qwen1.5-0.5b", "t_train"), ("qwen1.5-0.5b", "t_decode")]:
    cell = build_cell(arch, shape, rules, overrides={"microbatches": 2})
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        compiled = jitted.lower(*cell.args).compile()
    mem = compiled.memory_analysis()
    coll = parse_collectives(compiled.as_text())
    est = estimate_cost(cell.fn, *cell.args, n_devices=8)
    out[f"{arch}/{shape}"] = {
        "temp_bytes": int(mem.temp_size_in_bytes),
        "coll_ops": sum(v["count"] for v in coll["per_op"].values()),
        "flops": est.flops,
    }
import json
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_multiaxis_mesh_compiles():
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    train = out["qwen1.5-0.5b/t_train"]
    decode = out["qwen1.5-0.5b/t_decode"]
    assert train["flops"] > decode["flops"] > 0
    assert train["coll_ops"] > 0          # pod axis actually shards
    assert train["temp_bytes"] > 0


def test_cell_skip_rules():
    from repro.launch.specs import cell_is_skipped
    assert cell_is_skipped("llama3-8b", "long_500k") is not None
    assert cell_is_skipped("rwkv6-7b", "long_500k") is None
    assert cell_is_skipped("zamba2-2.7b", "long_500k") is None
    assert cell_is_skipped("llama3-8b", "train_4k") is None


def test_artifacts_if_present_are_wellformed():
    art = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                       "artifacts", "dryrun")
    if not os.path.isdir(art):
        pytest.skip("no dry-run artifacts yet")
    names = [n for n in os.listdir(art) if n.endswith(".json")]
    if not names:
        pytest.skip("no dry-run artifacts yet")
    for name in names:
        with open(os.path.join(art, name)) as f:
            rec = json.load(f)
        assert rec["status"] in ("ok", "skipped", "error")
        if rec["status"] == "ok":
            assert rec["t_step"] > 0
            assert rec["bottleneck"] in ("compute", "memory", "collective")
            assert 0 <= rec["roofline_fraction"]
