"""v4 raw wire dialect: codec round-trip matrix, pickle fallback, the
server dispatch-table fast path, and the v1-v4 client interop grid."""

import math
import threading
import time

import pytest

from repro.core import KVClient, KVServer
from repro.core import serialization as ser

# ---------------------------------------------------------------------------
# Codec round trips (no sockets)
# ---------------------------------------------------------------------------

#: The full raw value vocabulary, edge cases included.
ROUNDTRIP_VALUES = [
    None, True, False,
    0, 1, -1, 255, -256,
    (1 << 63) - 1, -(1 << 63),            # i64 boundaries
    1 << 63, -(1 << 63) - 1,              # just past i64 -> bigint
    1 << 200, -(1 << 200), 12345678901234567890123456789,
    0.0, -0.0, 1.5, -2.25, float("inf"), float("-inf"), 1e308,
    b"", b"x", b"\x00\xff" * 50, bytes(range(256)),
    "", "plain", "héllo ünicode ✓", "中文",
    "a\ud800b",                           # lone surrogate (surrogatepass)
    (), [], {},
    (1, "two", b"three", None, True),
    [b"x", 2.5, False, ""],
    {"a": 1, "b": [1, 2], "c": ("x", b"y")},
    {"nested": {"deeper": [1, (2, 3)]}},
    ["mixed", [1, [2, 3]], {"k": b"v"}],
]


class TestValueRoundTrip:
    @pytest.mark.parametrize("value", ROUNDTRIP_VALUES,
                             ids=lambda v: repr(v)[:40])
    def test_roundtrip_as_arg_and_reply(self, value):
        body = ser.encode_command("set", ("k", value), {})
        assert body is not None
        cmd, args, kwargs = ser.decode_command(body)
        assert cmd == "set" and kwargs == {}
        got = args[1]
        assert type(got) is type(value) or (
            isinstance(value, (bytearray, memoryview)))
        assert got == value
        rbody = ser.encode_reply(True, value)
        assert rbody is not None
        ok, rvalue = ser.decode_reply(rbody)
        assert ok is True and rvalue == value and type(rvalue) is type(value)

    def test_nan_roundtrip(self):
        ok, v = ser.decode_reply(ser.encode_reply(True, float("nan")))
        assert ok and math.isnan(v)

    def test_mutable_buffers_fall_back_to_pickle(self):
        """bytearray/memoryview would decode narrowed to bytes, so they
        stay on the pickle dialect (type fidelity over the wire)."""
        assert ser.encode_command("set", ("k", bytearray(b"ab")), {}) is None
        assert ser.encode_command("set", ("k", memoryview(b"cd")), {}) is None
        assert ser.encode_reply(True, bytearray(b"ab")) is None

    def test_int_float_bool_tags_distinct(self):
        """1, 1.0 and True hash equal but must encode distinctly."""
        for v in (1, 1.0, True):
            got = ser.decode_reply(ser.encode_reply(True, v))[1]
            assert got == v and type(got) is type(v)
        # and through the encode cache: same key string, different values
        a = ser.encode_command("expire", ("k", 1), {})
        b = ser.encode_command("expire", ("k", 1.0), {})
        assert a != b
        assert type(ser.decode_command(a)[1][1]) is int
        assert type(ser.decode_command(b)[1][1]) is float


class TestCommandRoundTrip:
    CASES = [
        ("get", ("k",), {}),
        ("set", ("k", b"v"), {"ex": 2.5, "nx": True}),
        ("mget", (["a", "b", "c"],), {}),
        ("mset", ({"a": b"1", "b": 2},), {}),
        ("incr", ("n",), {}),
        ("incrby", ("n", -3), {}),
        ("rpush", ("l", b"1", b"2", b"3"), {}),
        ("lpop", ("l",), {}),
        ("blpop", (["q1", "q2"], 5), {}),
        ("blpop", ("q",), {"timeout": None}),
        ("blpop_rpush", ("slots", "items", b"payload", 0.25), {}),
        ("bllen", ("k", 1.0), {}),
        ("getrange", ("k", 0, -1), {}),
        ("setrange", ("k", 4096, b"zz"), {}),
        ("msetrange", ([("k", 0, b"ab"), ("k2", 7, b"cd")],), {}),
        ("strlen", ("k",), {}),
        ("expire", ("k", 3.5), {}),
        ("delete", ("a", "b", "c"), {}),
    ]

    @pytest.mark.parametrize("cmd,args,kwargs", CASES,
                             ids=lambda c: c if isinstance(c, str) else None)
    def test_roundtrip(self, cmd, args, kwargs):
        body = ser.encode_command(cmd, args, kwargs)
        assert body is not None
        assert ser.decode_command(body) == (cmd, args, kwargs)

    def test_decode_command_id_matches_vocabulary(self):
        body = ser.encode_command("incr", ("n",), {})
        cid, args, kwargs = ser.decode_command_id(body)
        assert ser.RAW_COMMANDS[cid] == "incr"
        assert args == ("n",) and kwargs == {}

    def test_execute_batch_roundtrip(self):
        entries = [("incr", ("a",), {}), ("set", ("b", b"v"), {"nx": True}),
                   ("blpop", ("q", 0.0), {})]
        body = ser.encode_command("execute_batch", (entries,), {})
        assert body is not None
        cmd, args, kwargs = ser.decode_command(body)
        assert cmd == "execute_batch" and kwargs == {}
        assert args[0] == entries
        # id-form entries for the dispatch table
        cid, (id_entries,), _ = ser.decode_command_id(body)
        assert cid == ser.RAW_EXEC_ID
        assert [ser.RAW_COMMANDS[e[0]] for e in id_entries] == [
            "incr", "set", "blpop"]

    def test_batch_merge_is_concatenation(self):
        """Group commit merges pre-encoded entries byte-for-byte."""
        subs = [ser.encode_command("incr", (f"k{i}",), {}) for i in range(4)]
        merged = ser.encode_batch_entries(subs)
        direct = ser.encode_command(
            "execute_batch", ([("incr", (f"k{i}",), {}) for i in range(4)],),
            {})
        assert merged == direct


class TestFallback:
    def test_unknown_command(self):
        assert ser.encode_command("hset", ("h", "f", b"v"), {}) is None
        assert ser.encode_command("transaction", (lambda s: None,), {}) is None

    def test_non_raw_argument(self):
        assert ser.encode_command("set", ("k", object()), {}) is None
        assert ser.encode_command("set", ("k", {1: "non-str-key"}), {}) is None
        assert ser.encode_command("set", ("k", {"x"}), {}) is None  # set type

    def test_oob_sized_bytes_stay_on_pickle_path(self):
        big = b"x" * ser.OOB_THRESHOLD
        assert ser.encode_command("set", ("k", big), {}) is None
        assert ser.encode_command("set", ("k", big[:-1]), {}) is not None
        assert ser.encode_reply(True, big) is None

    def test_too_deep_nesting(self):
        v = [[[[[1]]]]]
        assert ser.encode_command("set", ("k", v), {}) is None

    def test_exec_entry_fallback_poisons_whole_batch(self):
        entries = [("incr", ("a",), {}), ("hset", ("h", "f", b"v"), {})]
        assert ser.encode_command("execute_batch", (entries,), {}) is None

    def test_no_nested_execute_batch(self):
        inner = [("incr", ("a",), {})]
        entries = [("execute_batch", (inner,), {})]
        assert ser.encode_command("execute_batch", (entries,), {}) is None

    def test_exception_reply_falls_back(self):
        assert ser.encode_reply(False, ValueError("boom")) is None

    def test_wide_reply_falls_back_to_c_unpickler(self):
        assert ser.encode_reply(True, list(range(100))) is None
        assert ser.encode_reply(True, list(range(4))) is not None

    def test_malformed_body_raises_valueerror(self):
        body = ser.encode_command("incr", ("k",), {})
        with pytest.raises(ValueError):
            ser.decode_command_id(body[:-2])
        with pytest.raises(ValueError):
            ser.decode_command_id(body + b"\x00")
        with pytest.raises(ValueError):
            ser.decode_reply(b"\x01\x7f")


# ---------------------------------------------------------------------------
# Wire: the four dialects against one server
# ---------------------------------------------------------------------------


@pytest.fixture
def server():
    with KVServer() as srv:
        yield srv


def _dialect_clients(address, transport=None):
    """One client per wire dialect: v1 legacy pickle, v2 per-thread
    pickle, v3 multiplexed pickle, v4 raw (mux and per-thread).
    ``transport`` pins all of them to one carrier (PR 6)."""
    kw = {"transport": transport}
    return {
        "v1": KVClient(address, legacy_protocol=True, **kw),
        "v2": KVClient(address, mux=False, raw=False, **kw),
        "v3": KVClient(address, mux=True, raw=False, **kw),
        "v4": KVClient(address, mux=True, raw=True, **kw),
        "v4-sockets": KVClient(address, mux=False, raw=True, **kw),
    }


class TestInterop:
    def test_dialect_grid(self, server):
        """Every (writer, reader) pair across v1-v4 observes the same
        store state — the server answers each request in the dialect it
        arrived in."""
        clients = _dialect_clients(server.address)
        try:
            for wname, w in clients.items():
                w.set(f"grid:{wname}", f"from-{wname}".encode())
                w.rpush(f"grid:{wname}:l", b"a", b"b")
                w.incr("grid:counter")
            for rname, r in clients.items():
                for wname in clients:
                    assert r.get(f"grid:{wname}") == f"from-{wname}".encode(), \
                        f"{rname} reading {wname}"
                    assert r.llen(f"grid:{wname}:l") == 2
            assert clients["v1"].get("grid:counter") == len(clients)
        finally:
            for c in clients.values():
                c.close()

    def test_mixed_dialects_on_one_connection(self, server):
        """A raw client interleaves raw-codable and pickle-fallback
        commands (plus OOB-sized payloads) on the SAME connection; every
        frame self-describes, so framing never desyncs."""
        c = KVClient(server.address)
        big = b"z" * (1 << 20)  # OOB path
        for i in range(3):
            assert c.incr("mix:n") == i + 1            # raw
            c.hset("mix:h", f"f{i}", b"x")             # pickle fallback
            c.rpush("mix:big", big)                    # pickle + OOB parts
            assert c.lpop("mix:big") == big
            assert c.strlen("mix:missing") == 0        # raw
        assert c.hgetall("mix:h") == {f"f{i}": b"x" for i in range(3)}
        c.close()

    def test_raw_error_reply_keeps_connection_synced(self, server):
        c = KVClient(server.address)
        c.set("k", b"v")
        with pytest.raises(TypeError):
            c.rpush("k", b"x")  # WRONGTYPE -> pickle error reply
        assert c.get("k") == b"v"  # still in sync
        with pytest.raises(AttributeError):
            c.definitely_not_a_command("k")
        assert c.incr("n") == 1
        c.close()

    def test_raw_blocking_lane(self, server):
        c1, c2 = KVClient(server.address), KVClient(server.address)
        out = []
        t = threading.Thread(target=lambda: out.append(c2.blpop("rq", 5)))
        t.start()
        time.sleep(0.05)
        c1.rpush("rq", b"msg")
        t.join(3)
        assert out == [("rq", b"msg")]
        assert c2.blpop("rq", 0.01) is None  # raw None reply on timeout
        c1.close()
        c2.close()

    def test_large_values_roundtrip_per_dialect(self, server):
        """>= OOB_THRESHOLD values ride the zero-copy pickle path from a
        raw client, transparently per command."""
        c = KVClient(server.address)
        for size in (ser.OOB_THRESHOLD - 1, ser.OOB_THRESHOLD,
                     ser.OOB_THRESHOLD + 1, 1 << 20):
            blob = bytes([size % 251]) * size
            c.set(f"sz:{size}", blob)
            assert c.get(f"sz:{size}") == blob
        c.close()

    def test_value_type_fidelity_over_wire(self, server):
        c = KVClient(server.address)
        for i, v in enumerate(ROUNDTRIP_VALUES):
            c.set(f"fid:{i}", v)
            got = c.get(f"fid:{i}")
            if isinstance(v, (bytes, bytearray)):
                assert bytes(got) == bytes(v)
            else:
                assert got == v and type(got) is type(v)
        c.set("fid:big", 1 << 100)
        assert c.get("fid:big") == 1 << 100
        c.close()


class TestLeaseInterop:
    """The PR 8 lease commands across every wire dialect: they ride the
    raw v4 fast path when codable and must interop with v1-v3 pickle
    clients observing the same lease state."""

    def test_lease_cycle_per_dialect(self, server):
        clients = _dialect_clients(server.address)
        try:
            for name, c in clients.items():
                q, fl = f"lq:{name}", f"lfl:{name}"
                c.rpush(q, (0, "t1", b"x"))
                assert c.blpop_lease(q, fl, f"w-{name}", 5.0, timeout=0) \
                    == (0, "t1", b"x")
                assert c.lease_renew(fl, "t1", 0, 5.0) is True
                assert c.lease_renew(fl, "t1", 9, 5.0) is False
                assert c.lease_release(fl, "t1", 0) is True
                assert c.blpop_lease(q, fl, "w", 5.0, timeout=0.01) is None
        finally:
            for c in clients.values():
                c.close()

    def test_lease_state_visible_across_dialects(self, server):
        """A v4 writer's lease is observed (and reaped) by a v1 reader:
        lease records and queue entries survive dialect boundaries."""
        clients = _dialect_clients(server.address)
        try:
            w, r = clients["v4"], clients["v1"]
            w.rpush("xq", (1, "tX", b"payload"))
            assert w.blpop_lease("xq", "xfl", "w4", 0.05, timeout=0) \
                == (1, "tX", b"payload")
            rec = r.hget("xfl", "tX")
            assert rec[1] == 1 and rec[2] == "w4" and rec[3] == b"payload"
            time.sleep(0.08)
            requeued, dead = r.lease_reap("xfl", "xq", 3)
            assert requeued == [("tX", 1)] and dead == []
            assert clients["v3"].lrange("xq", 0, -1) == [(2, "tX", b"payload")]
        finally:
            for c in clients.values():
                c.close()

    def test_blpop_lease_blocking_lane(self, server):
        """blpop_lease with a timeout parks on the server's blocking lane
        (not the fast dispatch table) and wakes on a push."""
        c1, c2 = KVClient(server.address), KVClient(server.address)
        out = []
        t = threading.Thread(target=lambda: out.append(
            c2.blpop_lease("bq", "bfl", "w1", 5.0, timeout=5)))
        t.start()
        time.sleep(0.05)
        c1.rpush("bq", (0, "tB", b"v"))
        t.join(3)
        assert out == [(0, "tB", b"v")]
        assert c1.hget("bfl", "tB")[2] == "w1"
        c1.close()
        c2.close()


class TestRawPipelines:
    def test_transactional_pipeline_is_one_eval(self, server):
        c = KVClient(server.address)
        before = server.store.metrics.commands.get("EVAL", 0)
        with c.pipeline() as p:
            a = p.rpush("l", b"1", b"2")
            b = p.llen("l")
            n = p.incr("n")
        assert a.get() == 2 and b.get() == 2 and n.get() == 1
        assert server.store.metrics.commands.get("EVAL", 0) == before + 1
        c.close()

    def test_pipeline_with_mixed_raw_and_fallback_commands(self, server):
        """A batch containing a non-raw entry falls back to pickle as a
        WHOLE frame and still executes transactionally."""
        c = KVClient(server.address)
        with c.pipeline() as p:
            p.incr("pm:n")
            p.hset("pm:h", "f", b"v")       # not in the raw vocabulary
            p.rpush("pm:big", b"x" * 8192)  # OOB-sized entry
            got = p.llen("pm:big")
        assert got.get() == 1
        assert c.hget("pm:h", "f") == b"v"
        c.close()

    def test_pipeline_with_execute_batch_entry_falls_back(self, server):
        """An execute_batch entry inside a pipeline batch must NOT be
        raw-encoded as EXEC-in-EXEC — the whole frame falls back to
        pickle and still runs (regression: the submit-time encoder used
        to bypass the nesting guard)."""
        c = KVClient(server.address)
        with c.pipeline() as p:
            p.set("nb:a", b"1")
            inner = p.execute_batch([("set", ("nb:b", b"2"), {}),
                                     ("incr", ("nb:n",), {})])
        assert [ok for ok, _ in inner.get()] == [True, True]
        assert c.get("nb:a") == b"1" and c.get("nb:b") == b"2"
        assert c.get("nb:n") == 1
        c.close()

    def test_error_mid_raw_batch(self, server):
        from repro.core.kvstore import PipelineError
        c = KVClient(server.address)
        c.set("eb:str", b"v")
        p = c.pipeline()
        p.incr("eb:n")
        p.rpush("eb:str", b"x")  # WRONGTYPE mid-batch
        p.incr("eb:n")
        with pytest.raises(PipelineError) as ei:
            p.execute()
        assert ei.value.index == 1
        assert c.get("eb:n") == 2  # both incrs ran (MULTI semantics)
        c.close()

    def test_nontransactional_pipeline_raw(self, server):
        c = KVClient(server.address)
        with c.pipeline(transactional=False) as p:
            a = p.rpush("nt:l", b"1")
            b = p.llen("nt:l")
        assert a.get() == 1 and b.get() == 1
        c.close()

    def test_concurrent_raw_singles_group_commit(self, server):
        """8 threads of raw singles multiplex one connection; results
        demux correctly (the merged frames are raw execute_batch)."""
        c = KVClient(server.address)
        errors = []

        def worker(i):
            try:
                for j in range(25):
                    assert c.incr(f"gc:{i}") == j + 1
            except BaseException as exc:
                errors.append(exc)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert all(c.get(f"gc:{i}") == 25 for i in range(8))
        c.close()


class TestDispatchTable:
    def test_table_covers_vocabulary(self, server):
        from repro.core.kvserver import _build_dispatch
        table = _build_dispatch(server.store)
        assert len(table) == len(ser.RAW_COMMANDS)
        for name, fn in zip(ser.RAW_COMMANDS, table):
            assert fn is not None and fn.__name__ == name

    def test_raw_exec_records_eval_and_inner_commands(self, server):
        c = KVClient(server.address)
        before = server.store.metrics.commands.get("EVAL", 0)
        with c.pipeline() as p:
            for i in range(5):
                p.incr(f"dt:{i}")
        assert server.store.metrics.commands.get("EVAL", 0) == before + 1
        assert server.store.metrics.commands.get("INCRBY", 0) >= 5
        c.close()

    def test_blocking_clamped_inside_raw_batch(self, server):
        """A blocking command inside a raw execute_batch must not park
        while the transaction holds every stripe."""
        c = KVClient(server.address)
        t0 = time.monotonic()
        with c.pipeline() as p:
            got = p.blpop("never:filled", 30)
        assert got.get() is None
        assert time.monotonic() - t0 < 5
        c.close()


# ---------------------------------------------------------------------------
# PR 6: the dialect grid crossed with the transport dimension — identical
# frames over tcp / uds / shm rings, mixed dialects on one ring
# ---------------------------------------------------------------------------


class TestInteropOverTransports:
    @pytest.mark.parametrize("transport", ["uds", "shm"])  # tcp: TestInterop
    def test_dialect_grid(self, server, transport):
        """Every (writer, reader) dialect pair agrees on store state when
        ALL of them ride the pinned carrier: framing is carrier-blind."""
        clients = _dialect_clients(server.endpoints, transport=transport)
        try:
            for wname, w in clients.items():
                w.set(f"g:{transport}:{wname}", wname.encode())
                w.incr(f"g:{transport}:n")
            for rname, r in clients.items():
                for wname in clients:
                    assert r.get(f"g:{transport}:{wname}") == wname.encode(), \
                        f"{rname} reading {wname} over {transport}"
            assert clients["v1"].get(f"g:{transport}:n") == len(clients)
        finally:
            for c in clients.values():
                c.close()

    def test_mixed_dialects_on_one_ring(self, server):
        """One shm ring carries raw v4 frames, pickle-fallback frames and
        OOB multi-part payloads interleaved — every frame self-describes,
        so the ring never desyncs."""
        c = KVClient(server.endpoints, transport="shm")
        assert c._mux("main").endpoint.scheme == "shm"
        big = b"r" * (1 << 20)
        for i in range(3):
            assert c.incr("ring:n") == i + 1          # raw v4
            c.hset("ring:h", f"f{i}", b"x")           # pickle fallback
            c.rpush("ring:big", big)                  # pickle + OOB parts
            assert c.lpop("ring:big") == big
        assert c.hgetall("ring:h") == {f"f{i}": b"x" for i in range(3)}
        c.close()

    @pytest.mark.parametrize("transport", ["uds", "shm"])
    def test_cross_transport_visibility(self, server, transport):
        """A write over one carrier is read back over another: transports
        are connection plumbing, the store is one."""
        w = KVClient(server.endpoints, transport=transport)
        r = KVClient(server.endpoints, transport="tcp")
        w.set("xt:k", b"via-" + transport.encode())
        assert r.get("xt:k") == b"via-" + transport.encode()
        w.close()
        r.close()

    @pytest.mark.parametrize("transport", ["uds", "shm"])
    def test_raw_error_reply_keeps_carrier_synced(self, server, transport):
        c = KVClient(server.endpoints, transport=transport)
        c.set("e:k", b"v")
        with pytest.raises(TypeError):
            c.rpush("e:k", b"x")
        assert c.incr("e:n") == 1    # connection still framed correctly
        c.close()


# ---------------------------------------------------------------------------
# PR 7: replication frames + redirect errors on the raw dialect
# ---------------------------------------------------------------------------


class TestReplicationCodec:
    def test_repl_apply_is_a_raw_command(self):
        assert "repl_apply" in ser.RAW_COMMANDS

    def test_repl_apply_entry_batch_roundtrips_raw(self):
        """The streamer's bread and butter: a chunk of log entries
        (cmd, args, kwargs-as-None) stays on the zero-pickle dialect."""
        entries = [
            ("set", ("k1", 7), None),
            ("rpush", ("q", b"payload"), None),
            ("lpop", ("q",), None),
            ("hset", ("h", "f", 3.25), None),
            ("delete", ("k1",), None),
        ]
        body = ser.encode_command("repl_apply", (42, entries), {})
        assert body is not None, "repl_apply chunk fell off the raw dialect"
        cmd, args, kwargs = ser.decode_command(body)
        assert cmd == "repl_apply" and kwargs == {}
        assert args[0] == 42
        assert [tuple(e) for e in args[1]] == entries

    def test_repl_apply_exotic_entries_fall_back_to_pickle(self):
        """Entries whose args the raw codec cannot carry (sets, custom
        types) must return None => the client transparently pickles."""
        entries = [("sadd", ("s", {"a", "b"}), None)]
        assert ser.encode_command("repl_apply", (1, entries), {}) is None

    def test_shard_redirect_error_roundtrips_raw(self):
        from repro.core.errors import ShardRedirectError
        exc = ShardRedirectError("replica cannot serve this command",
                                 epoch=9, shard=3)
        body = ser.encode_reply(False, exc)
        assert body is not None, "redirect fell off the raw dialect"
        ok, got = ser.decode_reply(body)
        assert ok is False
        assert isinstance(got, ShardRedirectError)
        assert got.epoch == 9 and got.shard == 3
        assert "replica cannot serve" in str(got)

    def test_shard_redirect_error_survives_pickle_dialect(self):
        """v1/v2 clients get the same typed error via pickle: __reduce__
        must preserve epoch/shard."""
        import pickle
        from repro.core.errors import ShardRedirectError, ShardUnavailableError
        r = pickle.loads(pickle.dumps(ShardRedirectError("m", epoch=4, shard=1)))
        assert isinstance(r, ShardRedirectError)
        assert r.epoch == 4 and r.shard == 1
        u = pickle.loads(pickle.dumps(
            ShardUnavailableError("m", shard=2, descriptor_version=7)))
        assert u.shard == 2 and u.descriptor_version == 7

    def test_live_redirect_over_raw_dialect(self):
        """A raw-dialect client talking to a replica-mode server gets the
        typed redirect end to end."""
        from repro.core.errors import ShardRedirectError
        from repro.core.kvstore import KVStore
        with KVServer(KVStore(name="rep"), replica=True, shard_index=5) as srv:
            c = KVClient(srv.endpoints, raw=True)
            with pytest.raises(ShardRedirectError) as ei:
                c.set("k", 1)
            assert ei.value.shard == 5
            assert c.get("k") is None  # reads still served
            c.close()
