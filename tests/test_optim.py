import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, dequantize_int8, quantize_int8,
                         wsd_schedule)


def _reference_adamw(params, grads, m, v, t, lr, b1, b2, eps, wd):
    """Textbook AdamW in numpy."""
    out_p, out_m, out_v = {}, {}, {}
    for k in params:
        g = grads[k]
        out_m[k] = b1 * m[k] + (1 - b1) * g
        out_v[k] = b2 * v[k] + (1 - b2) * g * g
        mhat = out_m[k] / (1 - b1 ** t)
        vhat = out_v[k] / (1 - b2 ** t)
        out_p[k] = params[k] - lr * (mhat / (np.sqrt(vhat) + eps)
                                     + wd * params[k])
    return out_p, out_m, out_v


class TestAdamW:
    def test_matches_reference_math(self):
        rng = np.random.default_rng(0)
        params = {"w": rng.standard_normal((4, 5)).astype(np.float32),
                  "b": rng.standard_normal(5).astype(np.float32)}
        grads = {k: rng.standard_normal(v.shape).astype(np.float32)
                 for k, v in params.items()}
        cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.95, eps=1e-8,
                          weight_decay=0.1, grad_clip_norm=None)
        jp = {k: jnp.asarray(v) for k, v in params.items()}
        state = adamw_init(cfg, jp)
        new_p, new_state, _ = adamw_update(
            cfg, {k: jnp.asarray(v) for k, v in grads.items()}, state, jp)
        ref_p, _, _ = _reference_adamw(
            params, grads,
            {k: np.zeros_like(v) for k, v in params.items()},
            {k: np.zeros_like(v) for k, v in params.items()},
            1, 1e-2, 0.9, 0.95, 1e-8, 0.1)
        for k in params:
            np.testing.assert_allclose(np.array(new_p[k]), ref_p[k],
                                       atol=1e-6, rtol=1e-6)

    def test_grad_clipping(self):
        cfg = AdamWConfig(lr=1.0, grad_clip_norm=1.0, weight_decay=0.0)
        p = {"w": jnp.zeros(4)}
        st = adamw_init(cfg, p)
        big = {"w": jnp.full(4, 100.0)}
        _, _, m = adamw_update(cfg, big, st, p)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    @pytest.mark.parametrize("sd", ["float32", "bfloat16", "int8"])
    def test_state_dtypes_train_similarly(self, sd):
        cfg = AdamWConfig(lr=0.1, state_dtype=sd, weight_decay=0.0,
                          grad_clip_norm=None)
        p = {"w": jnp.ones((8, 256))}
        st = adamw_init(cfg, p)
        target = jnp.zeros((8, 256))
        for _ in range(20):
            g = {"w": p["w"] - target}
            p, st, _ = adamw_update(cfg, g, st, p)
        # all precisions should have moved most of the way to the target
        assert float(jnp.abs(p["w"]).mean()) < 0.3

    def test_schedule_callable_lr(self):
        cfg = AdamWConfig(lr=lambda s: wsd_schedule(s, 1.0, 10, 100, 50))
        assert float(cfg.lr_at(0)) == pytest.approx(0.1)
        assert float(cfg.lr_at(50)) == pytest.approx(1.0)


class TestSchedules:
    def test_wsd_phases(self):
        lr = lambda s: float(wsd_schedule(s, 1.0, warmup_steps=10,  # noqa
                                          stable_steps=80, decay_steps=100))
        assert lr(0) == pytest.approx(0.1)
        assert lr(9) == pytest.approx(1.0)
        assert lr(50) == pytest.approx(1.0)      # stable plateau
        assert lr(89) == pytest.approx(1.0)
        assert 0.01 <= lr(140) < 1.0             # decaying
        assert lr(190) == pytest.approx(0.01, rel=0.01)

    def test_cosine(self):
        assert float(cosine_schedule(0, 1.0, 10, 100)) == pytest.approx(0.1)
        assert float(cosine_schedule(100, 1.0, 10, 100)) == pytest.approx(0.1, rel=0.05)


class TestQuant:
    def test_roundtrip_error(self):
        x = jnp.asarray(np.random.default_rng(1).standard_normal((7, 33)) * 5)
        back = dequantize_int8(quantize_int8(x))
        assert float(jnp.abs(back - x).max()) < 5 * 2 / 127 * 1.5

    def test_shapes_preserved(self):
        for shape in [(4,), (3, 5), (2, 3, 7)]:
            x = jnp.ones(shape)
            t = quantize_int8(x)
            assert t.q.shape == shape
            assert t.scale.shape == shape[:-1]
            assert dequantize_int8(t).shape == shape

    def test_pytree_registration(self):
        t = quantize_int8(jnp.ones((4, 8)))
        leaves = jax.tree.leaves(t)
        assert len(leaves) == 2
        t2 = jax.tree.map(lambda x: x, t)
        assert t2.shape == (4, 8)
