"""Behavioural tests for the full transparent multiprocessing API."""

import time

import pytest

from repro.core import mp


class TestPool:
    def test_map_order(self):
        with mp.Pool(4) as p:
            assert p.map(lambda x: x * 2, range(20)) == [x * 2 for x in range(20)]

    def test_starmap_apply(self):
        with mp.Pool(2) as p:
            assert p.starmap(lambda a, b: a - b, [(5, 3), (1, 1)]) == [2, 0]
            assert p.apply(lambda a: a + 1, (41,)) == 42
            r = p.apply_async(lambda: "x")
            assert r.get(5) == "x"
            assert r.successful()

    def test_imap(self):
        with mp.Pool(2) as p:
            assert list(p.imap(lambda x: x * x, range(6))) == [0, 1, 4, 9, 16, 25]
            assert sorted(p.imap_unordered(lambda x: x + 1, range(6))) == \
                [1, 2, 3, 4, 5, 6]

    def test_error_propagates(self):
        from repro.core.executor import RemoteError
        with mp.Pool(2) as p:
            res = p.map_async(lambda x: 1 // x, [1, 0, 2])
            with pytest.raises(RemoteError, match="ZeroDivisionError"):
                res.get(10)

    def test_initializer_runs_per_worker(self):
        from repro.core import get_session

        def init(tag):
            get_session().store.incr(f"{tag}:inits")

        with mp.Pool(3, initializer=init, initargs=("t",)) as p:
            p.map(lambda x: x, range(6))
            assert get_session().store.get("t:inits") == 3

    def test_single_lpush_submission(self):
        """Paper §3.1.2: a map is one batched submit, not per-task invokes."""
        from repro.core import get_session
        with mp.Pool(2) as p:
            before = get_session().store.metrics.commands.get("RPUSH", 0)
            p.map(lambda x: x, range(16), chunksize=4)
            pushes = get_session().store.metrics.commands.get("RPUSH", 0) - before
            # 1 job submit (4 chunks in one RPUSH) + 4 result pushes
            assert pushes <= 6

    def test_upload_func_content_addressed(self):
        """Repeated maps of the SAME function upload it once (grid
        search's loop); a different function uploads separately."""
        from repro.core import get_session

        def work(x):
            return x + 1

        storage = get_session().get_storage()
        with mp.Pool(2) as p:
            p.map(work, range(4))
            funcs_after_first = set(storage.list("pool/funcs/"))
            puts_after_first = storage.ops.get("PUT", 0)
            for _ in range(3):
                p.map(work, range(4))
            assert set(storage.list("pool/funcs/")) == funcs_after_first
            assert len(funcs_after_first) == 1
            # no further func PUTs (result traffic rides the KV store,
            # not object storage, so PUT counts are exactly func/init)
            assert storage.ops.get("PUT", 0) == puts_after_first
            p.map(lambda x: x * 2, range(4))
            assert len(storage.list("pool/funcs/")) == 2

    def test_empty_iterable_short_circuits(self):
        """map([]) resolves immediately: no upload, no job registered
        (a chunkless job would leak in self._jobs forever)."""
        from repro.core import get_session
        storage = get_session().get_storage()
        with mp.Pool(2) as p:
            assert p.map(lambda x: x, []) == []
            assert p.starmap(lambda a: a, []) == []
            assert list(p.imap(lambda x: x, [])) == []
            res = p.map_async(lambda x: x, [])
            assert res.get(1) == [] and res.successful()
            assert p._jobs == {}
            assert storage.list("pool/funcs/") == []

    def test_resize(self):
        p = mp.Pool(2)
        try:
            p.resize(5)
            time.sleep(0.2)
            assert p.n_workers == 5
            assert p.map(lambda x: x, range(10)) == list(range(10))
        finally:
            p.terminate()
            p.join(5)

    def test_callbacks(self):
        hits = []
        with mp.Pool(2) as p:
            r = p.map_async(lambda x: x, [1, 2], callback=hits.append)
            r.get(5)
            time.sleep(0.05)
        assert hits == [[1, 2]]


class TestProcess:
    def test_lifecycle(self):
        q = mp.Queue()
        pr = mp.Process(target=lambda q: q.put(21 * 2), args=(q,))
        assert pr.exitcode is None
        pr.start()
        pr.join(5)
        assert pr.exitcode == 0
        assert q.get(timeout=1) == 42

    def test_exitcode_on_error(self):
        pr = mp.Process(target=lambda: 1 / 0)
        pr.start()
        pr.join(5)
        assert pr.exitcode == 1

    def test_active_children_and_names(self):
        ev = mp.Event()
        pr = mp.Process(target=lambda ev: ev.wait(5), args=(ev,), name="w1")
        pr.start()
        assert pr.name == "w1"
        assert any(p.name == "w1" for p in mp.active_children())
        ev.set()
        pr.join(5)

    def test_current_process_in_child(self):
        q = mp.Queue()

        def child(q):
            q.put(mp.current_process().name)
        pr = mp.Process(target=child, args=(q,), name="childX")
        pr.start()
        pr.join(5)
        assert q.get(timeout=1) == "childX"


class TestQueuesAndPipes:
    def test_fifo_across_processes(self):
        q = mp.Queue()
        done = mp.Queue()

        def producer(q, done):
            for i in range(20):
                q.put(i)
            done.put("ok")
        pr = mp.Process(target=producer, args=(q, done))
        pr.start()
        assert done.get(timeout=5) == "ok"
        assert [q.get(timeout=1) for _ in range(20)] == list(range(20))
        pr.join()

    def test_bounded_queue_blocks(self):
        q = mp.Queue(maxsize=2)
        q.put(1)
        q.put(2)
        with pytest.raises(mp.Full):
            q.put_nowait(3)
        assert q.full()
        assert q.get() == 1
        q.put_nowait(3)

    def test_get_nowait_empty(self):
        q = mp.Queue()
        with pytest.raises(mp.Empty):
            q.get_nowait()

    def test_joinable_queue(self):
        q = mp.JoinableQueue()

        def consumer(q):
            while True:
                item = q.get()
                q.task_done()
                if item is None:
                    return
        pr = mp.Process(target=consumer, args=(q,))
        pr.start()
        for i in range(5):
            q.put(i)
        q.put(None)
        q.join(timeout=10)
        pr.join(5)

    def test_pipe_duplex(self):
        a, b = mp.Pipe()

        def echo(conn):
            conn.send(conn.recv() * 3)
        pr = mp.Process(target=echo, args=(b,))
        pr.start()
        a.send("ab")
        assert a.recv() == "ababab"
        pr.join(5)

    def test_pipe_simplex(self):
        r, w = mp.Pipe(duplex=False)
        w.send(1)
        assert r.recv() == 1
        with pytest.raises(OSError):
            r.send(2)
        with pytest.raises(OSError):
            w.recv_bytes(0.01)

    def test_pipe_poll(self):
        a, b = mp.Pipe()
        assert not a.poll()
        b.send(1)
        assert a.poll(1.0)

    def test_pipe_poll_timeout_blocks_not_spins(self):
        from repro.core import get_session
        a, b = mp.Pipe()
        store = get_session().store
        before = store.metrics.total_commands()
        t0 = time.monotonic()
        assert not a.poll(0.1)
        assert time.monotonic() - t0 >= 0.09
        # one blocking BLLEN, not an llen-every-2ms busy loop
        assert store.metrics.total_commands() - before == 1

    def test_bounded_queue_put_get_two_commands(self):
        """Acceptance: bounded put+get = 2 KV commands (was 4: the token
        BLPOP and payload RPUSH are fused into one BLPOPRPUSH each way)."""
        from repro.core import get_session
        q = mp.Queue(maxsize=2)
        store = get_session().store
        base = store.metrics.total_commands()
        q.put("item")
        mid = store.metrics.total_commands()
        assert mid - base == 1
        assert q.get() == "item"
        assert store.metrics.total_commands() - mid == 1
        assert store.metrics.commands.get("BLPOPRPUSH") == 2


class TestSync:
    def test_lock_mutual_exclusion(self):
        lock = mp.Lock()
        val = mp.Value("i", 0, lock=False)

        def bump(lock, val):
            for _ in range(30):
                with lock:
                    val.value += 1
        ps = [mp.Process(target=bump, args=(lock, val)) for _ in range(3)]
        [p.start() for p in ps]
        [p.join(20) for p in ps]
        assert val.value == 90

    def test_rlock_reentrant(self):
        rl = mp.RLock()
        assert rl.acquire()
        assert rl.acquire()
        rl.release()
        rl.release()
        assert rl.acquire(block=False)
        rl.release()

    def test_semaphore_counts(self):
        sem = mp.Semaphore(2)
        assert sem.acquire(block=False)
        assert sem.acquire(block=False)
        assert not sem.acquire(block=False)
        sem.release()
        assert sem.acquire(block=False)

    def test_bounded_semaphore_over_release(self):
        bs = mp.BoundedSemaphore(1)
        with pytest.raises(ValueError):
            bs.release()

    def test_event_broadcast(self):
        ev = mp.Event()
        q = mp.Queue()

        def waiter(ev, q, i):
            ev.wait()
            q.put(i)
        ps = [mp.Process(target=waiter, args=(ev, q, i)) for i in range(3)]
        [p.start() for p in ps]
        time.sleep(0.1)
        assert q.qsize() == 0
        ev.set()
        [p.join(10) for p in ps]
        assert sorted(q.get(timeout=1) for _ in range(3)) == [0, 1, 2]

    def test_event_set_before_wait(self):
        ev = mp.Event()
        ev.set()
        assert ev.wait(0.1)
        ev.clear()
        assert not ev.wait(0.05)

    def test_barrier(self):
        bar = mp.Barrier(3)
        q = mp.Queue()

        def arrive(bar, q, i):
            q.put(("before", i))
            bar.wait()
            q.put(("after", i))
        ps = [mp.Process(target=arrive, args=(bar, q, i)) for i in range(3)]
        [p.start() for p in ps]
        [p.join(10) for p in ps]
        events = [q.get(timeout=1) for _ in range(6)]
        assert [e[0] for e in events[:3]] == ["before"] * 3
        assert [e[0] for e in events[3:]] == ["after"] * 3

    def test_barrier_timeout_breaks(self):
        bar = mp.Barrier(2)
        with pytest.raises(mp.BrokenBarrierError):
            bar.wait(timeout=0.05)
        assert bar.broken

    def test_condition_notify(self):
        cond = mp.Condition()
        q = mp.Queue()

        def waiter(cond, q):
            with cond:
                cond.wait(5)
            q.put("woke")
        pr = mp.Process(target=waiter, args=(cond, q))
        pr.start()
        time.sleep(0.15)
        with cond:
            cond.notify()
        assert q.get(timeout=5) == "woke"
        pr.join(5)


class TestSharedCtypes:
    def test_value_types(self):
        v = mp.Value("d", 1.5)
        assert v.value == 1.5
        v.value = 2.5
        assert v.value == 2.5
        i = mp.Value("i", 7)
        i.value += 1
        assert i.value == 8

    def test_array_slices(self):
        arr = mp.Array("i", range(10))
        assert arr[3] == 3
        assert arr[2:5] == [2, 3, 4]
        arr[0] = 99
        arr[5:8] = [50, 60, 70]
        assert arr[:] == [99, 1, 2, 3, 4, 50, 60, 70, 8, 9]
        assert len(arr) == 10

    def test_array_across_processes(self):
        arr = mp.Array("d", [0.0] * 6)

        def fill(arr, lo, hi):
            for i in range(lo, hi):
                arr[i] = float(i * i)
        ps = [mp.Process(target=fill, args=(arr, 0, 3)),
              mp.Process(target=fill, args=(arr, 3, 6))]
        [p.start() for p in ps]
        [p.join(10) for p in ps]
        assert arr[:] == [float(i * i) for i in range(6)]

    def test_get_lock(self):
        arr = mp.Array("i", 3)
        with arr.get_lock():
            arr[0] = 1
        raw = mp.RawArray("i", 3)
        with pytest.raises(AttributeError):
            raw.get_lock()


class TestManager:
    def test_dict_list_namespace(self):
        m = mp.Manager()
        d = m.dict()
        l = m.list([1])
        ns = m.Namespace(x=0)

        def child(d, l, ns):
            d["k"] = {"nested": [1, 2]}
            d[("tuple", "key")] = 3
            l.append(2)
            l[0] = 10
            ns.x = "done"
        pr = mp.Process(target=child, args=(d, l, ns))
        pr.start()
        pr.join(10)
        assert d["k"] == {"nested": [1, 2]}
        assert d[("tuple", "key")] == 3
        assert list(l) == [10, 2]
        assert ns.x == "done"

    def test_dict_methods(self):
        m = mp.Manager()
        d = m.dict({"a": 1})
        d.update({"b": 2}, c=3)
        assert len(d) == 3
        assert sorted(d.keys()) == ["a", "b", "c"]
        assert d.pop("a") == 1
        assert d.get("missing", 9) == 9
        assert d.setdefault("z", 5) == 5
        assert "z" in d
        assert d.copy() == {"b": 2, "c": 3, "z": 5}

    def test_registered_class_rmi(self):
        class Counter:
            def __init__(self, start=0):
                self.n = start

            def inc(self, k=1):
                self.n += k
                return self.n

        m = mp.Manager()
        m.register("Counter", Counter)
        c = m.Counter(10)

        def child(c):
            for _ in range(5):
                c.inc(2)
        ps = [mp.Process(target=child, args=(c,)) for _ in range(2)]
        [p.start() for p in ps]
        [p.join(10) for p in ps]
        assert c.n == 30


class TestRefcounting:
    def test_queue_deleted_at_zero_refs(self):
        from repro.core import get_session
        q = mp.Queue()
        q.put(1)
        uid = q.uid
        store = get_session().store
        assert store.exists("{" + uid + "}:items")
        q.close()
        assert not store.exists("{" + uid + "}:items")
        assert not store.exists("{" + uid + "}:refs")

    def test_child_reference_keeps_alive(self):
        from repro.core import serialization, get_session
        q = mp.Queue()
        blob = serialization.dumps(q)  # simulates passing to a child
        store = get_session().store
        q.close()
        assert store.exists("{" + q.uid + "}:refs")  # child ref remains
        q2 = serialization.loads(blob)
        q2.put(5)
        assert q2.get(timeout=1) == 5
        q2.close()
        assert not store.exists("{" + q.uid + "}:refs")

    def test_ttl_backstop_set(self):
        from repro.core import get_session
        q = mp.Queue()
        ttl = get_session().store.ttl("{" + q.uid + "}:refs")
        assert 0 < ttl <= 3600
