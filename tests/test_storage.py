import pytest

from repro.core import ObjectStore
from repro.core import storage as st


class TestObjectStore:
    def test_put_get_head_delete(self):
        s = ObjectStore()
        s.put("a/b", b"data")
        assert s.get("a/b") == b"data"
        assert s.head("a/b") == 4
        assert s.exists("a/b")
        assert s.list("a/") == ["a/b"]
        assert s.delete("a/b") == 1
        with pytest.raises(KeyError):
            s.get("a/b")

    def test_immutable_semantics(self):
        s = ObjectStore()
        s.put("k", b"v1")
        s.put("k", b"v2")  # whole-object overwrite
        assert s.get("k") == b"v2"


class TestFileFacade:
    def test_write_read_text(self):
        with st.open("dir/file.txt", "w") as f:
            f.write("hello ")
            f.write("world")
        with st.open("dir/file.txt") as f:
            assert f.read() == "hello world"

    def test_binary_and_seek(self):
        with st.open("b.bin", "wb") as f:
            f.write(b"0123456789")
        with st.open("b.bin", "rb") as f:
            f.seek(5)
            assert f.read(3) == b"567"
            assert f.tell() == 8

    def test_append_rewrites(self):
        with st.open("log", "w") as f:
            f.write("a\n")
        with st.open("log", "a") as f:
            f.write("b\n")
        with st.open("log") as f:
            assert list(f) == ["a\n", "b\n"]

    def test_missing_file(self):
        with pytest.raises(FileNotFoundError):
            st.open("nope")

    def test_exclusive_create(self):
        with st.open("x", "x") as f:
            f.write("1")
        with pytest.raises(FileExistsError):
            st.open("x", "x")

    def test_path_module(self):
        with st.open("a/b/c.txt", "w") as f:
            f.write("z")
        assert st.path.exists("a/b/c.txt")
        assert st.path.isfile("a/b/c.txt")
        assert st.path.isdir("a/b")
        assert st.path.getsize("a/b/c.txt") == 1
        assert st.path.join("a", "b/", "c") == "a/b/c"
        assert st.path.basename("a/b/c.txt") == "c.txt"
        assert st.path.dirname("a/b/c.txt") == "a/b"
        assert st.listdir("a") == ["b"]
        st.remove("a/b/c.txt")
        assert not st.path.exists("a/b/c.txt")


class TestKVObjectStore:
    def test_backed_by_kv(self):
        from repro.core import KVObjectStore
        from repro.core.kvstore import KVStore
        kv = KVStore()
        s = KVObjectStore(kv)
        s.put("k1", b"v1")
        s.put("dir/k2", b"v2")
        assert s.get("k1") == b"v1"
        assert s.head("dir/k2") == 2
        assert s.list("dir/") == ["dir/k2"]
        assert s.delete("k1") == 1
        assert not s.exists("k1")
