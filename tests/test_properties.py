"""Property-based tests (hypothesis) for system invariants."""

import threading

from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import mp, serialization
from repro.core.kvstore import KVStore, ShardedKVStore

FAST = settings(max_examples=25, deadline=None,
                suppress_health_check=[HealthCheck.function_scoped_fixture,
                                       HealthCheck.too_slow])


# --------------------------------------------------------------- KV model


@FAST
@given(ops=st.lists(st.one_of(
    st.tuples(st.just("rpush"), st.binary(max_size=8)),
    st.tuples(st.just("lpush"), st.binary(max_size=8)),
    st.tuples(st.just("lpop"), st.none()),
    st.tuples(st.just("rpop"), st.none()),
), max_size=60))
def test_list_matches_python_model(ops):
    kv = KVStore()
    model = []
    for op, arg in ops:
        if op == "rpush":
            kv.rpush("k", arg)
            model.append(arg)
        elif op == "lpush":
            kv.lpush("k", arg)
            model.insert(0, arg)
        elif op == "lpop":
            assert kv.lpop("k") == (model.pop(0) if model else None)
        elif op == "rpop":
            assert kv.rpop("k") == (model.pop() if model else None)
    assert kv.lrange("k", 0, -1) == model


@FAST
@given(items=st.lists(st.binary(max_size=16), max_size=40),
       shards=st.integers(1, 5))
def test_sharded_store_equivalent_to_single(items, shards):
    sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(shards)])
    for i, b in enumerate(items):
        sh.set(f"k{i}", b)
    for i, b in enumerate(items):
        assert sh.get(f"k{i}") == b


# ------------------------------------------------------------ queue FIFO


@FAST
@given(items=st.lists(st.integers(), min_size=1, max_size=30))
def test_queue_fifo_single_consumer(items):
    q = mp.Queue()
    for x in items:
        q.put(x)
    assert [q.get(timeout=1) for _ in items] == items
    q.close()


@FAST
@given(items=st.lists(st.integers(), min_size=1, max_size=20),
       n_consumers=st.integers(1, 4))
def test_queue_multiconsumer_partition(items, n_consumers):
    """Every item delivered exactly once across concurrent consumers."""
    q = mp.Queue()
    got, lock = [], threading.Lock()

    def consume():
        while True:
            try:
                v = q.get(timeout=0.2)
            except mp.Empty:
                return
            with lock:
                got.append(v)

    for x in items:
        q.put(x)
    ts = [threading.Thread(target=consume) for _ in range(n_consumers)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert sorted(got) == sorted(items)
    q.close()


# ------------------------------------------------- semaphore invariant


@FAST
@given(value=st.integers(1, 4), n_threads=st.integers(2, 6))
def test_semaphore_never_exceeds_capacity(value, n_threads):
    sem = mp.Semaphore(value)
    active = [0]
    peak = [0]
    lock = threading.Lock()

    def worker():
        for _ in range(5):
            with sem:
                with lock:
                    active[0] += 1
                    peak[0] = max(peak[0], active[0])
                with lock:
                    active[0] -= 1

    ts = [threading.Thread(target=worker) for _ in range(n_threads)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert peak[0] <= value
    assert sem.get_value() == value


# ------------------------------------------------------ manager vs dict


@FAST
@given(ops=st.lists(st.tuples(
    st.sampled_from(["set", "del", "update"]),
    st.integers(0, 5), st.integers(-100, 100)), max_size=30))
def test_manager_dict_matches_dict(ops):
    m = mp.Manager()
    d = m.dict()
    model = {}
    for op, k, v in ops:
        if op == "set":
            d[k] = v
            model[k] = v
        elif op == "del":
            if k in model:
                del d[k]
                del model[k]
        elif op == "update":
            d.update({k: v, "fixed": op})
            model.update({k: v, "fixed": op})
    assert d.copy() == model
    assert len(d) == len(model)
    assert sorted(map(repr, d.keys())) == sorted(map(repr, model.keys()))


# ------------------------------------------------- serialization roundtrip


@FAST
@given(obj=st.recursive(
    st.one_of(st.integers(), st.floats(allow_nan=False), st.text(max_size=8),
              st.binary(max_size=8), st.booleans(), st.none()),
    lambda children: st.one_of(
        st.lists(children, max_size=4),
        st.dictionaries(st.text(max_size=4), children, max_size=4),
        st.tuples(children, children)),
    max_leaves=12))
def test_serialization_roundtrip(obj):
    assert serialization.loads(serialization.dumps(obj)) == obj


@FAST
@given(a=st.integers(-1000, 1000), b=st.integers(-1000, 1000))
def test_closure_roundtrip(a, b):
    def make(x):
        def inner(y):
            return x + y + b
        return inner
    fn = serialization.loads(serialization.dumps(make(a)))
    assert fn(10) == a + 10 + b


# ------------------------------------------------------- shared Array


@FAST
@given(values=st.lists(st.integers(-2**31, 2**31 - 1), min_size=1,
                       max_size=24))
def test_array_roundtrip_and_slices(values):
    arr = mp.Array("q", values)
    assert arr[:] == values
    assert arr[::2] == values[::2]
    rev = list(reversed(values))
    arr[:] = rev
    assert arr.tolist() == rev
