"""Multi-process sharded serving plane: bootstrap, routing, scatter/gather
pipelining, cross-shard blocking, supervision, and IPC transparency."""

import threading
import time

import pytest

from repro.core import Session, mp, set_session
from repro.core.kvcluster import (DESCRIPTOR_KEY, ClusterClient, KVCluster,
                                  connect)
from repro.core.kvserver import KVClient, KVServer


@pytest.fixture(scope="module")
def cluster():
    with KVCluster(shards=2) as cl:
        yield cl


@pytest.fixture
def client(cluster):
    c = cluster.client()
    c.flushall()
    yield c
    c.close()


def _cross_shard_keys(client):
    """Two keys guaranteed to live on different shards."""
    base = "{x}:q"
    other = next(k for k in (f"{{y{i}}}:q" for i in range(50))
                 if client.shard_for(k) is not client.shard_for(base))
    return base, other


class TestBootstrap:
    def test_descriptor_served_on_control_port(self, cluster):
        boot = KVClient(cluster.address)
        desc = boot.get(DESCRIPTOR_KEY)
        boot.close()
        assert desc["n_shards"] == 2
        assert [tuple(a) for a in desc["shards"]] == cluster.shard_addresses
        assert desc["hash"] == "fnv1a-hashtag"

    def test_cluster_client_bootstraps_from_one_address(self, cluster):
        c = ClusterClient(cluster.address)
        assert len(c.shards) == 2
        c.set("k", b"v")
        assert c.get("k") == b"v"
        c.close()

    def test_connect_autodetects_cluster_vs_plain_server(self, cluster):
        c = connect(cluster.address)
        assert isinstance(c, ClusterClient)
        c.close()
        with KVServer() as srv:
            c = connect(srv.address)
            assert isinstance(c, KVClient)
            c.close()

    def test_plain_server_rejected_as_control_endpoint(self):
        with KVServer() as srv:
            with pytest.raises(ConnectionError):
                ClusterClient(srv.address)


class TestRouting:
    def test_keys_spread_over_shards(self, client):
        for i in range(40):
            client.set(f"key-{i}", i)
        assert [client.get(f"key-{i}") for i in range(40)] == list(range(40))
        assert all(info["dbsize"] > 0 for info in client.info())

    def test_hash_tags_colocate(self, client):
        assert client.shard_for("{u1}:a") is client.shard_for("{u1}:b")

    def test_routing_matches_sharded_kvstore(self, cluster, client):
        """Client-side hash == ShardedKVStore hash: block-array segment
        keys land where the in-process router would put them."""
        from repro.core.kvstore import ShardedKVStore, KVStore
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(2)])
        for key in [f"{{res-{i}}}:seg:{j}" for i in range(10) for j in (0, 1)]:
            assert (client.shards.index(client.shard_for(key))
                    == sh.shards.index(sh.shard_for(key)))

    def test_multi_key_commands_split_per_shard(self, client):
        client.mset({f"m{i}": i for i in range(20)})
        assert client.mget([f"m{i}" for i in range(20)]) == list(range(20))
        assert client.delete(*[f"m{i}" for i in range(20)]) == 20
        assert client.mget(["m0", "m1"]) == [None, None]

    def test_byte_ranges_over_cluster(self, client):
        assert client.setrange("s", 0, b"Hello World") == 11
        assert client.getrange("s", 6, -1) == b"World"
        client.msetrange([("{t}:a", 0, b"xx"), ("{t}:b", 1, b"yy")])
        assert client.get("{t}:a") == b"xx"
        assert client.strlen("{t}:b") == 3


class TestScatterGather:
    def test_pipeline_scatters_one_batch_per_shard(self, client):
        evals_before = [i["commands"].get("EVAL", 0) for i in client.info()]
        with client.pipeline() as p:
            futs = [p.incr(f"n{i}") for i in range(16)]
        assert [f.get() for f in futs] == [1] * 16
        evals_after = [i["commands"].get("EVAL", 0) for i in client.info()]
        # one execute_batch per shard, concurrently flushed
        assert [a - b for a, b in zip(evals_after, evals_before)] == [1, 1]

    def test_pipeline_results_in_submission_order(self, client):
        with client.pipeline() as p:
            futs = [p.set(f"o{i}", i) for i in range(8)]
            gets = [p.get(f"o{i}") for i in range(8)]
        assert [g.get() for g in gets] == list(range(8))
        assert all(f.get() for f in futs)

    def test_error_mid_scatter_does_not_desync(self, client):
        from repro.core.kvstore import PipelineError, WrongTypeError
        client.set("str", b"v")
        p = client.pipeline()
        first = p.incr("n")
        bad = p.rpush("str", b"x")  # WRONGTYPE on whichever shard owns it
        last = p.incr("n")
        with pytest.raises(PipelineError):
            p.execute()
        assert first.get() == 1 and last.get() == 2
        with pytest.raises(WrongTypeError):
            bad.get()
        # every shard connection drained: follow-up traffic is in sync
        assert client.incr("n") == 3
        assert client.get("str") == b"v"

    def test_large_payload_scatter(self, client):
        blob = b"z" * (1 << 20)
        with client.pipeline() as p:
            for i in range(4):
                p.rpush(f"blob{i}", blob)
        for i in range(4):
            assert bytes(client.lpop(f"blob{i}")) == blob


class TestBlocking:
    def test_cross_shard_blpop_wakeup(self, client, cluster):
        k1, k2 = _cross_shard_keys(client)
        for waker in (k2, k1):  # wake via each shard in turn
            out = []
            t = threading.Thread(
                target=lambda: out.append(client.blpop([k1, k2], 5)))
            t.start()
            time.sleep(0.05)
            helper = cluster.client()
            helper.rpush(waker, b"m")
            t.join(5)
            helper.close()
            assert out == [(waker, bytes(b"m"))]

    def test_same_shard_blpop_blocks_server_side(self, client, cluster):
        out = []
        t = threading.Thread(target=lambda: out.append(client.blpop("q", 5)))
        t.start()
        time.sleep(0.05)
        helper = cluster.client()
        helper.rpush("q", b"msg")
        t.join(5)
        helper.close()
        assert out == [("q", b"msg")]

    def test_fused_blpop_rpush_single_command_when_tagged(self, client):
        client.rpush("{b}:slots", b"s")
        assert client.blpop_rpush("{b}:slots", "{b}:items", b"x", 1) == b"s"
        assert client.lrange("{b}:items", 0, -1) == [b"x"]

    def test_cross_shard_blpop_rpush_fallback(self, client):
        src, dst = _cross_shard_keys(client)
        client.rpush(src, b"item")
        assert client.blpop_rpush(src, dst, b"tok", 1) == b"item"
        assert client.lrange(dst, 0, -1) == [b"tok"]


class TestTransparencyOverCluster:
    """The acceptance claim: every IPC primitive runs unchanged when the
    session store is a ClusterClient instead of a KVServer connection."""

    @pytest.fixture(autouse=True)
    def cluster_session(self, cluster, client):
        set_session(Session(store=client))
        yield

    def test_bounded_queue(self):
        q = mp.Queue(maxsize=2)
        q.put("a")
        q.put("b")
        assert q.full()
        assert q.get(timeout=5) == "a"
        assert q.get(timeout=5) == "b"

    def test_lock_value_process(self):
        lock = mp.Lock()
        v = mp.Value("i", 0)
        q = mp.Queue()

        def child(q, lock, v):
            with lock:
                v.value += 5
            q.put("done")
        pr = mp.Process(target=child, args=(q, lock, v))
        pr.start()
        assert q.get(timeout=10) == "done"
        pr.join(10)
        assert v.value == 5

    def test_pool_job_queue(self):
        with mp.Pool(4) as pool:
            assert pool.map(lambda x: x * x, range(12)) == [x * x
                                                            for x in range(12)]

    def test_joinable_queue_transaction_over_wire(self):
        jq = mp.JoinableQueue()
        jq.put(1)
        assert jq.get(timeout=5) == 1
        jq.task_done()
        jq.join(5)

    def test_block_array_lock_scoped_cache(self):
        arr = mp.Array("d", [0.0] * 700)  # spans 2 segments, hash-tagged
        with arr.get_lock():
            for i in range(700):
                arr[i] = float(i)
            total = sum(arr[i] for i in range(700))
        assert total == sum(range(700))
        assert arr[100:105] == [100.0, 101.0, 102.0, 103.0, 104.0]

    def test_pipe_send_recv_poll(self):
        a, b = mp.Pipe()
        a.send({"x": [1, 2]})
        assert b.recv() == {"x": [1, 2]}
        assert b.poll(0.01) is False

    def test_manager_dict_list(self):
        from repro.core.managers import Manager
        m = Manager()
        d = m.dict({"a": 1})
        lst = m.list([1, 2])
        d["b"] = 2
        lst.append(3)
        assert dict(d) == {"a": 1, "b": 2}
        assert list(lst) == [1, 2, 3]
        m.shutdown()


class TestSupervision:
    def test_poll_restart_and_reuse(self):
        with KVCluster(shards=1) as cl:
            assert cl.poll() == [True]
            cl.ensure_alive()
            c = cl.client()
            c.set("k", b"v")
            cl._procs[0].proc.kill()
            cl._procs[0].proc.wait()
            assert cl.poll() == [False]
            with pytest.raises(RuntimeError, match="shard 0 exited"):
                cl.ensure_alive()
            # explicit respawn on a FRESH ephemeral port (no EADDRINUSE
            # race against the dead child's lingering socket); the control
            # endpoint republishes the descriptor, so a re-bootstrap sees
            # the new address; the partition restarts empty (documented
            # data loss)
            new_addr = cl.restart_shard(0)
            assert cl.poll() == [True]
            assert cl.shard_addresses == [new_addr]
            boot = KVClient(cl.address)
            desc = boot.get(DESCRIPTOR_KEY)
            boot.close()
            assert [tuple(a) for a in desc["shards"]] == [new_addr]
            c2 = cl.client()
            assert c2.get("k") is None
            c2.set("k", b"w")
            assert c2.get("k") == b"w"
            c.close()
            c2.close()

    def test_failed_spawn_raises_with_diagnostics(self):
        cl = KVCluster(shards=1, host="256.0.0.1")  # unbindable address
        with pytest.raises(Exception):
            cl.start()
        cl.stop()

    def test_shards_die_with_supervisor(self):
        cl = KVCluster(shards=1).start()
        proc = cl._procs[0].proc
        cl.stop()
        assert proc.poll() is not None  # no orphan shard processes


class TestLeaseSweep:
    """Cluster-side lease reaping (PR 8): even if the Pool's own process
    dies, leases registered in ``LEASE_REGISTRY_KEY`` are swept back to
    their job queues by the cluster supervisor."""

    def test_sweep_requeues_expired_registered_leases(self):
        from repro.core.kvstore import LEASE_REGISTRY_KEY
        with KVCluster(shards=2, lease_sweep_s=0.2) as cl:
            c = cl.client()
            try:
                # a pool-shaped layout: hash-tagged queue + in-flight hash,
                # registered exactly the way Pool.__init__ does it
                c.hset(LEASE_REGISTRY_KEY, "{p1}:inflight",
                       ("{p1}:jobs", 3, "{p1}:dead"))
                c.rpush("{p1}:jobs", (0, "j0.0", b"x"))
                assert c.blpop_lease("{p1}:jobs", "{p1}:inflight",
                                     "w1", 0.1, timeout=0) == (0, "j0.0", b"x")
                # the orphaned lease expires; the sweep thread (no client
                # involvement) must requeue it with a bumped attempt
                deadline = time.monotonic() + 10
                entry = None
                while time.monotonic() < deadline:
                    got = c.lrange("{p1}:jobs", 0, -1)
                    if got:
                        entry = got[0]
                        break
                    time.sleep(0.05)
                assert entry == (1, "j0.0", b"x")
                assert c.hget("{p1}:inflight", "j0.0") is None
                # the registration survives the sweep (only the pool
                # unregisters itself on close/join)
                assert c.hlen(LEASE_REGISTRY_KEY) == 1
            finally:
                c.close()

    def test_sweep_once_counts_and_dead_letters(self):
        from repro.core.kvstore import LEASE_REGISTRY_KEY
        with KVCluster(shards=1) as cl:  # sweep thread off: drive by hand
            c = cl.client()
            try:
                c.hset(LEASE_REGISTRY_KEY, "{p}:inflight",
                       ("{p}:jobs", 0, "{p}:dead"))  # max_attempts=0
                c.rpush("{p}:jobs", (0, "t", b"x"))
                c.blpop_lease("{p}:jobs", "{p}:inflight", "w", 0.05,
                              timeout=0)
                time.sleep(0.08)
                assert cl.lease_sweep_once() == 1
                assert c.lrange("{p}:dead", 0, -1) == [("t", 0, "w", b"x")]
                assert cl.lease_sweep_once() == 0  # idempotent when clean
            finally:
                c.close()


@pytest.mark.slow
class TestSubprocessWorkerOverCluster:
    def test_worker_bootstraps_from_control_address(self, cluster):
        """A real OS-process worker reaches the whole cluster through the
        ONE control address in REPRO_KV_ADDR (worker_main -> connect)."""
        from repro.core.executor import FunctionExecutor
        from repro.core.storage import KVObjectStore
        client = cluster.client()
        set_session(Session(store=client,
                            storage=KVObjectStore(client),
                            kv_address=cluster.address))
        ex = FunctionExecutor(backend="subprocess")
        assert ex.call_async(lambda a, b: a * b, (6, 7)).result(90) == 42
        ex.shutdown(wait=False)
        client.close()


class TestScatterOverMux:
    """PR 4: scatter flushes are mux submissions — concurrent threads'
    per-shard batches group-commit, co-resident shards share one frame,
    and the per-thread-socket transport stays available for A/B."""

    def test_concurrent_scatters_group_commit(self, cluster):
        """4 threads scattering pipelines through ONE ClusterClient: all
        results correct, over exactly one main-lane connection per shard
        (not one per thread per shard)."""
        client = cluster.client()
        client.flushall()
        errors = []

        def run(ti):
            try:
                for r in range(10):
                    with client.pipeline() as p:
                        futs = [p.incr(f"gcs:{ti}:{j}") for j in range(16)]
                    assert [f.get() for f in futs] == [r + 1] * 16
            except Exception as exc:  # pragma: no cover - failure detail
                errors.append((ti, exc))

        threads = [threading.Thread(target=run, args=(i,)) for i in range(4)]
        [t.start() for t in threads]
        [t.join(60) for t in threads]
        assert errors == []
        # one shared main-lane mux per shard client, regardless of threads
        for shard in client._clients:
            assert set(shard._muxes) == {"main"}
        client.close()

    def test_coresident_shards_coalesce_to_one_frame(self):
        """Two 'shards' at the SAME address share one client/connection,
        and a batch scattering across both lands as ONE wire frame (one
        server-side EVAL), not two."""
        with KVServer() as srv:
            client = ClusterClient(shard_addresses=[srv.address, srv.address])
            assert client._clients[0] is client._clients[1]
            # find keys routing to each shard index
            k0 = next(f"a{i}" for i in range(100)
                      if client._hash(f"a{i}") % 2 == 0)
            k1 = next(f"b{i}" for i in range(100)
                      if client._hash(f"b{i}") % 2 == 1)
            before = srv.store.metrics.commands.get("EVAL", 0)
            with client.pipeline() as p:
                f0 = p.incr(k0)
                f1 = p.incr(k1)
            assert f0.get() == 1 and f1.get() == 1
            assert srv.store.metrics.commands.get("EVAL", 0) - before == 1
            client.close()

    def test_per_thread_socket_transport_still_works(self, cluster):
        """mux=False keeps the PR 3 scatter (one socket per thread per
        shard) — the benchmark baseline must stay a working transport."""
        client = cluster.client(mux=False)
        client.flushall()
        assert all(not s.mux_enabled for s in client.shards)
        with client.pipeline() as p:
            futs = [p.incr(f"pts:{i}") for i in range(16)]
        assert [f.get() for f in futs] == [1] * 16
        assert client.blpop_rpush("{pt}:a", "{pt}:b", b"x", 0) is None
        client.close()

    def test_mux_and_socket_clients_interop(self, cluster):
        """Both transports against the same cluster see the same data."""
        muxed = cluster.client()
        plain = cluster.client(mux=False)
        muxed.flushall()
        muxed.set("interop", b"via-mux")
        assert plain.get("interop") == b"via-mux"
        plain.set("interop", b"via-socket")
        assert muxed.get("interop") == b"via-socket"
        muxed.close()
        plain.close()


class TestBatchOrdering:
    def test_pipeline_reads_its_own_writes_across_router_commands(self, client):
        """Multi-key commands (mget/mset) inside a pipeline observe the
        batch's earlier single-key writes — shard groups flush before a
        router-handled command runs, preserving submission order."""
        with client.pipeline() as p:
            p.set("{rw}:a", 1)
            p.set("rw-b", 2)
            got = p.mget(["{rw}:a", "rw-b"])
            p.set("rw-b", 3)
            got2 = p.mget(["rw-b"])
        assert got.get() == [1, 2]
        assert got2.get() == [3]

    def test_unstarted_cluster_client_rejected(self):
        with pytest.raises(RuntimeError, match="not started"):
            KVCluster(shards=2).client()
        with pytest.raises(ValueError, match="at least one shard"):
            ClusterClient(shard_addresses=[])


# ---------------------------------------------------------------------------
# PR 6: endpoint-carrying descriptors + transports over the cluster plane
# ---------------------------------------------------------------------------


class TestClusterTransports:
    def test_descriptor_advertises_endpoints(self, cluster):
        desc = cluster.describe()
        assert desc["version"] == 3
        assert desc["epoch"] >= 1
        assert len(desc["endpoints"]) == desc["n_shards"]
        for shard_eps, (host, port) in zip(desc["endpoints"], desc["shards"]):
            schemes = {u.split("://")[0] for u in shard_eps}
            assert f"tcp://{host}:{port}" in shard_eps
            assert "tcp" in schemes          # uds/shm presence is platform-
                                             # dependent; tcp never optional

    def test_v1_descriptor_still_bootstraps(self, cluster):
        """A pre-endpoint descriptor (bare host/port pairs) keeps
        working: version-2 parsing is additive."""
        c = ClusterClient(shard_addresses=cluster.shard_addresses)
        c.set("v1desc", 1)
        assert c.get("v1desc") == 1
        c.close()

    @pytest.mark.parametrize("transport", ["tcp", "uds", "shm"])
    def test_pinned_transport_end_to_end(self, cluster, transport):
        c = ClusterClient(address=cluster.address, transport=transport)
        c.flushall()
        for i in range(8):
            c.set(f"tk{i}", i)
        assert [c.get(f"tk{i}") for i in range(8)] == list(range(8))
        with c.pipeline() as p:
            for i in range(8):
                p.incr(f"tk{i}")
        for shard in {id(s): s for s in c._clients}.values():
            assert shard._mux("main").endpoint.scheme == transport
        c.close()

    def test_kill_then_restart_cycle_no_stale_paths(self):
        """SIGKILL a shard (no orderly cleanup), restart it, and use
        every carrier against the respawn: the parent removed the
        corpse's uds path, so nothing trips over a stale socket file."""
        import os
        import signal
        with KVCluster(shards=2) as cl:
            c = cl.client()
            c.set("pre", b"1")
            victim = cl._procs[0]
            old_uds = [u for u in victim.endpoints if u.startswith("uds://")]
            victim.proc.send_signal(signal.SIGKILL)
            victim.proc.wait()
            cl.restart_shard(0)
            for u in old_uds:
                assert not os.path.exists(u[len("uds://"):])
            for transport in ("tcp", "uds", "shm"):
                c2 = ClusterClient(address=cl.address, transport=transport)
                c2.set(f"post:{transport}", b"2")
                assert c2.get(f"post:{transport}") == b"2"
                c2.close()
            c.close()

    def test_restarted_shard_advertises_fresh_endpoints(self):
        with KVCluster(shards=1) as cl:
            before = cl.describe()["endpoints"][0]
            cl._procs[0].proc.kill()
            cl._procs[0].proc.wait()
            cl.restart_shard(0)
            after = cl.describe()["endpoints"][0]
            assert after != before
            boot = KVClient(cl.address)
            desc = boot.get(DESCRIPTOR_KEY)
            boot.close()
            assert desc["endpoints"][0] == after

    def test_connect_passes_transport_through(self, cluster):
        c = connect(cluster.address, transport="uds")
        assert isinstance(c, ClusterClient)
        c.set("ct", 3)
        assert c.get("ct") == 3
        assert c._clients[0]._mux("main").endpoint.scheme == "uds"
        c.close()


# ---------------------------------------------------------------------------
# PR 7: replicated shards, automatic failover, typed unavailability
# ---------------------------------------------------------------------------


from repro.core.errors import ShardRedirectError, ShardUnavailableError  # noqa: E402


def _replicated(**kw):
    defaults = dict(shards=2, replicas=1, ack="quorum")
    defaults.update(kw)
    return KVCluster(**defaults)


def _key_on_shard(client, shard, prefix="rk"):
    return next(f"{prefix}{i}" for i in range(1000)
                if client._hash(f"{prefix}{i}") % len(client.shards) == shard)


class TestReplicationFailover:
    def test_descriptor_v3_carries_replication_topology(self):
        with _replicated() as cl:
            desc = cl.describe()
            assert desc["version"] == 3
            assert desc["epoch"] == 1
            assert desc["ack"] == "quorum"
            assert len(desc["replicas"]) == 2
            assert all(len(reps) == 1 for reps in desc["replicas"])

    def test_replica_redirects_mutators_serves_reads(self):
        with _replicated(shards=1) as cl:
            c = cl.client()
            c.set("seen", 41)
            rep_urls = cl.describe()["replicas"][0][0]
            rc = KVClient(rep_urls)
            try:
                with pytest.raises(ShardRedirectError):
                    rc.set("x", 1)
                # replicas serve (possibly stale) reads; the streamed write
                # arrives promptly
                deadline = time.monotonic() + 5
                while rc.get("seen") != 41:
                    assert time.monotonic() < deadline, "write never replicated"
                    time.sleep(0.01)
            finally:
                rc.close()
                c.close()

    def test_kill_primary_mid_pipeline_no_acked_write_lost(self):
        """Quorum-acked writes survive SIGKILL of their primary; the next
        pipeline retries transparently onto the promoted replica."""
        with _replicated() as cl:
            c = cl.client()
            acked = []
            with c.pipeline() as p:
                for i in range(100):
                    p.set(f"k{i}", i)
            acked.extend(range(100))  # batch returned => all acked
            cl.kill_shard(0)
            promoter = threading.Timer(0.4, cl.promote_shard, args=(0,))
            promoter.start()
            try:
                # issued while shard 0 is DOWN: retry loop must carry the
                # scatter across the promotion (sets are idempotent)
                with c.pipeline() as p:
                    for i in range(100, 140):
                        p.set(f"k{i}", i)
                acked.extend(range(100, 140))
            finally:
                promoter.join()
            assert c.mget([f"k{i}" for i in acked]) == acked
            c.close()

    def test_kill_during_blpop_typed_error_then_repark(self):
        with _replicated() as cl:
            c = cl.client()
            dq = _key_on_shard(c, 1, "dq")
            out = []

            def park():
                try:
                    out.append(c.blpop(dq, timeout=30))
                except ShardUnavailableError as exc:
                    out.append(exc)

            th = threading.Thread(target=park)
            th.start()
            time.sleep(0.3)
            cl.kill_shard(1)
            th.join(20)
            assert out and isinstance(out[0], ShardUnavailableError)
            assert out[0].shard == 1
            cl.promote_shard(1)
            # re-park lands on the promoted replica and completes
            got = []
            th2 = threading.Thread(
                target=lambda: got.append(c.blpop(dq, timeout=10)))
            th2.start()
            time.sleep(0.2)
            c.rpush(dq, "after-failover")
            th2.join(15)
            assert got and got[0][1] == "after-failover"
            c.close()

    def test_parked_blpop_on_healthy_shard_survives_other_failover(self):
        with _replicated() as cl:
            c = cl.client()
            qk = _key_on_shard(c, 0, "q")
            got = []
            th = threading.Thread(
                target=lambda: got.append(c.blpop(qk, timeout=20)))
            th.start()
            time.sleep(0.3)
            cl.kill_shard(1)
            cl.promote_shard(1)
            c.rpush(qk, "payload")
            th.join(10)
            assert got and got[0][1] == "payload"
            c.close()

    def test_kill_during_execute_batch_scatter(self):
        """A scatter issued while one shard is down retries whole-batch
        (all-idempotent) and completes after promotion."""
        with _replicated() as cl:
            c = cl.client()
            cl.kill_shard(0)
            promoter = threading.Timer(0.4, cl.promote_shard, args=(0,))
            promoter.start()
            try:
                res = c.execute_batch(
                    [("set", (f"s{i}", i), {}) for i in range(64)])
            finally:
                promoter.join()
            assert all(ok for ok, _ in res)
            assert c.mget([f"s{i}" for i in range(64)]) == list(range(64))
            # a batch with a non-idempotent command fails typed instead
            cl.kill_shard(1)
            c2 = cl.client(failover_timeout_s=1.5)
            k1 = _key_on_shard(c2, 1, "nb")
            with pytest.raises(ShardUnavailableError):
                c2.execute_batch([("rpush", (k1, "x"), {})])
            c2.close()
            c.close()

    def test_double_failure_is_a_typed_loss(self):
        """replicas=1 survives exactly one failure per shard: the second
        kill has no promotable replica and surfaces as bounded typed
        errors, not hangs."""
        with _replicated() as cl:
            c = cl.client(failover_timeout_s=1.5)
            cl.kill_shard(0)
            cl.promote_shard(0)
            c.set("ok", 1)
            assert c.get("ok") == 1
            cl.kill_shard(0)
            with pytest.raises(RuntimeError, match="no live replica"):
                cl.promote_shard(0)
            k0 = _key_on_shard(c, 0, "dead")
            with pytest.raises(ShardUnavailableError) as ei:
                c.get(k0)  # retry-safe, but retries exhaust
            assert ei.value.shard == 0
            c.close()

    def test_watchdog_promotes_automatically(self):
        with _replicated(watchdog=True, heartbeat_s=0.2) as cl:
            c = cl.client()
            c.set("w", 1)
            cl.kill_shard(0)
            t0 = time.monotonic()
            deadline = t0 + 15
            while time.monotonic() < deadline:
                try:
                    if c.get("w") == 1 and cl._epoch > 1:
                        break
                except ConnectionError:
                    pass
                time.sleep(0.05)
            assert cl._epoch == 2, "watchdog never promoted"
            c.set("w2", 2)
            assert c.get("w2") == 2
            c.close()

    def test_refresh_detects_epoch_change_after_restart(self):
        with KVCluster(shards=2) as cl:  # replicas=0: restart, not promote
            c = cl.client()
            c.set("r", 1)
            assert c.refresh() in (True, False)  # first fetch may rebind
            epoch0 = c._desc_epoch
            cl.restart_shard(0)
            assert c.refresh() is True
            assert c._desc_epoch == epoch0 + 1
            # restarted shard is empty but serving
            k0 = _key_on_shard(c, 0, "fresh")
            c.set(k0, "v")
            assert c.get(k0) == "v"
            c.close()

    def test_static_shard_list_fails_fast_with_typed_error(self):
        """No control endpoint => nothing to refresh from: connection
        death surfaces immediately as ShardUnavailableError."""
        with KVCluster(shards=1) as cl:
            c = ClusterClient(shard_addresses=cl.shard_endpoints)
            c.set("s", 1)
            cl._procs[0].kill()
            with pytest.raises(ShardUnavailableError) as ei:
                c.get("s")
            assert ei.value.shard == 0
            assert ei.value.descriptor_version == 0
            c.close()
            cl.restart_shard(0)  # leave the fixture cluster healthy
