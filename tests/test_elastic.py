"""Elastic autoscaling worker plane (PR 9): ElasticPolicy decision
boundaries, the public Pool contract (``n_workers`` / ``backlog()``),
graceful drain semantics, session-level ``pool_defaults``, and the
auto-started controller — all over the fast in-process threads backend
(warm handler reuse over real OS processes lives in test_kvserver.py)."""

import time

import pytest

from repro.core import configure, get_session, mp
from repro.core.pool import Pool
from repro.runtime.elastic import ElasticController, ElasticPolicy


def _wait_until(pred, timeout=8.0, interval=0.02):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return pred()


# ---------------------------------------------------------------------------
# ElasticPolicy decision boundaries (pure — no pool needed)
# ---------------------------------------------------------------------------


class TestPolicyBoundaries:
    def test_hysteresis_one_quiet_sample_never_shrinks(self):
        p = ElasticPolicy(min_workers=1, idle_cycles_before_shrink=3)
        assert p.decide(8, backlog=0, idle_cycles=0) == 8
        assert p.decide(8, backlog=0, idle_cycles=1) == 8
        assert p.decide(8, backlog=0, idle_cycles=2) == 8
        assert p.decide(8, backlog=0, idle_cycles=3) == 4  # step=4 default

    def test_exact_threshold_holds_steady(self):
        # backlog == backlog_per_worker * n is NOT overload (strict >)
        p = ElasticPolicy(backlog_per_worker=2.0)
        assert p.decide(4, backlog=8, idle_cycles=0) == 4
        assert p.decide(4, backlog=9, idle_cycles=0) > 4

    def test_scale_up_is_step_clamped(self):
        p = ElasticPolicy(max_workers=64, step=4, backlog_per_worker=1.0)
        # a huge backlog still grows by at most `step` per decision
        assert p.decide(2, backlog=10 ** 6, idle_cycles=0) == 6

    def test_scale_up_clamps_at_max_workers(self):
        p = ElasticPolicy(min_workers=2, max_workers=4)
        assert p.decide(3, backlog=10 ** 6, idle_cycles=0) == 4
        assert p.decide(4, backlog=10 ** 6, idle_cycles=0) == 4
        # even a fleet already above max is pulled back into bounds
        assert p.decide(1000, backlog=10 ** 6, idle_cycles=0) == 4

    def test_scale_down_clamps_at_min_workers(self):
        p = ElasticPolicy(min_workers=2, step=4)
        assert p.decide(3, backlog=0, idle_cycles=99) == 2
        assert p.decide(2, backlog=0, idle_cycles=99) == 2

    def test_small_overload_grows_at_least_one(self):
        # 5 > 2*2 is overload; ceil(5/2)=3 guarantees visible growth
        p = ElasticPolicy(backlog_per_worker=2.0, step=4)
        assert p.decide(2, backlog=5, idle_cycles=0) == 3

    def test_invalid_policy_fields_raise(self):
        with pytest.raises(ValueError):
            ElasticPolicy(min_workers=-1)
        with pytest.raises(ValueError):
            ElasticPolicy(min_workers=8, max_workers=4)
        with pytest.raises(ValueError):
            ElasticPolicy(backlog_per_worker=0)
        with pytest.raises(ValueError):
            ElasticPolicy(step=0)


# ---------------------------------------------------------------------------
# The public Pool contract: n_workers + backlog()
# ---------------------------------------------------------------------------


class TestPoolContract:
    def test_backlog_zero_and_kv_free_when_idle(self):
        """An idle pool reports backlog 0 without touching the KV plane
        (the no-KV-load-when-idle half of the controller contract)."""
        with mp.Pool(2, max_retries=1) as p:
            p.map(lambda x: x, range(4))
            metrics = get_session().store.metrics
            llen0 = metrics.commands.get("LLEN", 0)
            hlen0 = metrics.commands.get("HLEN", 0)
            for _ in range(10):
                assert p.backlog() == 0
            # backlog() on an idle pool short-circuits client-side:
            # no LLEN, no HLEN — nothing hits the KV plane
            assert metrics.commands.get("LLEN", 0) == llen0
            assert metrics.commands.get("HLEN", 0) == hlen0

    def test_backlog_counts_queue_plus_inflight(self):
        """queued + in-flight, via one pipelined LLEN+HLEN read."""
        sess = get_session()
        p = Pool(1, max_retries=1)
        try:
            hold = sess.store  # direct handle for ground truth
            res = p.map_async(lambda x: time.sleep(0.15) or x, range(6),
                              chunksize=1)
            assert _wait_until(lambda: hold.hlen(p._inflight_key) >= 1)
            llen_before = sess.store.metrics.commands.get("LLEN", 0)
            hlen_before = sess.store.metrics.commands.get("HLEN", 0)
            b = p.backlog()
            # exactly one LLEN + one HLEN, in one pipelined flush
            assert sess.store.metrics.commands.get("LLEN", 0) \
                == llen_before + 1
            assert sess.store.metrics.commands.get("HLEN", 0) \
                == hlen_before + 1
            assert b >= 1  # 1 in-flight (plus whatever is still queued)
            assert res.get(30) == list(range(6))
        finally:
            p.close()
            p.join(timeout=10)

    def test_backlog_without_ft_is_queue_depth_only(self):
        p = Pool(1)
        try:
            res = p.map_async(lambda x: time.sleep(0.1) or x, range(4),
                              chunksize=1)
            b = p.backlog()
            assert b >= 0  # no in-flight hash to consult
            assert get_session().store.metrics.commands.get("HLEN", 0) == 0
            assert res.get(30) == list(range(4))
        finally:
            p.close()
            p.join(timeout=10)

    def test_n_workers_tracks_resize(self):
        p = Pool(2, elastic=True)
        try:
            assert p.n_workers == 2
            p.resize(4)
            assert _wait_until(lambda: p.n_workers == 4)
            p.resize(1)
            assert _wait_until(lambda: p.n_workers == 1)
        finally:
            p.close()
            p.join(timeout=10)


# ---------------------------------------------------------------------------
# Graceful drain
# ---------------------------------------------------------------------------


class TestGracefulDrain:
    def test_drain_on_empty_queue_exits_promptly(self):
        p = Pool(4, elastic=True)
        try:
            t0 = time.monotonic()
            p.resize(1)
            assert _wait_until(lambda: p.n_workers == 1, timeout=5)
            assert time.monotonic() - t0 < 5
            fs = p.fault_stats()
            assert fs["workers_drained"] == 3
            assert fs["workers_lost"] == 0
            assert fs["workers_respawned"] == 0
        finally:
            p.close()
            p.join(timeout=10)

    def test_drained_worker_finishes_inflight_task(self):
        """Scale-down mid-job: the drained worker completes its current
        lease — the task is never killed, dead-lettered or re-run."""
        p = Pool(2, max_retries=2, elastic=True, lease_ttl_s=2.0)
        try:
            res = p.map_async(lambda x: time.sleep(0.25) or x * 10,
                              range(8), chunksize=1)
            assert _wait_until(
                lambda: get_session().store.hlen(p._inflight_key) >= 1)
            p.resize(1)  # drains one worker while it holds a lease
            assert res.get(30) == [x * 10 for x in range(8)]
            assert _wait_until(lambda: p.n_workers == 1)
            fs = p.fault_stats()
            assert fs["workers_drained"] == 1
            assert fs["tasks_dead_lettered"] == 0
            assert fs["leases_requeued"] == 0
            assert fs["workers_lost"] == 0
            assert fs["respawn_budget_left"] == 4  # untouched (2 * 2)
        finally:
            p.close()
            p.join(timeout=10)

    def test_scale_up_cancels_pending_drain(self):
        p = Pool(3, elastic=True, max_retries=1)
        try:
            # hold all workers busy so drain flags stay un-honored
            res = p.map_async(lambda x: time.sleep(0.4) or x, range(3),
                              chunksize=1)
            assert _wait_until(
                lambda: get_session().store.hlen(p._inflight_key) >= 2)
            p.resize(1)   # flags 2 workers for drain
            p.resize(3)   # cancels both before they finish their task
            assert res.get(30) == list(range(3))
            assert _wait_until(lambda: p.n_workers == 3, timeout=6)
            assert p.map(lambda x: -x, range(6)) == [-x for x in range(6)]
        finally:
            p.close()
            p.join(timeout=10)

    def test_default_pool_resize_uses_legacy_poison(self):
        """Without elastic=, scale-down is the PR-6-era poison pill —
        no drain flags, no drain stats."""
        p = Pool(3)
        try:
            p.resize(1)
            assert _wait_until(lambda: p.n_workers == 1)
            fs = p.fault_stats()
            assert fs["workers_drained"] == 0
            assert fs["draining_workers"] == 0
        finally:
            p.close()
            p.join(timeout=10)


# ---------------------------------------------------------------------------
# session.configure(pool_defaults=...)
# ---------------------------------------------------------------------------


class TestPoolDefaults:
    def test_defaults_apply_and_merge(self):
        configure(pool_defaults={"max_retries": 3, "lease_ttl_s": 2.0})
        configure(pool_defaults={"speculation_factor": 2.5})  # composes
        p = Pool(2)
        try:
            assert p._max_retries == 3
            assert p._lease_cfg[0] == 2.0
            assert p._spec_factor == 2.5
        finally:
            p.close()
            p.join(timeout=10)

    def test_explicit_kwarg_wins(self):
        configure(pool_defaults={"max_retries": 3, "processes": 5})
        p = Pool(2, max_retries=0)
        try:
            assert p._max_retries == 0
            assert p._lease_cfg is None
            assert p.n_workers == 2  # explicit processes beats default
        finally:
            p.close()
            p.join(timeout=10)

    def test_unknown_default_key_raises_up_front(self):
        with pytest.raises(ValueError, match="unknown pool_defaults"):
            configure(pool_defaults={"max_retrys": 1})

    def test_none_removes_a_default(self):
        configure(pool_defaults={"max_retries": 3})
        configure(pool_defaults={"max_retries": None})
        p = Pool(2)
        try:
            assert p._max_retries == 0
        finally:
            p.close()
            p.join(timeout=10)

    def test_elastic_default_via_session(self):
        configure(pool_defaults={"elastic": {"min_workers": 1,
                                             "max_workers": 6}})
        p = Pool(2)
        try:
            assert p._elastic_controller is not None
            assert p._elastic_controller.policy.max_workers == 6
            assert p.map(lambda x: x + 1, range(10)) == list(range(1, 11))
        finally:
            p.close()
            p.join(timeout=10)
            assert p._elastic_controller is None  # stopped by close()


# ---------------------------------------------------------------------------
# Controller end-to-end over the public contract
# ---------------------------------------------------------------------------


class TestControllerEndToEnd:
    def test_scales_up_under_load_and_back_down_when_idle(self):
        p = Pool(1, max_retries=1,
                 elastic=ElasticPolicy(min_workers=1, max_workers=8,
                                       backlog_per_worker=1.0,
                                       idle_cycles_before_shrink=2,
                                       step=4))
        ctl = p._elastic_controller
        try:
            assert ctl is not None
            res = p.map_async(lambda x: time.sleep(0.05) or x, range(40),
                              chunksize=1)
            assert res.get(60) == list(range(40))
            assert ctl.decisions, "controller never acted"
            assert max(d[2] for d in ctl.decisions) > 1  # scaled up
            # idle hysteresis then drain back to the floor
            assert _wait_until(lambda: p.n_workers == 1, timeout=15)
            assert p.fault_stats()["workers_lost"] == 0
            assert ctl.worker_seconds() > 0
        finally:
            p.close()
            p.join(timeout=10)

    def test_invalid_elastic_value_raises(self):
        with pytest.raises(TypeError):
            Pool(2, elastic=object())

    def test_controller_against_custom_target(self):
        """The contract is duck-typed: anything with backlog()/n_workers/
        resize() can be driven (no Pool internals touched)."""

        class FakePool:
            def __init__(self):
                self.n_workers = 2
                self._backlog = 50
                self.calls = []

            def backlog(self):
                return self._backlog

            def resize(self, n):
                self.calls.append(n)
                self.n_workers = n
                self._backlog = 0  # pretend the burst was absorbed

        fake = FakePool()
        ctl = ElasticController(fake, ElasticPolicy(max_workers=8, step=4,
                                                    backlog_per_worker=1.0),
                                interval=0.01)
        with ctl:
            assert _wait_until(lambda: fake.calls, timeout=3)
        assert fake.calls[0] == 6  # 2 + step, not the full backlog jump
