import functools

import numpy as np
import pytest

from repro.core import serialization as ser

GLOBAL = 13


def module_fn(x):
    return x + GLOBAL


def recursive(n):
    return 1 if n <= 1 else n * recursive(n - 1)


class TestSerialization:
    def test_importable_by_reference(self):
        fn = ser.loads(ser.dumps(module_fn))
        assert fn(1) == 14

    def test_lambda_with_global(self):
        fn = ser.loads(ser.dumps(lambda x: x * GLOBAL))
        assert fn(2) == 26

    def test_closure(self):
        def make(a):
            b = a * 2

            def inner(c):
                return a + b + c
            return inner
        fn = ser.loads(ser.dumps(make(5)))
        assert fn(1) == 16

    def test_recursive_function(self):
        fn = ser.loads(ser.dumps(recursive))
        assert fn(5) == 120

    def test_local_recursive_function(self):
        def fib(n):
            return n if n < 2 else fib(n - 1) + fib(n - 2)
        fn = ser.loads(ser.dumps(fib))
        assert fn(10) == 55

    def test_defaults_and_kwdefaults(self):
        def f(a, b=2, *, c=3):
            return a + b + c
        fn = ser.loads(ser.dumps(f))
        assert fn(1) == 6
        assert fn(1, b=0, c=0) == 1

    def test_partial(self):
        fn = ser.loads(ser.dumps(functools.partial(module_fn, 7)))
        assert fn() == 20

    def test_numpy_payload(self):
        arr = np.arange(12).reshape(3, 4)
        out = ser.loads(ser.dumps(arr))
        np.testing.assert_array_equal(out, arr)

    def test_captured_module(self):
        import math

        def f(x):
            return math.sqrt(x)
        fn = ser.loads(ser.dumps(f))
        assert fn(9) == 3.0

    def test_dynamic_class(self):
        class Point:
            def __init__(self, x):
                self.x = x

            def double(self):
                return self.x * 2
        cls = ser.loads(ser.dumps(Point))
        assert cls(4).double() == 8

    def test_nested_functions_in_containers(self):
        obj = {"fns": [lambda x: x + 1, lambda x: x * 2], "n": 5}
        out = ser.loads(ser.dumps(obj))
        assert out["fns"][0](1) == 2
        assert out["fns"][1](3) == 6

    def test_payload_size(self):
        assert ser.payload_size({"a": 1}) > 0
