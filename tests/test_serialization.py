import functools

import numpy as np
import pytest

from repro.core import serialization as ser

GLOBAL = 13


def module_fn(x):
    return x + GLOBAL


def recursive(n):
    return 1 if n <= 1 else n * recursive(n - 1)


class TestSerialization:
    def test_importable_by_reference(self):
        fn = ser.loads(ser.dumps(module_fn))
        assert fn(1) == 14

    def test_lambda_with_global(self):
        fn = ser.loads(ser.dumps(lambda x: x * GLOBAL))
        assert fn(2) == 26

    def test_closure(self):
        def make(a):
            b = a * 2

            def inner(c):
                return a + b + c
            return inner
        fn = ser.loads(ser.dumps(make(5)))
        assert fn(1) == 16

    def test_recursive_function(self):
        fn = ser.loads(ser.dumps(recursive))
        assert fn(5) == 120

    def test_local_recursive_function(self):
        def fib(n):
            return n if n < 2 else fib(n - 1) + fib(n - 2)
        fn = ser.loads(ser.dumps(fib))
        assert fn(10) == 55

    def test_defaults_and_kwdefaults(self):
        def f(a, b=2, *, c=3):
            return a + b + c
        fn = ser.loads(ser.dumps(f))
        assert fn(1) == 6
        assert fn(1, b=0, c=0) == 1

    def test_partial(self):
        fn = ser.loads(ser.dumps(functools.partial(module_fn, 7)))
        assert fn() == 20

    def test_numpy_payload(self):
        arr = np.arange(12).reshape(3, 4)
        out = ser.loads(ser.dumps(arr))
        np.testing.assert_array_equal(out, arr)

    def test_captured_module(self):
        import math

        def f(x):
            return math.sqrt(x)
        fn = ser.loads(ser.dumps(f))
        assert fn(9) == 3.0

    def test_dynamic_class(self):
        class Point:
            def __init__(self, x):
                self.x = x

            def double(self):
                return self.x * 2
        cls = ser.loads(ser.dumps(Point))
        assert cls(4).double() == 8

    def test_nested_functions_in_containers(self):
        obj = {"fns": [lambda x: x + 1, lambda x: x * 2], "n": 5}
        out = ser.loads(ser.dumps(obj))
        assert out["fns"][0](1) == 2
        assert out["fns"][1](3) == 6

    def test_payload_size(self):
        assert ser.payload_size({"a": 1}) > 0


class TestWireEfficiency:
    """Size/zero-copy regressions for the remote hot path."""

    def test_default_protocol_is_highest(self):
        import pickle
        import pickletools
        op, arg, _ = next(pickletools.genops(ser.dumps({"a": 1})))
        assert op.name == "PROTO" and arg == pickle.HIGHEST_PROTOCOL

    def test_large_bytes_size_regression(self):
        blob = b"x" * (1 << 20)
        assert len(ser.dumps(blob)) <= len(blob) + 64

    def test_large_array_size_regression(self):
        arr = np.arange(1 << 17, dtype=np.float64)  # 1 MiB
        assert len(ser.dumps(arr)) <= arr.nbytes + 512

    def test_oob_roundtrip_bytes(self):
        blob = b"z" * 100_000
        payload, bufs = ser.dumps_oob(blob)
        assert len(payload) < 256  # descriptor only, data out-of-band
        assert len(bufs) == 1 and bufs[0].nbytes == len(blob)
        out = ser.loads_oob(payload, bufs)
        assert out == blob and type(out) is bytes

    def test_oob_roundtrip_bytearray(self):
        blob = bytearray(b"y" * 50_000)
        payload, bufs = ser.dumps_oob(blob)
        out = ser.loads_oob(payload, [bytearray(bytes(b)) for b in bufs])
        assert out == blob and type(out) is bytearray

    def test_oob_numpy_zero_copy(self):
        arr = np.arange(100_000, dtype=np.float32)
        payload, bufs = ser.dumps_oob(arr)
        assert len(payload) < 1024
        assert sum(b.nbytes for b in bufs) == arr.nbytes
        np.testing.assert_array_equal(ser.loads_oob(payload, bufs), arr)

    def test_oob_fortran_order_array(self):
        arr = np.asfortranarray(np.arange(5000, dtype=np.int64).reshape(50, 100))
        payload, bufs = ser.dumps_oob(arr)
        np.testing.assert_array_equal(ser.loads_oob(payload, bufs), arr)

    def test_oob_command_shape(self):
        # the transport's request tuple: large args go oob, small in-band
        blob = b"B" * 100_000
        cmd = ("rpush", ("key", blob, b"small"), {})
        payload, bufs = ser.dumps_oob(cmd)
        assert len(bufs) == 1 and len(payload) < 512
        assert ser.loads_oob(payload, bufs) == cmd

    def test_oob_small_payload_stays_inband(self):
        payload, bufs = ser.dumps_oob({"k": b"tiny"})
        assert bufs == []
        assert ser.loads_oob(payload) == {"k": b"tiny"}

    def test_oob_receive_buffer_types(self):
        # transport hands over bytearray receive buffers directly
        blob = b"q" * 65_536
        payload, bufs = ser.dumps_oob(blob)
        recv = [bytearray(bytes(b)) for b in bufs]
        assert ser.loads_oob(bytearray(payload), recv) == blob
