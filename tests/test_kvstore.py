import threading
import time

import pytest

from repro.core.kvstore import (KVStore, LatencyModel, ShardedKVStore,
                                WrongTypeError)


@pytest.fixture
def kv():
    return KVStore()


class TestLists:
    def test_push_pop_order(self, kv):
        kv.rpush("l", b"a", b"b")
        kv.lpush("l", b"z")
        assert kv.lrange("l", 0, -1) == [b"z", b"a", b"b"]
        assert kv.lpop("l") == b"z"
        assert kv.rpop("l") == b"b"
        assert kv.llen("l") == 1

    def test_lindex_lset(self, kv):
        kv.rpush("l", b"a", b"b", b"c")
        assert kv.lindex("l", 1) == b"b"
        assert kv.lindex("l", -1) == b"c"
        kv.lset("l", 1, b"B")
        assert kv.lrange("l", 0, -1) == [b"a", b"B", b"c"]

    def test_lrange_negative(self, kv):
        kv.rpush("l", *[str(i).encode() for i in range(5)])
        assert kv.lrange("l", -2, -1) == [b"3", b"4"]
        assert kv.lrange("l", 1, 2) == [b"1", b"2"]

    def test_empty_list_removed(self, kv):
        kv.rpush("l", b"x")
        kv.lpop("l")
        assert not kv.exists("l")

    def test_blpop_blocks_until_push(self, kv):
        out = []
        t = threading.Thread(target=lambda: out.append(kv.blpop("q", 5)))
        t.start()
        time.sleep(0.05)
        assert not out
        kv.rpush("q", b"v")
        t.join(2)
        assert out == [("q", b"v")]

    def test_blpop_timeout(self, kv):
        t0 = time.monotonic()
        assert kv.blpop("missing", 0.05) is None
        assert time.monotonic() - t0 >= 0.04

    def test_blpop_multiple_keys(self, kv):
        kv.rpush("b", b"2")
        assert kv.blpop(["a", "b"], 0.1) == ("b", b"2")

    def test_rpoplpush(self, kv):
        kv.rpush("src", b"1", b"2")
        assert kv.rpoplpush("src", "dst") == b"2"
        assert kv.lrange("dst", 0, -1) == [b"2"]


class TestStringsAndCounters:
    def test_set_get(self, kv):
        kv.set("k", b"v")
        assert kv.get("k") == b"v"
        assert kv.get("missing") is None

    def test_setnx(self, kv):
        assert kv.setnx("k", 1)
        assert not kv.setnx("k", 2)
        assert kv.get("k") == 1

    def test_incr_decr(self, kv):
        assert kv.incr("c") == 1
        assert kv.incrby("c", 10) == 11
        assert kv.decr("c") == 10

    def test_getset(self, kv):
        assert kv.getset("k", b"new") is None
        assert kv.getset("k", b"newer") == b"new"


class TestHashes:
    def test_basic(self, kv):
        kv.hset("h", "f", b"v")
        kv.hset("h", mapping={"g": b"w"})
        assert kv.hget("h", "f") == b"v"
        assert kv.hgetall("h") == {"f": b"v", "g": b"w"}
        assert kv.hlen("h") == 2
        assert sorted(kv.hkeys("h")) == ["f", "g"]
        assert kv.hdel("h", "f") == 1
        assert not kv.hexists("h", "f")

    def test_hsetnx_hincrby(self, kv):
        assert kv.hsetnx("h", "f", 1)
        assert not kv.hsetnx("h", "f", 2)
        assert kv.hincrby("h", "n", 5) == 5
        assert kv.hincrby("h", "n", -2) == 3


class TestSets:
    def test_basic(self, kv):
        assert kv.sadd("s", b"a", b"b") == 2
        assert kv.sadd("s", b"a") == 0
        assert kv.smembers("s") == {b"a", b"b"}
        assert kv.sismember("s", b"a")
        assert kv.srem("s", b"a") == 1
        assert kv.scard("s") == 1


class TestExpiry:
    def test_ttl_expires(self, kv):
        kv.set("k", b"v", ex=0.05)
        assert kv.get("k") == b"v"
        assert 0 < kv.ttl("k") <= 0.05
        time.sleep(0.07)
        assert kv.get("k") is None
        assert kv.ttl("k") == -2

    def test_expire_and_persist(self, kv):
        kv.set("k", b"v")
        assert kv.ttl("k") == -1
        kv.expire("k", 100)
        assert kv.ttl("k") > 0
        kv.persist("k")
        assert kv.ttl("k") == -1


class TestSemantics:
    def test_wrong_type(self, kv):
        kv.set("k", b"v")
        with pytest.raises(WrongTypeError):
            kv.rpush("k", b"x")

    def test_transaction_atomic(self, kv):
        def txn(s):
            v = s.incr("a")
            s.rpush("log", str(v).encode())
            return v
        assert kv.transaction(txn) == 1
        assert kv.lrange("log", 0, -1) == [b"1"]

    def test_keys_pattern(self, kv):
        kv.set("a:1", 1)
        kv.set("a:2", 2)
        kv.set("b:1", 3)
        assert sorted(kv.keys("a:*")) == ["a:1", "a:2"]

    def test_concurrent_incr_is_atomic(self, kv):
        def bump():
            for _ in range(200):
                kv.incr("n")
        ts = [threading.Thread(target=bump) for _ in range(4)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert kv.get("n") == 800


class TestLatencyModel:
    def test_virtual_time_accrues(self):
        kv = KVStore(LatencyModel(rtt_s=0.001, bandwidth_bps=1e6, scale=0.0))
        kv.set("k", b"x" * 1000)
        assert kv.latency.virtual_time == pytest.approx(0.002, rel=0.01)

    def test_scaled_sleep(self):
        kv = KVStore(LatencyModel(rtt_s=0.1, scale=0.1))
        t0 = time.monotonic()
        kv.set("k", 1)
        assert 0.005 <= time.monotonic() - t0 < 0.1


class TestSharded:
    def test_routing_consistent(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        for i in range(50):
            sh.set(f"key-{i}", i)
        for i in range(50):
            assert sh.get(f"key-{i}") == i
        assert sh.dbsize() == 50
        # keys spread over more than one shard
        assert sum(1 for s in sh.shards if s.dbsize() > 0) > 1

    def test_hash_tags_colocate(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        assert sh.shard_for("{u1}:a") is sh.shard_for("{u1}:b")

    def test_blocking_across_shards(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        out = []
        t = threading.Thread(
            target=lambda: out.append(sh.blpop(["{x}:q", "{y}:q"], 3)))
        t.start()
        time.sleep(0.05)
        sh.rpush("{y}:q", b"v")
        t.join(2)
        assert out == [("{y}:q", b"v")]
