import threading
import time

import pytest

from repro.core.kvstore import (KVStore, LatencyModel, PipelineError,
                                ShardedKVStore, WrongTypeError)


@pytest.fixture
def kv():
    return KVStore()


class TestLists:
    def test_push_pop_order(self, kv):
        kv.rpush("l", b"a", b"b")
        kv.lpush("l", b"z")
        assert kv.lrange("l", 0, -1) == [b"z", b"a", b"b"]
        assert kv.lpop("l") == b"z"
        assert kv.rpop("l") == b"b"
        assert kv.llen("l") == 1

    def test_lindex_lset(self, kv):
        kv.rpush("l", b"a", b"b", b"c")
        assert kv.lindex("l", 1) == b"b"
        assert kv.lindex("l", -1) == b"c"
        kv.lset("l", 1, b"B")
        assert kv.lrange("l", 0, -1) == [b"a", b"B", b"c"]

    def test_lrange_negative(self, kv):
        kv.rpush("l", *[str(i).encode() for i in range(5)])
        assert kv.lrange("l", -2, -1) == [b"3", b"4"]
        assert kv.lrange("l", 1, 2) == [b"1", b"2"]

    def test_empty_list_removed(self, kv):
        kv.rpush("l", b"x")
        kv.lpop("l")
        assert not kv.exists("l")

    def test_blpop_blocks_until_push(self, kv):
        out = []
        t = threading.Thread(target=lambda: out.append(kv.blpop("q", 5)))
        t.start()
        time.sleep(0.05)
        assert not out
        kv.rpush("q", b"v")
        t.join(2)
        assert out == [("q", b"v")]

    def test_blpop_timeout(self, kv):
        t0 = time.monotonic()
        assert kv.blpop("missing", 0.05) is None
        assert time.monotonic() - t0 >= 0.04

    def test_blpop_multiple_keys(self, kv):
        kv.rpush("b", b"2")
        assert kv.blpop(["a", "b"], 0.1) == ("b", b"2")

    def test_rpoplpush(self, kv):
        kv.rpush("src", b"1", b"2")
        assert kv.rpoplpush("src", "dst") == b"2"
        assert kv.lrange("dst", 0, -1) == [b"2"]


class TestStringsAndCounters:
    def test_set_get(self, kv):
        kv.set("k", b"v")
        assert kv.get("k") == b"v"
        assert kv.get("missing") is None

    def test_setnx(self, kv):
        assert kv.setnx("k", 1)
        assert not kv.setnx("k", 2)
        assert kv.get("k") == 1

    def test_incr_decr(self, kv):
        assert kv.incr("c") == 1
        assert kv.incrby("c", 10) == 11
        assert kv.decr("c") == 10

    def test_getset(self, kv):
        assert kv.getset("k", b"new") is None
        assert kv.getset("k", b"newer") == b"new"


class TestHashes:
    def test_basic(self, kv):
        kv.hset("h", "f", b"v")
        kv.hset("h", mapping={"g": b"w"})
        assert kv.hget("h", "f") == b"v"
        assert kv.hgetall("h") == {"f": b"v", "g": b"w"}
        assert kv.hlen("h") == 2
        assert sorted(kv.hkeys("h")) == ["f", "g"]
        assert kv.hdel("h", "f") == 1
        assert not kv.hexists("h", "f")

    def test_hsetnx_hincrby(self, kv):
        assert kv.hsetnx("h", "f", 1)
        assert not kv.hsetnx("h", "f", 2)
        assert kv.hincrby("h", "n", 5) == 5
        assert kv.hincrby("h", "n", -2) == 3


class TestSets:
    def test_basic(self, kv):
        assert kv.sadd("s", b"a", b"b") == 2
        assert kv.sadd("s", b"a") == 0
        assert kv.smembers("s") == {b"a", b"b"}
        assert kv.sismember("s", b"a")
        assert kv.srem("s", b"a") == 1
        assert kv.scard("s") == 1


class TestExpiry:
    def test_ttl_expires(self, kv):
        kv.set("k", b"v", ex=0.05)
        assert kv.get("k") == b"v"
        assert 0 < kv.ttl("k") <= 0.05
        time.sleep(0.07)
        assert kv.get("k") is None
        assert kv.ttl("k") == -2

    def test_expire_and_persist(self, kv):
        kv.set("k", b"v")
        assert kv.ttl("k") == -1
        kv.expire("k", 100)
        assert kv.ttl("k") > 0
        kv.persist("k")
        assert kv.ttl("k") == -1


class TestSemantics:
    def test_wrong_type(self, kv):
        kv.set("k", b"v")
        with pytest.raises(WrongTypeError):
            kv.rpush("k", b"x")

    def test_transaction_atomic(self, kv):
        def txn(s):
            v = s.incr("a")
            s.rpush("log", str(v).encode())
            return v
        assert kv.transaction(txn) == 1
        assert kv.lrange("log", 0, -1) == [b"1"]

    def test_keys_pattern(self, kv):
        kv.set("a:1", 1)
        kv.set("a:2", 2)
        kv.set("b:1", 3)
        assert sorted(kv.keys("a:*")) == ["a:1", "a:2"]

    def test_concurrent_incr_is_atomic(self, kv):
        def bump():
            for _ in range(200):
                kv.incr("n")
        ts = [threading.Thread(target=bump) for _ in range(4)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert kv.get("n") == 800


class TestBatchCommands:
    def test_mset_mget(self, kv):
        assert kv.mset({"a": 1, "b": b"two"}) == 2
        assert kv.mget(["a", "b", "missing"]) == [1, b"two", None]

    def test_mget_wrong_type_yields_none(self, kv):
        kv.rpush("alist", b"x")
        kv.set("s", 1)
        assert kv.mget(["alist", "s"]) == [None, 1]

    def test_blpop_rpush_immediate(self, kv):
        kv.rpush("slots", b"s")
        assert kv.blpop_rpush("slots", "items", b"blob", 1) == b"s"
        assert kv.lrange("items", 0, -1) == [b"blob"]

    def test_blpop_rpush_blocks_until_push(self, kv):
        out = []
        t = threading.Thread(
            target=lambda: out.append(kv.blpop_rpush("src", "dst", b"v", 5)))
        t.start()
        time.sleep(0.05)
        assert not out
        kv.rpush("src", b"e")
        t.join(2)
        assert out == [b"e"]
        assert kv.lrange("dst", 0, -1) == [b"v"]

    def test_blpop_rpush_timeout_pushes_nothing(self, kv):
        assert kv.blpop_rpush("nope", "dst", b"v", 0.05) is None
        assert kv.llen("dst") == 0

    def test_blpop_rpush_bad_dst_does_not_consume_src(self, kv):
        kv.set("dst", 1)  # string, not list
        kv.rpush("src", b"x")
        with pytest.raises(WrongTypeError):
            kv.blpop_rpush("src", "dst", b"v", 0.1)
        assert kv.lrange("src", 0, -1) == [b"x"]  # element not lost

    def test_blpop_rpush_is_one_command(self, kv):
        kv.rpush("slots", b"s")
        before = kv.metrics.total_commands()
        kv.blpop_rpush("slots", "items", b"x", 1)
        assert kv.metrics.total_commands() - before == 1

    def test_bllen_nonblocking_and_timeout(self, kv):
        kv.rpush("l", b"1", b"2")
        assert kv.bllen("l", 0.1) == 2
        t0 = time.monotonic()
        assert kv.bllen("missing", 0.05) == 0
        assert time.monotonic() - t0 >= 0.04

    def test_bllen_wakes_on_push(self, kv):
        out = []
        t = threading.Thread(target=lambda: out.append(kv.bllen("later", 5)))
        t.start()
        time.sleep(0.05)
        kv.rpush("later", b"a", b"b")
        t.join(2)
        assert out == [2]

    def test_execute_batch_values_and_errors(self, kv):
        kv.set("str", b"v")
        res = kv.execute_batch([
            ("incr", ("n",), {}),
            ("rpush", ("str", b"x"), {}),       # WRONGTYPE mid-batch
            ("set", ("k",), {"value": 5}),      # still executed
            ("definitely_not_a_command", (), {}),
        ])
        assert res[0] == (True, 1)
        assert res[1][0] is False and isinstance(res[1][1], WrongTypeError)
        assert res[2] == (True, True)
        assert res[3][0] is False and isinstance(res[3][1], AttributeError)
        assert kv.get("k") == 5

    def test_execute_batch_forces_nonblocking(self, kv):
        t0 = time.monotonic()
        res = kv.execute_batch([("blpop", ("never", 60), {})])
        assert time.monotonic() - t0 < 1.0
        assert res == [(True, None)]

    def test_execute_batch_rejects_private(self, kv):
        res = kv.execute_batch([("_charge", ("X",), {})])
        assert res[0][0] is False and isinstance(res[0][1], AttributeError)

    def test_execute_batch_charges_one_rtt(self):
        kv = KVStore(LatencyModel(rtt_s=0.001, scale=0.0))
        kv.execute_batch([("incr", ("n",), {}) for _ in range(10)])
        assert kv.latency.charges == 1
        assert kv.latency.virtual_time == pytest.approx(0.001, rel=0.01)

    def test_pipeline_futures(self, kv):
        with kv.pipeline() as p:
            a = p.rpush("l", b"1", b"2")
            b = p.llen("l")
        assert a.get() == 2 and b.get() == 2

    def test_pipeline_error_drains_batch(self, kv):
        kv.set("s", b"v")
        p = kv.pipeline()
        first = p.incr("n")
        bad = p.rpush("s", b"x")
        last = p.incr("n")
        with pytest.raises(PipelineError) as ei:
            p.execute()
        assert ei.value.index == 1
        assert first.get() == 1 and last.get() == 2  # drained past failure
        with pytest.raises(WrongTypeError):
            bad.get()


class TestLeases:
    """Lease protocol (PR 8): fused pop-and-lease, fenced renew/release,
    expiry reaping with attempt bumps, and the dead-letter channel."""

    def test_blpop_lease_moves_entry_into_hash(self, kv):
        kv.rpush("q", (0, "t1", b"payload"))
        got = kv.blpop_lease("q", "fl", "w1", 5.0, timeout=0)
        assert got == (0, "t1", b"payload")
        rec = kv.hget("fl", "t1")
        assert rec[1] == 0 and rec[2] == "w1" and rec[3] == b"payload"
        assert rec[0] > time.monotonic()  # deadline in the future
        assert kv.llen("q") == 0

    def test_blpop_lease_is_one_command(self, kv):
        kv.rpush("q", (0, "t1", b"x"))
        before = kv.metrics.total_commands()
        kv.blpop_lease("q", "fl", "w1", 5.0, timeout=0)
        assert kv.metrics.total_commands() - before == 1

    def test_blpop_lease_passthrough_non_entry(self, kv):
        # poison pills and legacy payloads pass through un-leased
        kv.rpush("q", b"__poison__")
        assert kv.blpop_lease("q", "fl", "w1", 5.0, timeout=0) == b"__poison__"
        assert not kv.exists("fl")

    def test_blpop_lease_atomic_under_concurrent_consumers(self, kv):
        n = 200
        for i in range(n):
            kv.rpush("q", (0, f"t{i}", i))
        won: list = []
        lock = threading.Lock()

        def consume(wid):
            while True:
                got = kv.blpop_lease("q", "fl", wid, 30.0, timeout=0)
                if got is None:
                    return
                with lock:
                    won.append(got[1])

        threads = [threading.Thread(target=consume, args=(f"w{j}",))
                   for j in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(20)
        # every task leased exactly once: no loss, no double-acquire
        assert sorted(won) == sorted(f"t{i}" for i in range(n))
        assert kv.hlen("fl") == n

    def test_lease_renew_and_release_are_fenced(self, kv):
        kv.rpush("q", (3, "t1", b"x"))
        kv.blpop_lease("q", "fl", "w1", 5.0, timeout=0)
        assert kv.lease_renew("fl", "t1", 3, 10.0) is True
        assert kv.lease_renew("fl", "t1", 2, 10.0) is False   # stale attempt
        assert kv.lease_release("fl", "t1", 2) is False       # stale attempt
        assert kv.hlen("fl") == 1                             # still held
        assert kv.lease_release("fl", "t1", 3) is True
        assert not kv.exists("fl")  # empty hash removed
        assert kv.lease_release("fl", "t1", 3) is False       # idempotent

    def test_lease_reap_requeues_expired_with_attempt_bump(self, kv):
        kv.rpush("q", (0, "t1", b"x"))
        kv.blpop_lease("q", "fl", "w1", 0.05, timeout=0)
        time.sleep(0.08)
        requeued, dead = kv.lease_reap("fl", "q", max_attempts=3)
        assert requeued == [("t1", 0)] and dead == []
        assert kv.lrange("q", 0, -1) == [(1, "t1", b"x")]
        assert not kv.exists("fl")

    def test_lease_reap_respects_live_leases(self, kv):
        kv.rpush("q", (0, "t1", b"x"))
        kv.blpop_lease("q", "fl", "w1", 30.0, timeout=0)
        assert kv.lease_reap("fl", "q", max_attempts=3) == ([], [])
        assert kv.hlen("fl") == 1

    def test_lease_reap_by_worker_reclaims_live_lease(self, kv):
        kv.rpush("q", (0, "t1", b"x"))
        kv.rpush("q", (0, "t2", b"y"))
        kv.blpop_lease("q", "fl", "w1", 30.0, timeout=0)
        kv.blpop_lease("q", "fl", "w2", 30.0, timeout=0)
        requeued, dead = kv.lease_reap("fl", "q", max_attempts=3, worker="w1")
        assert requeued == [("t1", 0)] and dead == []
        assert list(kv.hgetall("fl")) == ["t2"]  # w2's lease untouched

    def test_lease_reap_dead_letters_with_holder(self, kv):
        kv.rpush("q", (2, "t1", b"x"))  # attempt 2 == max_attempts: last try
        kv.blpop_lease("q", "fl", "w9", 0.05, timeout=0)
        time.sleep(0.08)
        requeued, dead = kv.lease_reap("fl", "q", max_attempts=2,
                                       dead_key="dq")
        assert requeued == [] and dead == [("t1", 2)]
        assert kv.llen("q") == 0
        # the dead-letter record carries the last holder for the error
        assert kv.lrange("dq", 0, -1) == [("t1", 2, "w9", b"x")]

    def test_lease_reap_returns_entries_when_not_pushing(self, kv):
        kv.rpush("q", (1, "t1", b"x"))
        kv.blpop_lease("q", "fl", "w1", 0.05, timeout=0)
        time.sleep(0.08)
        # no src: the caller (the sharded router) routes the pushes, so
        # the store returns full entries instead of pushing summaries
        requeued, dead = kv.lease_reap("fl", max_attempts=3)
        assert requeued == [(2, "t1", b"x")] and dead == []
        assert kv.llen("q") == 0  # nothing pushed by the store itself

    def test_stale_settle_after_reap_is_rejected(self, kv):
        """The zombie scenario at the store layer: expiry, requeue, a new
        worker settles attempt 1 — the old worker's attempt-0 release and
        renew must both bounce off the fence."""
        kv.rpush("q", (0, "t1", b"x"))
        kv.blpop_lease("q", "fl", "w1", 0.05, timeout=0)
        time.sleep(0.08)
        kv.lease_reap("fl", "q", max_attempts=3)
        got = kv.blpop_lease("q", "fl", "w2", 30.0, timeout=0)
        assert got == (1, "t1", b"x")
        assert kv.lease_renew("fl", "t1", 0, 30.0) is False   # zombie renew
        assert kv.lease_release("fl", "t1", 0) is False       # zombie settle
        assert kv.hget("fl", "t1")[2] == "w2"                 # w2 still holds
        assert kv.lease_release("fl", "t1", 1) is True


class TestSizeof:
    def test_memoryview_counts_bytes_not_elements(self):
        kv = KVStore()
        view = memoryview(bytearray(64)).cast("d")  # 8 elements, 64 bytes
        kv.set("k", view)
        assert kv.metrics.bytes_in == 64

    def test_str_counts_encoded_bytes(self):
        kv = KVStore()
        kv.set("k", "héllo")   # 5 chars, 6 utf-8 bytes
        assert kv.metrics.bytes_in == 6


class TestLatencyModel:
    def test_virtual_time_accrues(self):
        kv = KVStore(LatencyModel(rtt_s=0.001, bandwidth_bps=1e6, scale=0.0))
        kv.set("k", b"x" * 1000)
        assert kv.latency.virtual_time == pytest.approx(0.002, rel=0.01)

    def test_scaled_sleep(self):
        kv = KVStore(LatencyModel(rtt_s=0.1, scale=0.1))
        t0 = time.monotonic()
        kv.set("k", 1)
        assert 0.005 <= time.monotonic() - t0 < 0.1


class TestSharded:
    def test_routing_consistent(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        for i in range(50):
            sh.set(f"key-{i}", i)
        for i in range(50):
            assert sh.get(f"key-{i}") == i
        assert sh.dbsize() == 50
        # keys spread over more than one shard
        assert sum(1 for s in sh.shards if s.dbsize() > 0) > 1

    def test_hash_tags_colocate(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        assert sh.shard_for("{u1}:a") is sh.shard_for("{u1}:b")

    def test_blocking_across_shards(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        out = []
        t = threading.Thread(
            target=lambda: out.append(sh.blpop(["{x}:q", "{y}:q"], 3)))
        t.start()
        time.sleep(0.05)
        sh.rpush("{y}:q", b"v")
        t.join(2)
        assert out == [("{y}:q", b"v")]

    def test_multishard_bpop_timeout_capped(self):
        # {x} and {y} land on different shards: the poll loop's backoff
        # must be capped at the remaining timeout, not overshoot it.
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        assert sh.shard_for("{x}:q") is not sh.shard_for("{y}:q")
        t0 = time.monotonic()
        assert sh.blpop(["{x}:q", "{y}:q"], 0.15) is None
        elapsed = time.monotonic() - t0
        assert 0.13 <= elapsed < 0.5, elapsed

    def test_multishard_bpop_fairness(self):
        # Items on both shards: repeated pops drain both queues rather
        # than starving one shard.
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        sh.rpush("{x}:q", b"x1", b"x2")
        sh.rpush("{y}:q", b"y1", b"y2")
        got = [sh.blpop(["{x}:q", "{y}:q"], 1) for _ in range(4)]
        assert sorted(v for _, v in got) == [b"x1", b"x2", b"y1", b"y2"]
        assert sh.blpop(["{x}:q", "{y}:q"], 0.05) is None

    def test_multishard_bpop_late_push_wakes(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        out = []
        t = threading.Thread(
            target=lambda: out.append(sh.blpop(["{x}:q", "{y}:q"], 5)))
        t.start()
        time.sleep(0.2)  # long enough that backoff reached its cap
        sh.rpush("{x}:q", b"late")
        t.join(2)
        assert out == [("{x}:q", b"late")]

    def test_sharded_blpop_rpush_same_shard(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        sh.rpush("{u}:slots", b"s")
        assert sh.blpop_rpush("{u}:slots", "{u}:items", b"B", 1) == b"s"
        assert sh.lrange("{u}:items", 0, -1) == [b"B"]
        # fused op on one shard: a single command in that shard's metrics
        shard = sh.shard_for("{u}:slots")
        assert shard.metrics.commands.get("BLPOPRPUSH") == 1

    def test_sharded_blpop_rpush_cross_shard_fallback(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        src, dst = "{x}:src", "{y}:dst"
        assert sh.shard_for(src) is not sh.shard_for(dst)
        sh.rpush(src, b"1")
        assert sh.blpop_rpush(src, dst, b"2", 1) == b"1"
        assert sh.lrange(dst, 0, -1) == [b"2"]

    def test_sharded_blpop_rpush_cross_shard_bad_dst_no_loss(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        src, dst = "{x}:src", "{y}:dst"
        sh.set(dst, 1)  # string, not list
        sh.rpush(src, b"elem")
        with pytest.raises(WrongTypeError):
            sh.blpop_rpush(src, dst, b"v", 0.1)
        assert sh.lrange(src, 0, -1) == [b"elem"]  # element not consumed

    def test_sharded_rpoplpush_cross_shard(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        src, dst = "{x}:src", "{y}:dst"
        sh.rpush(src, b"1", b"2")
        assert sh.rpoplpush(src, dst) == b"2"
        assert sh.lrange(dst, 0, -1) == [b"2"]  # visible under dst's shard

    def test_sharded_batch_two_key_commands_route_correctly(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        src, dst = "{x}:src", "{y}:dst"
        sh.rpush(src, b"1")
        with sh.pipeline() as p:
            moved = p.blpop_rpush(src, dst, b"v", 0)
        assert moved.get() == b"1"
        # the push landed where direct reads look for it
        assert sh.lrange(dst, 0, -1) == [b"v"]

    def test_sharded_execute_batch_preserves_order(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        res = sh.execute_batch(
            [("set", (f"key-{i}", i), {}) for i in range(20)]
            + [("get", (f"key-{i}",), {}) for i in range(20)])
        assert all(ok for ok, _ in res)
        assert [v for _, v in res[20:]] == list(range(20))

    def test_sharded_pipeline(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(2)])
        with sh.pipeline() as p:
            a = p.incr("a")
            b = p.incr("b")
        assert a.get() == 1 and b.get() == 1

    def test_sharded_mset_mget_route_per_key(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        mapping = {f"key-{i}": i for i in range(20)}
        assert sh.mset(mapping) == 20
        assert sh.mget([f"key-{i}" for i in range(20)]) == list(range(20))
        # readable through single-key routing too (same shard per key)
        assert all(sh.get(f"key-{i}") == i for i in range(20))

    def test_sharded_batch_routes_multikey_commands(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        with sh.pipeline() as p:
            p.mset({f"m-{i}": i for i in range(8)})
            got = p.mget([f"m-{i}" for i in range(8)])
            popped = p.blpop(["{x}:q", "{y}:q"], 30)  # forced non-blocking
        assert got.get() == list(range(8))
        assert popped.get() is None
        # multi-key delete spans shards instead of landing on args[0]'s
        sh.mset({f"d-{i}": i for i in range(8)})
        with sh.pipeline() as p:
            deleted = p.delete(*[f"d-{i}" for i in range(8)])
        assert deleted.get() == 8
        assert sh.mget([f"d-{i}" for i in range(8)]) == [None] * 8

    def test_sharded_blpop_lease_same_shard_fast_path(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        # hash tags co-locate the pool's queue and in-flight hash, the
        # layout Pool uses: one fused command on one shard
        sh.rpush("{u}:jobs", (0, "t1", b"x"))
        got = sh.blpop_lease("{u}:jobs", "{u}:inflight", "w1", 5.0, timeout=0)
        assert got == (0, "t1", b"x")
        shard = sh.shard_for("{u}:jobs")
        assert shard.metrics.commands.get("BLPOPLEASE") == 1
        assert sh.hget("{u}:inflight", "t1")[2] == "w1"

    def test_sharded_blpop_lease_cross_shard(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        src, dst = "{x}:jobs", "{y}:inflight"
        assert sh.shard_for(src) is not sh.shard_for(dst)
        sh.rpush(src, (0, "t1", b"x"))
        assert sh.blpop_lease(src, dst, "w1", 5.0, timeout=0) == (0, "t1", b"x")
        # the lease is visible where direct reads route to
        assert sh.hget(dst, "t1")[2] == "w1"
        assert sh.lease_release(dst, "t1", 0) is True

    def test_sharded_lease_reap_fallback_routes_pushes(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        src, dst, dead = "{x}:jobs", "{y}:inflight", "{z}:dead"
        sh.rpush(src, (0, "t1", b"x"))
        sh.rpush(src, (2, "t2", b"y"))
        for w in ("w1", "w2"):
            sh.blpop_lease(src, dst, w, 0.05, timeout=0)
        time.sleep(0.08)
        requeued, deadl = sh.lease_reap(dst, src, max_attempts=2,
                                        dead_key=dead)
        assert requeued == [("t1", 0)] and deadl == [("t2", 2)]
        assert sh.lrange(src, 0, -1) == [(1, "t1", b"x")]
        assert sh.lrange(dead, 0, -1) == [("t2", 2, "w2", b"y")]


class TestByteRange:
    def test_getrange_semantics(self, kv):
        kv.set("s", b"Hello World")
        assert kv.getrange("s", 0, 4) == b"Hello"
        assert kv.getrange("s", 6, -1) == b"World"
        assert kv.getrange("s", -5, -1) == b"World"
        assert kv.getrange("s", 0, -1) == b"Hello World"
        assert kv.getrange("s", 20, 25) == b""
        assert kv.getrange("missing", 0, -1) == b""
        assert kv.strlen("s") == 11
        assert kv.strlen("missing") == 0

    def test_setrange_overwrite_and_extend(self, kv):
        kv.set("s", b"Hello World")
        assert kv.setrange("s", 6, b"Redis") == 11
        assert kv.get("s") == b"Hello Redis"
        # extend past the end zero-pads the gap
        assert kv.setrange("s", 13, b"!") == 14
        assert kv.get("s") == b"Hello Redis\x00\x00!"
        # creates a missing key, zero-padded up to offset
        assert kv.setrange("fresh", 3, b"xy") == 5
        assert kv.get("fresh") == b"\x00\x00\x00xy"

    def test_setrange_empty_value_is_a_noop(self, kv):
        # Redis: an empty value neither creates the key nor pads it
        assert kv.setrange("missing", 5, b"") == 0
        assert not kv.exists("missing")
        kv.set("s", b"abc")
        assert kv.setrange("s", 10, b"") == 3
        assert kv.get("s") == b"abc"
        assert kv.msetrange([("gone", 4, b""), ("s", 0, b"X")]) == 2
        assert not kv.exists("gone")
        assert kv.get("s") == b"Xbc"

    def test_setrange_negative_offset_rejected(self, kv):
        with pytest.raises(ValueError):
            kv.setrange("s", -1, b"x")

    def test_byte_range_wrong_type(self, kv):
        kv.rpush("l", b"a")
        with pytest.raises(WrongTypeError):
            kv.getrange("l", 0, -1)
        kv.set("n", 42)  # non-bytes string value
        with pytest.raises(WrongTypeError):
            kv.setrange("n", 0, b"x")

    def test_msetrange_is_one_command(self, kv):
        kv.mset({"a": b"aaaa", "b": b"bbbb"})
        before = kv.metrics.total_commands()
        assert kv.msetrange([("a", 0, b"XX"), ("b", 2, b"YY"),
                             ("c", 1, b"Z")]) == 3
        assert kv.metrics.total_commands() - before == 1
        assert kv.metrics.commands.get("MSETRANGE") == 1
        assert kv.get("a") == b"XXaa"
        assert kv.get("b") == b"bbYY"
        assert kv.get("c") == b"\x00Z"

    def test_byte_range_in_execute_batch(self, kv):
        res = kv.execute_batch([
            ("setrange", ("k", 0, b"abcdef"), {}),
            ("getrange", ("k", 1, 3), {}),
            ("msetrange", ([("k", 0, b"Z")],), {}),
            ("getrange", ("k", 0, -1), {}),
        ])
        assert all(ok for ok, _ in res)
        assert res[1][1] == b"bcd"
        assert res[3][1] == b"Zbcdef"

    def test_sharded_msetrange_routes_per_shard(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(4)])
        entries = [(f"key-{i}", 2, b"XY") for i in range(12)]
        assert sh.msetrange(entries) == 12
        for i in range(12):
            assert sh.get(f"key-{i}") == b"\x00\x00XY"
        # hash-tagged keys co-locate: the whole batch is ONE command on
        # one shard (the shared-array segment-flush fast path)
        tagged = [(f"{{res}}:seg:{i}", 0, b"ab") for i in range(8)]
        before = sh.metrics.commands.get("MSETRANGE", 0)
        sh.msetrange(tagged)
        assert sh.metrics.commands.get("MSETRANGE", 0) - before == 1

    def test_sharded_getrange_setrange_single_key_routing(self):
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(3)])
        sh.setrange("k", 0, b"hello")
        assert sh.getrange("k", 1, 3) == b"ell"
        assert sh.strlen("k") == 5


class TestStripedLocking:
    """PR 3: the striped store runs distinct-key commands in parallel
    while keeping per-key atomicity and batch transactionality."""

    def _two_stripe_keys(self, kv):
        """Two keys guaranteed to live on different stripes."""
        base = "stripe-a"
        other = next(k for k in (f"stripe-b{i}" for i in range(200))
                     if kv._stripe_index(k) != kv._stripe_index(base))
        return base, other

    def test_hash_tags_share_a_stripe(self, kv):
        assert kv._stripe_index("{u}:slots") == kv._stripe_index("{u}:items")

    def test_distinct_stripes_do_not_serialize(self, kv):
        """A held stripe lock blocks only its own stripe: ops on another
        stripe complete, ops on the same stripe wait."""
        k_held, k_other = self._two_stripe_keys(kv)
        same_stripe = next(
            k for k in (f"stripe-c{i}" for i in range(500))
            if kv._stripe_index(k) == kv._stripe_index(k_held))
        held = kv._stripe(k_held)
        done_other, done_same = [], []
        held.lock.acquire()
        try:
            t1 = threading.Thread(
                target=lambda: done_other.append(kv.incr(k_other)))
            t2 = threading.Thread(
                target=lambda: done_same.append(kv.incr(same_stripe)))
            t1.start()
            t2.start()
            t1.join(2)
            assert done_other == [1], "other-stripe op blocked by held stripe"
            time.sleep(0.05)
            assert done_same == [], "same-stripe op ran through a held lock"
        finally:
            held.lock.release()
        t2.join(2)
        assert done_same == [1]

    def test_same_key_ops_stay_atomic(self, kv):
        def bump():
            for _ in range(300):
                kv.incr("shared")
        threads = [threading.Thread(target=bump) for _ in range(8)]
        [t.start() for t in threads]
        [t.join(10) for t in threads]
        assert kv.get("shared") == 2400

    def test_distinct_key_ops_in_parallel_threads(self, kv):
        def bump(i):
            for _ in range(200):
                kv.incr(f"c{i}")
        threads = [threading.Thread(target=bump, args=(i,)) for i in range(8)]
        [t.start() for t in threads]
        [t.join(10) for t in threads]
        assert [kv.get(f"c{i}") for i in range(8)] == [200] * 8

    def test_blpop_wakes_across_stripe_traffic(self, kv):
        """A waiter wakes on its own key even while other stripes churn."""
        k_wait, k_noise = self._two_stripe_keys(kv)
        out = []
        t = threading.Thread(target=lambda: out.append(kv.blpop(k_wait, 5)))
        t.start()
        for _ in range(50):
            kv.rpush(k_noise, b"n")
            kv.lpop(k_noise)
        kv.rpush(k_wait, b"v")
        t.join(3)
        assert out == [(k_wait, b"v")]

    def test_multi_stripe_blpop_late_push_wakes(self, kv):
        k1, k2 = self._two_stripe_keys(kv)
        out = []
        t = threading.Thread(target=lambda: out.append(kv.blpop([k1, k2], 5)))
        t.start()
        time.sleep(0.05)
        kv.rpush(k2, b"m")
        t.join(3)
        assert out == [(k2, b"m")]

    def test_cross_stripe_blpop_rpush_atomic_and_wakes(self, kv):
        """The fused op works across stripes: late push wakes the waiter,
        the element moves atomically."""
        src, dst = self._two_stripe_keys(kv)
        out = []
        t = threading.Thread(
            target=lambda: out.append(kv.blpop_rpush(src, dst, b"tok", 5)))
        t.start()
        time.sleep(0.05)
        kv.rpush(src, b"item")
        t.join(3)
        assert out == [b"item"]
        assert kv.lrange(dst, 0, -1) == [b"tok"]
        assert not kv.exists(src)

    def test_cross_stripe_blpop_rpush_bad_dst_does_not_consume(self, kv):
        src, dst = self._two_stripe_keys(kv)
        kv.set(dst, b"not-a-list")
        kv.rpush(src, b"item")
        with pytest.raises(WrongTypeError):
            kv.blpop_rpush(src, dst, b"tok", 0.1)
        assert kv.lrange(src, 0, -1) == [b"item"]

    def test_execute_batch_remains_transactional(self, kv):
        """Writers batch two cross-stripe sets; a transactional reader can
        never observe them out of sync (take-all-stripes ordering)."""
        ka, kb = self._two_stripe_keys(kv)
        kv.mset({ka: 0, kb: 0})
        stop = threading.Event()
        torn = []

        def writer():
            v = 0
            while not stop.is_set():
                v += 1
                kv.execute_batch([("set", (ka, v), {}), ("set", (kb, v), {})])

        def reader():
            while not stop.is_set():
                a, b = kv.transaction(lambda s: (s.get(ka), s.get(kb)))
                if a != b:
                    torn.append((a, b))

        threads = [threading.Thread(target=writer),
                   threading.Thread(target=reader)]
        [t.start() for t in threads]
        time.sleep(0.4)
        stop.set()
        [t.join(5) for t in threads]
        assert torn == []

    def test_stress_mixed_ops_under_contention(self, kv):
        """Pipelines, singles and blocking ops interleaving across threads
        leave exact counts behind (no lost updates, no deadlock)."""
        n_threads, n_iter = 6, 60
        kv.rpush("{q}:slots", *([b"s"] * 4))

        def work(i):
            for j in range(n_iter):
                kv.incr("total")
                kv.incr(f"mine-{i}")
                assert kv.blpop_rpush("{q}:slots", "{q}:items", b"x", 5) is not None
                assert kv.blpop_rpush("{q}:items", "{q}:slots", b"s", 5) is not None
                with kv.pipeline() as p:
                    p.rpush(f"log-{i}", j)
                    p.llen(f"log-{i}")

        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(n_threads)]
        [t.start() for t in threads]
        [t.join(30) for t in threads]
        assert kv.get("total") == n_threads * n_iter
        assert all(kv.get(f"mine-{i}") == n_iter for i in range(n_threads))
        assert all(kv.llen(f"log-{i}") == n_iter for i in range(n_threads))
        assert kv.llen("{q}:slots") == 4
        assert not kv.exists("{q}:items")


class TestScatterLatency:
    """PR 3 satellite: concurrently-flushed per-shard batches bill ONE
    wall-clock RTT (max across shards), and Metrics reports fan-out."""

    def _sharded_with_latency(self, n=2):
        models = [LatencyModel(rtt_s=1e-3, scale=0) for _ in range(n)]
        sh = ShardedKVStore([KVStore(models[i], name=f"s{i}")
                             for i in range(n)])
        return sh, models

    def test_charge_scatter_bills_max_not_sum(self):
        m = LatencyModel(rtt_s=1e-3, bandwidth_bps=1e6, scale=0)
        m.charge_scatter([1000, 4000, 2000])
        assert m.charges == 1
        assert m.virtual_time == pytest.approx(1e-3 + 4000 / 1e6)

    def test_sharded_batch_one_rtt_across_shards(self):
        sh, models = self._sharded_with_latency()
        # keys on both shards (test_routing_consistent guarantees spread)
        cmds = [("set", (f"key-{i}", b"v"), {}) for i in range(16)]
        sh.execute_batch(cmds)
        assert all(s.dbsize() for s in sh.shards)  # batch hit both shards
        total_virtual = sum(m.virtual_time for m in models)
        total_charges = sum(m.charges for m in models)
        # one scatter charge at max cost, not one RTT per shard
        assert total_charges == 1
        assert total_virtual == pytest.approx(1e-3, rel=0.2)

    def test_fanout_recorded_in_metrics(self):
        sh, _ = self._sharded_with_latency()
        sh.execute_batch([("set", (f"key-{i}", b"v"), {}) for i in range(16)])
        fanout = sh.metrics.fanout
        assert fanout.get(2) == 1
        assert "fanout" in sh.shards[0].metrics.snapshot()

    def test_single_shard_batch_fanout_width_one(self):
        sh, models = self._sharded_with_latency()
        sh.execute_batch([("incr", ("{tag}:a",), {}),
                          ("incr", ("{tag}:b",), {})])
        assert sh.metrics.fanout == {1: 1}
        assert sum(m.charges for m in models) == 1

    def test_blocking_inside_transaction_forced_nonblocking(self, kv):
        """A blocking command inside transaction(fn) must not wait while
        holding every stripe (it would deadlock its own producers): like
        Redis scripts, it runs with timeout forced to 0."""
        t0 = time.monotonic()
        got = kv.transaction(lambda s: s.blpop("empty", 5))
        assert got is None
        assert time.monotonic() - t0 < 1.0
        assert kv.transaction(lambda s: s.blpop_rpush("e2", "d2", b"x", 5)) is None
        assert kv.transaction(lambda s: s.bllen("e3", 5)) == 0
        # and the store still works normally afterwards (tid restored)
        kv.rpush("q", b"v")
        assert kv.blpop("q", 1) == ("q", b"v")


class TestShardedBatchOrdering:
    def test_batch_reads_its_own_writes_across_router_commands(self):
        from repro.core.kvstore import KVStore, ShardedKVStore
        sh = ShardedKVStore([KVStore(name=f"s{i}") for i in range(3)])
        res = sh.execute_batch([
            ("set", ("a", 1), {}),
            ("set", ("b", 2), {}),
            ("mget", (["a", "b"],), {}),
            ("mset", ({"a": 10},), {}),
            ("get", ("a",), {}),
        ])
        assert [v for ok, v in res] == [True, True, [1, 2], 1, 10]
        assert all(ok for ok, _ in res)
