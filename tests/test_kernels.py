"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracles in kernels/ref.py, and gradient checks for the
custom-vjp flash attention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode, flash_decode_paged
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_scan import mamba2_chunked
from repro.kernels.rwkv6_scan import rwkv6_chunked

KEY = jax.random.PRNGKey(0)

ATTN_SWEEP = [
    # B, S, H, K, D, causal, dtype
    (2, 256, 8, 4, 64, True, jnp.float32),
    (1, 128, 4, 4, 32, False, jnp.float32),
    (2, 512, 8, 2, 128, True, jnp.float32),
    (1, 256, 4, 2, 112, True, jnp.float32),   # kimi head dim (pad to 128)
    (2, 256, 8, 4, 64, True, jnp.bfloat16),
    (1, 64, 2, 1, 64, True, jnp.float32),     # MHA==GQA(1)
]


def _qkv(B, S, H, K, D, dtype):
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (B, S, H, D), dtype),
            jax.random.normal(ks[1], (B, S, K, D), dtype),
            jax.random.normal(ks[2], (B, S, K, D), dtype))


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,H,K,D,causal,dtype", ATTN_SWEEP)
    def test_forward_matches_oracle(self, B, S, H, K, D, causal, dtype):
        q, k, v = _qkv(B, S, H, K, D, dtype)
        o_ref = ref.attention(q, k, v, causal=causal)
        o = flash_attention(q, k, v, causal=causal, block_q=128,
                            block_k=128, interpret=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.array(o, np.float32),
                                   np.array(o_ref, np.float32),
                                   atol=tol, rtol=tol)

    def test_blocked_ref_matches_oracle(self):
        for (B, S, H, K, D, causal, dtype) in ATTN_SWEEP[:3]:
            q, k, v = _qkv(B, S, H, K, D, dtype)
            o1 = ref.attention(q, k, v, causal=causal)
            o2 = ref.attention_blocked(q, k, v, causal=causal,
                                       block_q=64, block_k=64)
            np.testing.assert_allclose(np.array(o1, np.float32),
                                       np.array(o2, np.float32),
                                       atol=2e-5, rtol=2e-5)

    def test_gradients_match_oracle(self):
        B, S, H, K, D = 1, 128, 4, 2, 64
        q, k, v = _qkv(B, S, H, K, D, jnp.float32)

        def loss_flash(q, k, v):
            return (flash_attention(q, k, v, causal=True, block_q=64,
                                    block_k=64, interpret=True) ** 2).sum()

        def loss_ref(q, k, v):
            return (ref.attention(q, k, v, causal=True) ** 2).sum()

        g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.array(a), np.array(b),
                                       atol=5e-4, rtol=5e-4)

    def test_noncausal_gradients(self):
        B, S, H, K, D = 1, 128, 2, 2, 32
        q, k, v = _qkv(B, S, H, K, D, jnp.float32)
        g1 = jax.grad(lambda q: (flash_attention(
            q, k, v, causal=False, block_q=64, block_k=64,
            interpret=True) ** 2).sum())(q)
        g2 = jax.grad(lambda q: (ref.attention(
            q, k, v, causal=False) ** 2).sum())(q)
        np.testing.assert_allclose(np.array(g1), np.array(g2),
                                   atol=5e-4, rtol=5e-4)

    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(B=st.integers(1, 2), nheads=st.sampled_from([(4, 4), (8, 2)]),
           S=st.sampled_from([64, 128, 192]),
           D=st.sampled_from([32, 64]))
    def test_property_shapes(self, B, nheads, S, D):
        H, K = nheads
        q, k, v = _qkv(B, S, H, K, D, jnp.float32)
        o_ref = ref.attention(q, k, v, causal=True)
        o = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                            interpret=True)
        np.testing.assert_allclose(np.array(o), np.array(o_ref),
                                   atol=2e-5, rtol=2e-5)


class TestFlashDecode:
    @pytest.mark.parametrize("B,S,H,K,D", [
        (2, 256, 8, 4, 64), (3, 300, 4, 2, 128), (1, 128, 4, 4, 32),
        (2, 96, 8, 8, 64),
    ])
    def test_matches_oracle(self, B, S, H, K, D):
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
        kc = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
        vc = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
        lens = jax.random.randint(ks[3], (B,), 1, S + 1)
        o_ref = ref.decode_attention(q, kc, vc, lens)
        o = flash_decode(q, kc, vc, lens, block_k=64, interpret=True)
        np.testing.assert_allclose(np.array(o), np.array(o_ref),
                                   atol=2e-5, rtol=2e-5)

    def test_decode_equals_last_position_of_full(self):
        B, S, H, K, D = 2, 64, 8, 4, 32
        q, k, v = _qkv(B, S, H, K, D, jnp.float32)
        full = ref.attention(q, k, v, causal=True)
        dec = flash_decode(q[:, -1], k, v, jnp.full((B,), S, jnp.int32),
                           block_k=32, interpret=True)
        np.testing.assert_allclose(np.array(full[:, -1]), np.array(dec),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("lens", [
        [0, 0, 0],          # empty rows: defined as zero output
        [128, 128, 128],    # length == padded cache size
        [0, 37, 128],       # mixed, incl. non-block-aligned interior
        [1, 63, 65],        # straddling block_k=64 boundaries
    ])
    def test_ragged_lengths_match_oracle(self, lens):
        """Pallas and the jnp oracle agree on every ragged shape —
        including lengths of 0, where both are defined to emit zeros."""
        B, S, H, K, D = 3, 128, 8, 4, 32
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
        kc = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
        vc = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
        lengths = jnp.asarray(lens, jnp.int32)
        o_ref = ref.decode_attention(q, kc, vc, lengths)
        o = flash_decode(q, kc, vc, lengths, block_k=64, interpret=True)
        np.testing.assert_allclose(np.array(o), np.array(o_ref),
                                   atol=2e-5, rtol=2e-5)
        # zero-length rows must be exactly zero, not a uniform V average
        for b, ln in enumerate(lens):
            if ln == 0:
                assert not np.any(np.array(o[b]))


class TestPagedDecode:
    """Paged flash-decode vs the contiguous oracle: scatter a contiguous
    cache into a randomly-permuted page slab and the outputs must match
    bit-for-tolerance (page indirection is pure data movement)."""

    @staticmethod
    def _paged_from_contiguous(kc, vc, page, n_pages, seed=0):
        B, S, K, D = kc.shape
        M = S // page
        rng = np.random.default_rng(seed)
        perm = rng.permutation(np.arange(1, n_pages))[:B * M]
        table = perm.reshape(B, M).astype(np.int32)
        k_pages = np.zeros((n_pages, page, K, D), np.float32)
        v_pages = np.zeros((n_pages, page, K, D), np.float32)
        for b in range(B):
            for m in range(M):
                k_pages[table[b, m]] = np.asarray(kc[b, m * page:(m + 1) * page])
                v_pages[table[b, m]] = np.asarray(vc[b, m * page:(m + 1) * page])
        return jnp.asarray(k_pages), jnp.asarray(v_pages), jnp.asarray(table)

    @pytest.mark.parametrize("lens", [
        [0, 37, 128], [128, 1, 64], [16, 17, 15],
    ])
    def test_paged_matches_contiguous(self, lens):
        B, S, H, K, D = 3, 128, 8, 4, 32
        page, n_pages = 16, 32
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
        kc = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
        vc = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
        lengths = jnp.asarray(lens, jnp.int32)
        kp, vp, table = self._paged_from_contiguous(kc, vc, page, n_pages)
        o_ref = ref.decode_attention(q, kc, vc, lengths)
        o_pallas = flash_decode_paged(q, kp, vp, table, lengths,
                                      interpret=True)
        o_jnp = ref.paged_decode_attention(q, kp, vp, table, lengths)
        np.testing.assert_allclose(np.array(o_pallas), np.array(o_ref),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(np.array(o_jnp), np.array(o_ref),
                                   atol=2e-5, rtol=2e-5)

    def test_gather_round_trip(self):
        """ref.paged gather reconstructs the contiguous cache exactly:
        scatter -> gather is the identity on the valid prefix."""
        B, S, K, D = 2, 64, 2, 16
        page = 8
        kc = jax.random.normal(KEY, (B, S, K, D), jnp.float32)
        kp, _, table = self._paged_from_contiguous(kc, kc, page, 24, seed=3)
        gathered = kp[table].reshape(B, S, K, D)
        np.testing.assert_array_equal(np.array(gathered), np.array(kc))


class TestRWKV6:
    @pytest.mark.parametrize("B,S,H,D,chunk", [
        (2, 64, 4, 16, 16), (1, 128, 2, 32, 32), (2, 96, 3, 16, 16),
    ])
    def test_matches_oracle(self, B, S, H, D, chunk):
        ks = jax.random.split(KEY, 5)
        r = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, H, D))
        v = jax.random.normal(ks[2], (B, S, H, D))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, D))) * 0.5 + 0.45
        u = jax.random.normal(ks[4], (H, D)) * 0.1
        st_ = jax.random.normal(KEY, (B, H, D, D)) * 0.1
        o_ref, s_ref = ref.rwkv6_scan(r, k, v, w, u, st_)
        o, s = rwkv6_chunked(r, k, v, w, u, st_, chunk=chunk, interpret=True)
        np.testing.assert_allclose(np.array(o), np.array(o_ref),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.array(s), np.array(s_ref),
                                   atol=1e-4, rtol=1e-4)

    def test_state_carrying_splits_sequence(self):
        """scan(S) == scan(S/2) ∘ scan(S/2) with carried state."""
        B, S, H, D = 1, 64, 2, 16
        ks = jax.random.split(KEY, 5)
        r, k, v = (jax.random.normal(ks[i], (B, S, H, D)) for i in range(3))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, D)))
        u = jax.random.normal(ks[4], (H, D)) * 0.1
        o_full, s_full = ref.rwkv6_scan(r, k, v, w, u)
        o1, s1 = ref.rwkv6_scan(r[:, :32], k[:, :32], v[:, :32], w[:, :32], u)
        o2, s2 = ref.rwkv6_scan(r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:],
                                u, s1)
        np.testing.assert_allclose(np.array(o_full),
                                   np.concatenate([o1, o2], 1),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.array(s_full), np.array(s2),
                                   atol=1e-4, rtol=1e-4)


class TestMamba2:
    @pytest.mark.parametrize("B,S,H,P,N,chunk", [
        (2, 64, 4, 16, 16, 16), (1, 128, 2, 32, 16, 32),
        (2, 96, 3, 16, 32, 16),
    ])
    def test_matches_oracle(self, B, S, H, P, N, chunk):
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        a = -jnp.abs(jax.random.normal(ks[2], (H,)))
        b = jax.random.normal(ks[3], (B, S, N))
        c = jax.random.normal(ks[4], (B, S, N))
        st_ = jax.random.normal(KEY, (B, H, P, N)) * 0.1
        y_ref, h_ref = ref.mamba2_scan(x, dt, a, b, c, st_)
        y, h = mamba2_chunked(x, dt, a, b, c, st_, chunk=chunk,
                              interpret=True)
        np.testing.assert_allclose(np.array(y), np.array(y_ref),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.array(h), np.array(h_ref),
                                   atol=1e-4, rtol=1e-4)

    def test_chunked_ref_bptt_matches_plain_scan(self):
        """The remat-chunked ref recurrence must not change gradients."""
        B, S, H, P, N = 1, 128, 2, 8, 8
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        a = -jnp.abs(jax.random.normal(ks[2], (H,)))
        b = jax.random.normal(ks[3], (B, S, N))
        c = jax.random.normal(ks[4], (B, S, N))

        def loss(x):
            y, _ = ref.mamba2_scan(x, dt, a, b, c)
            return (y ** 2).sum()
        g = jax.grad(loss)(x)
        assert bool(jnp.isfinite(g).all())
