"""THE paper claim: identical application code, stdlib vs transparent.

Each application below is written once against the module-level API and
executed twice — with ``multiprocessing`` (threads stand-in: we use the
stdlib ``multiprocessing.dummy`` to stay 1-vCPU-friendly and avoid fork
overhead in CI) and with ``repro.core.mp`` — asserting identical results.
"""

import multiprocessing.dummy as stdlib_mp

import numpy as np
import pytest

from repro.core import mp as serverless_mp


def app_pool_pipeline(mp):
    """map -> starmap -> apply_async chain."""
    with mp.Pool(4) as pool:
        squares = pool.map(lambda x: x * x, range(10))
        sums = pool.starmap(lambda a, b: a + b, zip(squares, range(10)))
        final = pool.apply_async(lambda xs: sum(xs), (sums,)).get(10)
    return final


def app_producer_consumer(mp):
    q = mp.Queue()
    out = mp.Queue()

    def consumer(q, out):
        total = 0
        while True:
            item = q.get()
            if item is None:
                out.put(total)
                return
            total += item

    procs = [mp.Process(target=consumer, args=(q, out)) for _ in range(2)]
    [p.start() for p in procs]
    for i in range(50):
        q.put(i)
    q.put(None)
    q.put(None)
    totals = [out.get(timeout=10) for _ in range(2)]
    [p.join(10) for p in procs]
    return sum(totals)


def app_locked_counter(mp):
    lock = mp.Lock()
    val = mp.Value("i", 0)

    def bump(lock, val):
        for _ in range(25):
            with lock:
                val.value += 1

    procs = [mp.Process(target=bump, args=(lock, val)) for _ in range(4)]
    [p.start() for p in procs]
    [p.join(10) for p in procs]
    return val.value


APPS = [app_pool_pipeline, app_producer_consumer, app_locked_counter]


@pytest.mark.parametrize("app", APPS, ids=lambda f: f.__name__)
def test_same_code_same_result(app):
    assert app(serverless_mp) == app(stdlib_mp)


@pytest.fixture(scope="module")
def kv_cluster():
    """A real multi-process sharded serving plane (PR 3): each shard is
    its own OS process reached over TCP."""
    from repro.core.kvcluster import KVCluster
    with KVCluster(shards=2) as cl:
        yield cl


@pytest.mark.parametrize("app", APPS, ids=lambda f: f.__name__)
def test_same_code_same_result_over_cluster(app, kv_cluster):
    """THE scaling transparency claim: the identical application code
    also runs unchanged when the store is a sharded multi-process
    cluster instead of an in-process KVStore — queues, locks, shared
    values, and the Pool job queue all hash-route through ClusterClient
    without the application (or the IPC layer) knowing."""
    from repro.core import Session, set_session
    client = kv_cluster.client()
    try:
        set_session(Session(store=client))
        assert app(serverless_mp) == app(stdlib_mp)
    finally:
        from repro.core import reset_session
        reset_session()
        client.close()


def test_pipe_api_parity():
    """send/recv/poll protocol matches stdlib semantics."""
    import multiprocessing as std

    def drive(mp_mod, use_std):
        a, b = mp_mod.Pipe()
        a.send({"x": [1, 2]})
        got = b.recv()
        assert b.poll(0.01) is False
        b.send("reply")
        got2 = a.recv()
        return got, got2

    assert drive(serverless_mp, False) == ({"x": [1, 2]}, "reply")


def test_array_value_parity_with_stdlib_semantics():
    arr = serverless_mp.Array("i", [1, 2, 3])
    assert list(arr) == [1, 2, 3]
    arr[1] = 9
    assert arr[:] == [1, 9, 3]
    v = serverless_mp.Value("d", 0.5)
    v.value *= 4
    assert v.value == 2.0
