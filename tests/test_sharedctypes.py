"""ISSUE 2 coverage: block-backed shared arrays + lock-scoped caching.

- negative-step and strided slice reads/writes under both layouts
- Value/Array round-tripping through a worker under both layouts
- ctypes-faithful typecode "c" casting
- the block layout's command-count cost model (slices are O(segments),
  lock scopes absorb element traffic, release flushes once)
- multiprocessing-compatible TimeoutError
"""

import pytest

from repro.core import get_session, mp, reset_session
from repro.core.sharedctypes import SEGMENT_BYTES, _cast


pytestmark = pytest.mark.usefixtures("fresh_session")

LAYOUTS = ["block", "list"]


class TestSlices:
    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_negative_step_reads(self, layout):
        ref = list(range(20))
        arr = mp.Array("i", ref, layout=layout)
        for sl in (slice(None, None, -1), slice(15, 3, -2), slice(18, None, -3),
                   slice(5, 5, -1), slice(3, 10, -1)):
            assert arr[sl] == ref[sl], sl

    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_strided_reads(self, layout):
        ref = [float(i) for i in range(31)]
        arr = mp.Array("d", ref, layout=layout)
        for sl in (slice(None, None, 2), slice(1, 25, 3), slice(0, 0),
                   slice(30, None), slice(-7, None, 2)):
            assert arr[sl] == ref[sl], sl

    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_negative_step_and_strided_writes(self, layout):
        ref = list(range(20))
        arr = mp.Array("q", ref, layout=layout)
        arr[::-2] = list(range(10))
        ref[::-2] = list(range(10))
        assert arr[:] == ref
        arr[3:15:3] = [100, 200, 300, 400]
        ref[3:15:3] = [100, 200, 300, 400]
        assert arr[:] == ref
        arr[17:2:-5] = [-1, -2, -3]
        ref[17:2:-5] = [-1, -2, -3]
        assert arr[:] == ref

    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_slice_assignment_length_mismatch(self, layout):
        arr = mp.Array("i", 5, layout=layout)
        with pytest.raises(ValueError):
            arr[1:4] = [1, 2]

    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_long_typecode_holds_64bit_values(self, layout):
        # ctypes c_long is 8 bytes on LP64; the packed layout must not
        # narrow it to the 4-byte standard struct size
        arr = mp.Array("l", [2 ** 40, -(2 ** 40)], layout=layout)
        assert arr[:] == [2 ** 40, -(2 ** 40)]
        arr[0] = 2 ** 62
        assert arr[0] == 2 ** 62
        ua = mp.Array("L", [2 ** 63], layout=layout)
        assert ua[0] == 2 ** 63

    def test_multi_segment_array(self):
        # force > 1 segment: 4096/8 = 512 doubles per segment
        n = SEGMENT_BYTES // 8 * 2 + 17
        ref = [float(i) for i in range(n)]
        arr = mp.Array("d", ref)
        assert len(arr) == n
        assert arr[:] == ref
        assert arr[510:515] == ref[510:515]  # straddles the seg boundary
        arr[510:515] = [9.0] * 5
        ref[510:515] = [9.0] * 5
        assert arr[:] == ref
        assert arr[::511] == ref[::511]


class TestWorkerRoundTrip:
    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_array_through_worker(self, layout):
        arr = mp.Array("d", [0.0] * 6, layout=layout)

        def fill(arr, lo, hi):
            with arr.get_lock():
                for i in range(lo, hi):
                    arr[i] = float(i * i)
        ps = [mp.Process(target=fill, args=(arr, 0, 3)),
              mp.Process(target=fill, args=(arr, 3, 6))]
        [p.start() for p in ps]
        [p.join(10) for p in ps]
        assert arr[:] == [float(i * i) for i in range(6)]

    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_value_through_worker(self, layout):
        val = mp.Value("i", 0, layout=layout)

        def bump(val):
            for _ in range(10):
                with val.get_lock():
                    val.value += 1
        ps = [mp.Process(target=bump, args=(val,)) for _ in range(3)]
        [p.start() for p in ps]
        [p.join(10) for p in ps]
        assert val.value == 30

    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_rawarray_through_worker(self, layout):
        arr = mp.RawArray("i", [0] * 4, layout=layout)

        def fill(arr):
            arr[:] = [1, 2, 3, 4]
        p = mp.Process(target=fill, args=(arr,))
        p.start()
        p.join(10)
        assert arr[:] == [1, 2, 3, 4]


class TestCharTypecode:
    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_int_and_bytes_accepted(self, layout):
        arr = mp.Array("c", 3, layout=layout)
        arr[0] = 65           # ctypes: c_char(65) == b"A"
        arr[1] = b"Z"
        arr[2] = bytearray(b"!")
        assert arr[:] == [b"A", b"Z", b"!"]

    def test_bad_values_rejected(self):
        arr = mp.Array("c", 2)
        for bad in (b"xy", b"", "A", 1.5, 256, -1):
            with pytest.raises(TypeError):
                arr[0] = bad

    def test_cast_directly(self):
        assert _cast("c", 65) == b"A"
        assert _cast("c", b"B") == b"B"
        with pytest.raises(TypeError):
            _cast("c", b"many")


class TestBlockCostModel:
    def test_slice_read_is_one_command(self):
        arr = mp.Array("d", [1.0] * 100)
        store = get_session().store
        before = store.metrics.total_commands()
        assert arr[10:90] == [1.0] * 80
        assert store.metrics.total_commands() - before == 1  # one MGET

    def test_slice_write_is_one_command(self):
        arr = mp.Array("d", [0.0] * 100)
        store = get_session().store
        before = store.metrics.total_commands()
        arr[10:90] = [2.0] * 80
        assert store.metrics.total_commands() - before == 1  # one MSETRANGE
        assert arr[10:90] == [2.0] * 80

    def test_lock_scope_absorbs_element_traffic(self):
        arr = mp.Array("d", [0.0] * 256)  # single segment
        store = get_session().store
        with arr.get_lock():
            before = store.metrics.total_commands()
            for i in range(256):
                arr[i] = float(i)
            _ = [arr[i] for i in range(256)]
            in_scope = store.metrics.total_commands() - before
        # 512 element accesses, ONE segment fetch
        assert in_scope == 1, in_scope
        assert arr[:] == [float(i) for i in range(256)]

    def test_release_flushes_dirty_segments_once(self):
        arr = mp.Array("d", [0.0] * 1200)  # 3 segments
        store = get_session().store
        with arr.get_lock():
            arr[:] = [float(i) for i in range(1200)]
            flushes_before = store.metrics.commands.get("MSETRANGE", 0)
        assert store.metrics.commands.get("MSETRANGE", 0) - flushes_before == 1
        assert arr[0] == 0.0 and arr[1199] == 1199.0

    def test_acquire_invalidates_stale_cache(self):
        arr = mp.Array("i", [0] * 8)
        import pickle
        other = pickle.loads(pickle.dumps(arr))  # second proxy, own cache
        with arr.get_lock():
            assert arr[3] == 0  # populates arr's cache
        with other.get_lock():
            other[3] = 42       # flushed at release
        with arr.get_lock():
            assert arr[3] == 42  # reacquire must not serve the stale 0

    def test_dirty_writes_invisible_until_release(self):
        arr = mp.Array("i", [0] * 4)
        import pickle
        other = pickle.loads(pickle.dumps(arr))
        arr.get_lock().acquire()
        arr[0] = 7
        # "other" reads the store directly (it does not hold the lock):
        # the write is still write-combined client-side
        assert other._backing.read_one(0) == 0
        arr.get_lock().release()
        assert other[0] == 7  # flush published it

    def test_sibling_thread_without_lock_bypasses_cache(self):
        # A second thread of the SAME process using the same proxy without
        # holding the lock must hit the store directly: its writes land
        # (not diverted into the holder's scope) and nothing crashes.
        import threading
        arr = mp.Array("i", [0] * 8)
        store = get_session().store
        entered = threading.Event()
        done = threading.Event()

        def holder():
            with arr.get_lock():
                arr[0] = 1
                entered.set()
                done.wait(5)

        t = threading.Thread(target=holder)
        t.start()
        assert entered.wait(5)
        arr[7] = 42  # lock-free sibling write goes straight to the store
        raw = store.getrange(arr._backing._seg_key(0), 28, 31)
        assert int.from_bytes(raw, "little") == 42
        done.set()
        t.join(5)
        assert arr[7] == 42 and arr[0] == 1

    def test_value_under_lock(self):
        val = mp.Value("q", 5)
        store = get_session().store
        with val.get_lock():
            before = store.metrics.total_commands()
            for _ in range(50):
                val.value += 1
            in_scope = store.metrics.total_commands() - before
        assert in_scope == 1  # one fetch; 100 accesses served locally
        assert val.value == 55

    def test_failed_flush_still_releases_lock(self):
        arr = mp.Array("i", [0] * 4)
        store = get_session().store
        orig = store.msetrange
        with pytest.raises(RuntimeError):
            with arr.get_lock():
                arr[0] = 1
                store.msetrange = lambda entries: (_ for _ in ()).throw(
                    RuntimeError("store down"))
        store.msetrange = orig
        # the flush failed (write lost, error surfaced) but the lock must
        # not stay permanently held
        assert arr.get_lock().acquire(block=False)
        arr.get_lock().release()

    def test_lock_false_has_no_cache(self):
        arr = mp.Array("i", [1, 2, 3], lock=False)
        with pytest.raises(AttributeError):
            arr.get_lock()
        assert arr[:] == [1, 2, 3]

    def test_refcount_cleanup_removes_segments(self):
        store = get_session().store
        arr = mp.Array("d", [1.0] * 1200, ttl_s=0)
        seg_keys = arr._backing.kv_keys()
        assert all(store.exists(k) for k in seg_keys)
        arr.close()
        if arr._lock_obj is not None:
            arr._lock_obj.close()
        assert not any(store.exists(k) for k in seg_keys)


class TestTimeoutError:
    def test_distinct_from_builtin(self):
        assert mp.TimeoutError is not TimeoutError
        assert not issubclass(mp.TimeoutError, TimeoutError)
        assert issubclass(mp.TimeoutError, mp.ProcessError)

    def test_pool_get_raises_mp_timeout(self):
        import time
        with mp.Pool(1) as pool:
            res = pool.apply_async(time.sleep, (1,))
            with pytest.raises(mp.TimeoutError):
                res.get(timeout=0.05)

    def test_connection_and_join_raise_mp_timeout(self):
        a, b = mp.Pipe()
        with pytest.raises(mp.TimeoutError):
            a.recv_bytes(timeout=0.02)
        q = mp.JoinableQueue()
        q.put("x")
        with pytest.raises(mp.TimeoutError):
            q.join(timeout=0.02)
