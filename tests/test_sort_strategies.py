"""Paper Table 3 correctness: all three sort strategies produce sorted
output, and their KV command profiles have the paper's ordering
(inplace >> localcopy > message)."""

import numpy as np

from benchmarks.bench_sort import _run_strategy
from repro.core import get_session


def test_all_strategies_sort_correctly():
    rng = np.random.default_rng(0)
    data = rng.random(200).tolist()
    expected = sorted(data)
    for strategy in ("inplace", "localcopy", "message"):
        assert _run_strategy(strategy, list(data), 4) == expected, strategy


def test_command_count_ordering_matches_paper():
    rng = np.random.default_rng(1)
    data = rng.random(120).tolist()
    counts = {}
    for strategy in ("inplace", "localcopy", "message"):
        store = get_session().store
        before = store.metrics.total_commands()
        _run_strategy(strategy, list(data), 4)
        counts[strategy] = store.metrics.total_commands() - before
    # Table 3's lesson in command-space
    assert counts["inplace"] > 10 * counts["localcopy"]
    assert counts["message"] < counts["localcopy"]
