"""Paper Table 3 correctness: all three sort strategies produce sorted
output under both Array layouts; the paper-faithful ``layout="list"``
keeps Table 3's command-count ordering (inplace >> localcopy > message);
and the PR's block layout + lock-scoped cache flips the in-place verdict
with >= 50x fewer KV commands at the same size."""

import numpy as np
import pytest

from benchmarks.bench_sort import _run_strategy
from repro.core import get_session, reset_session


@pytest.mark.parametrize("layout", ["block", "list"])
def test_all_strategies_sort_correctly(layout):
    rng = np.random.default_rng(0)
    data = rng.random(200).tolist()
    expected = sorted(data)
    for strategy in ("inplace", "localcopy", "message"):
        assert _run_strategy(strategy, list(data), 4,
                             layout=layout) == expected, (strategy, layout)


def test_command_count_ordering_matches_paper():
    # The faithful one-element-per-index layout reproduces Table 3.
    rng = np.random.default_rng(1)
    data = rng.random(120).tolist()
    counts = {}
    for strategy in ("inplace", "localcopy", "message"):
        store = get_session().store
        before = store.metrics.total_commands()
        _run_strategy(strategy, list(data), 4, layout="list")
        counts[strategy] = store.metrics.total_commands() - before
    # Table 3's lesson in command-space
    assert counts["inplace"] > 10 * counts["localcopy"]
    assert counts["message"] < counts["localcopy"]


def test_block_layout_makes_inplace_win():
    # ISSUE 2 acceptance: the paper's losing workload, >= 50x fewer KV
    # commands under layout="block" than layout="list" at the same size.
    rng = np.random.default_rng(2)
    data = rng.random(240).tolist()
    expected = sorted(data)
    counts = {}
    for layout in ("block", "list"):
        reset_session()
        store = get_session().store
        before = store.metrics.total_commands()
        assert _run_strategy("inplace", list(data), 4,
                             layout=layout) == expected
        counts[layout] = store.metrics.total_commands() - before
    assert counts["list"] >= 50 * counts["block"], counts
