"""Fault-tolerant task plane (PR 8): worker supervision, lease-based
retries, typed worker-loss failures, straggler speculation, and the
zero-cost-when-off contract — all over the fast in-process threads
backend (the full OS-process kill matrix lives in ``tests/chaos.py``)."""

import pickle
import time

import pytest

from repro.core import get_session, mp
from repro.core.errors import ProcessError, WorkerLostError
from repro.core.executor import FunctionExecutor
from repro.core.kvstore import LEASE_REGISTRY_KEY
from repro.core.pool import Pool, _kill_flag_matches


def _die(x):
    # SystemExit escapes the per-item error wrapper and kills the worker
    # (the threads-backend analogue of a SIGKILLed container)
    raise SystemExit(f"worker killed by task {x}")


class TestWorkerLoss:
    def test_retry_recovers_from_one_worker_death(self):
        """A task that kills its first worker succeeds on a respawned one
        if its second attempt behaves."""
        with mp.Pool(2, max_retries=2, lease_ttl_s=0.4) as p:

            def flaky(x):
                if x == 3 and get_session().store.incr("ft:runs") == 1:
                    raise SystemExit("first attempt dies")
                return x * 2

            assert p.map(flaky, range(8), chunksize=1) == \
                [x * 2 for x in range(8)]
            # death detection is asynchronous (a grace period filters
            # shutdown races), so poll the counters briefly
            deadline = time.monotonic() + 10
            while time.monotonic() < deadline:
                stats = p.fault_stats()
                if stats["workers_lost"] and stats["workers_respawned"]:
                    break
                time.sleep(0.05)
            assert stats["workers_lost"] >= 1
            assert stats["workers_respawned"] >= 1
            assert stats["leases_requeued"] >= 1
            assert stats["tasks_dead_lettered"] == 0

    def test_max_retries_exceeded_raises_typed_error(self):
        """A task that kills EVERY worker it lands on settles as a typed
        WorkerLostError carrying task id, attempt count, and last worker
        — within bounded time, never a hang."""
        with mp.Pool(2, max_retries=1, lease_ttl_s=0.4) as p:
            res = p.map_async(_die, [1])
            t0 = time.monotonic()
            with pytest.raises(WorkerLostError) as ei:
                res.get(timeout=30)
            assert time.monotonic() - t0 < 20
            err = ei.value
            assert err.task_id == "j0.0"
            assert err.attempts == 2  # initial + 1 retry
            assert err.last_worker is not None
            assert isinstance(err, ProcessError)
            assert p.fault_stats()["tasks_dead_lettered"] == 1

    def test_all_workers_dead_fails_fast_without_ft(self):
        """Satellite S1: with fault tolerance OFF (default), a map whose
        workers all died must fail typed, not hang forever."""
        with mp.Pool(2) as p:
            res = p.map_async(_die, range(4), chunksize=1)
            with pytest.raises(WorkerLostError, match="all pool workers"):
                res.get(timeout=30)
            assert p.fault_stats()["all_dead_failures"] == 1

    def test_all_workers_dead_unblocks_imap(self):
        with mp.Pool(2) as p:
            with pytest.raises(WorkerLostError):
                list(p.imap(_die, range(4), chunksize=1))

    def test_worker_lost_error_pickles(self):
        err = WorkerLostError("gone", task_id="j1.2", attempts=3,
                              last_worker=7)
        err2 = pickle.loads(pickle.dumps(err))
        assert isinstance(err2, WorkerLostError)
        assert (err2.task_id, err2.attempts, err2.last_worker) == \
            ("j1.2", 3, 7)


class TestSpeculation:
    def test_straggler_is_speculated_and_first_settle_wins(self):
        """A one-off straggler (slow first attempt, fast duplicate) must
        not gate the map on its full sleep; the duplicate's settle wins
        and the late original is discarded by fencing."""
        with mp.Pool(3, speculation_factor=3.0, lease_ttl_s=10.0) as p:

            def straggle(x):
                if x == 5 and get_session().store.incr("spec:runs") == 1:
                    time.sleep(4.0)  # only the FIRST attempt straggles
                else:
                    time.sleep(0.05)
                return x + 100

            t0 = time.monotonic()
            got = p.map(straggle, range(12), chunksize=1)
            elapsed = time.monotonic() - t0
            assert got == [x + 100 for x in range(12)]
            assert elapsed < 3.5  # did not wait out the 4 s straggler
            assert p.fault_stats()["speculative_tasks"] >= 1


class TestZeroCostWhenOff:
    def test_default_pool_issues_no_lease_commands(self):
        """With FT off (the default) the hot path is wire-identical to
        PR 1-6: no lease commands, no registry writes, no heartbeats."""
        with mp.Pool(2) as p:
            p.map(lambda x: x, range(8), chunksize=2)
            cmds = get_session().store.metrics.commands
            assert "BLPOPLEASE" not in cmds
            assert "LEASERENEW" not in cmds and "LEASERELEASE" not in cmds
            assert "LEASEREAP" not in cmds
            assert not get_session().store.exists(LEASE_REGISTRY_KEY)

    def test_default_pool_has_no_elastic_footprint(self):
        """PR 9 zero-cost extension: with ``elastic`` unset and defaults
        off, the job path stays byte-identical — no drain flags are ever
        written or polled, no controller exists, resize still shrinks by
        poison pill, and backlog() on the idle pool adds no KV command."""
        with mp.Pool(2) as p:
            assert p._drain_enabled is False
            assert p._elastic_controller is None
            p.map(lambda x: x, range(8), chunksize=2)
            store = get_session().store
            cmds = store.metrics.commands
            llen0, hlen0 = cmds.get("LLEN", 0), cmds.get("HLEN", 0)
            assert p.backlog() == 0          # client-side short-circuit
            assert cmds.get("LLEN", 0) == llen0
            assert cmds.get("HLEN", 0) == hlen0
            assert not any(":drain:" in k for k in store.keys("*"))
            p.resize(1)
            deadline = time.monotonic() + 5
            while p.n_workers > 1 and time.monotonic() < deadline:
                time.sleep(0.02)
            fs = p.fault_stats()
            assert fs["workers_drained"] == 0 and fs["draining_workers"] == 0
            assert not any(":drain:" in k for k in store.keys("*"))

    def test_ft_pool_registers_and_unregisters_reaper_entry(self):
        st = get_session().store
        p = mp.Pool(2, max_retries=1)
        try:
            assert st.hlen(LEASE_REGISTRY_KEY) == 1
            (spec,) = st.hgetall(LEASE_REGISTRY_KEY).values()
            assert spec[1] == 1  # max_retries rides the registration
        finally:
            p.close()
            p.join()
        assert not st.exists(LEASE_REGISTRY_KEY)


class TestTerminateGeneration:
    def test_kill_flag_matching(self):
        assert _kill_flag_matches(None, "u1") is False
        assert _kill_flag_matches("u1", "u1") is True
        assert _kill_flag_matches(b"u1", "u1") is True
        assert _kill_flag_matches("u2", "u1") is False
        assert _kill_flag_matches(1, "u1") is True  # legacy kill-all flag

    def test_terminate_then_new_pool_works(self):
        """Satellite S6: a terminated pool's kill flag is fenced by pool
        generation — a fresh pool created right after (even one reading a
        stale flag) keeps its workers and serves maps."""
        p1 = mp.Pool(2)
        p1.terminate()
        p1.join(timeout=10)
        with mp.Pool(2) as p2:
            # simulate the stale-flag hazard explicitly: p1's uid under
            # p2's kill key must NOT kill p2's generation of workers
            get_session().store.set(p2._kill_key, p1.uid, ex=60)
            assert p2.map(lambda x: x + 1, range(6)) == list(range(1, 7))
            assert p2.n_workers == 2


class TestExecutorDeadline:
    def test_get_result_timeout_is_shared_not_per_future(self):
        """Satellite S2: the gather deadline bounds TOTAL wall-clock; N
        unfinished futures must not cost up to N x timeout."""
        ex = FunctionExecutor()
        futs = [ex.call_async(time.sleep, (5,)) for _ in range(4)]
        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            ex.get_result(futs, timeout=0.5)
        assert time.monotonic() - t0 < 2.0  # not 4 x 0.5 + slop per future
        ex.shutdown(wait=False)
