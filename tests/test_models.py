"""Per-architecture smoke tests (REQUIRED by the assignment): reduced
same-family config, one forward + one train step on CPU, asserting output
shapes and finiteness. Plus decode==teacher-forcing consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import init_train_state, make_train_step

RNG = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=16):
    b = {"tokens": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(
            RNG, (B, cfg.num_prefix_embeddings, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(RNG, (B, S, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        batch = make_batch(cfg)
        logits, aux = model.forward(params, batch)
        assert logits.shape == (2, 16, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        assert bool(jnp.isfinite(aux))

    def test_one_train_step(self, arch):
        cfg = smoke_config(arch)
        model = build_model(cfg)
        opt = AdamWConfig(lr=1e-3)
        state = init_train_state(model, opt, jax.random.PRNGKey(1))
        step = jax.jit(make_train_step(model, opt))
        batch = make_batch(cfg)
        new_state, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        assert metrics["grad_norm"] > 0
        # params actually moved
        moved = jax.tree.map(
            lambda a, b: bool(jnp.any(a != b)),
            state["params"], new_state["params"])
        assert any(jax.tree.leaves(moved))

    def test_full_config_is_published_shape(self, arch):
        cfg = get_config(arch)
        total, active = cfg.param_counts()
        assert active <= total
        assert total > 1e8  # every assigned arch is at least 100M-scale
        if arch == "kimi-k2-1t-a32b":
            assert 0.8e12 < total < 1.3e12      # ~1T
            assert 25e9 < active < 40e9         # ~32B active
        if arch == "llama3-8b":
            assert 7e9 < total < 9.5e9


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forcing(arch):
    cfg = smoke_config(arch)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=float(cfg.num_experts))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    batch = dict(make_batch(cfg, B, S), tokens=toks, labels=toks)
    full_logits, _ = model.forward(params, batch)

    if cfg.family == "encdec":
        logits0, cache = model.prefill(
            params, {"frames": batch["frames"], "tokens": toks}, max_len=S + 4)
        np.testing.assert_allclose(np.array(logits0),
                                   np.array(full_logits[:, 0]),
                                   atol=3e-3, rtol=3e-3)
        for t in range(1, S):
            lg, cache = model.decode(params, cache, toks[:, t])
            np.testing.assert_allclose(np.array(lg),
                                       np.array(full_logits[:, t]),
                                       atol=3e-3, rtol=3e-3)
        return

    Sp = S - 4
    pre = dict(batch, tokens=toks[:, :Sp])
    pre.pop("labels")
    logits, cache = model.prefill(params, pre,
                                  max_len=S + cfg.num_prefix_embeddings + 4)
    np.testing.assert_allclose(np.array(logits),
                               np.array(full_logits[:, Sp - 1]),
                               atol=3e-3, rtol=3e-3)
    for t in range(Sp, S):
        logits, cache = model.decode(params, cache, toks[:, t])
        np.testing.assert_allclose(np.array(logits),
                                   np.array(full_logits[:, t]),
                                   atol=3e-3, rtol=3e-3)


def test_unrolled_matches_scanned():
    """scan_layers=False (dry-run analysis mode) is numerically identical."""
    cfg = smoke_config("llama3-8b")
    model_s = build_model(cfg)
    model_u = build_model(cfg.replace(scan_layers=False))
    params = model_s.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg)
    a, _ = model_s.forward(params, batch)
    b, _ = model_u.forward(params, batch)
    np.testing.assert_allclose(np.array(a), np.array(b), atol=1e-5, rtol=1e-5)


def test_microbatched_grads_match_full_batch():
    cfg = smoke_config("qwen1.5-0.5b")
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, grad_clip_norm=None)
    state = init_train_state(model, opt, jax.random.PRNGKey(1))
    batch = make_batch(cfg, B=4, S=16)
    s1, m1 = jax.jit(make_train_step(model, opt))(
        jax.tree.map(jnp.copy, state), batch)
    s2, m2 = jax.jit(make_train_step(model, opt, num_microbatches=2))(
        jax.tree.map(jnp.copy, state), batch)
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]),
                               rtol=1e-4)
    w1 = jax.tree.leaves(s1["params"])[0]
    w2 = jax.tree.leaves(s2["params"])[0]
    np.testing.assert_allclose(np.array(w1), np.array(w2), atol=1e-5)


def test_loss_decreases_when_training():
    cfg = smoke_config("qwen1.5-0.5b")
    model = build_model(cfg)
    opt = AdamWConfig(lr=3e-3)
    state = init_train_state(model, opt, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(model, opt))
    batch = make_batch(cfg, B=4, S=32)  # overfit one batch
    first = None
    for i in range(30):
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first - 0.5
