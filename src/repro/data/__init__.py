from .pipeline import SyntheticLM, DataPipeline, shard_registry  # noqa: F401
