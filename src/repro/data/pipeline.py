"""Data pipeline: deterministic synthetic LM shards + prefetch through the
transparent mp substrate.

Shards are claimed by workers through an atomic KV counter (elastic:
workers can join/leave mid-epoch and shard assignment stays exactly-once);
prefetched batches flow to the trainer over a bounded ``mp.Queue`` —
dogfooding the paper's abstractions as the framework's own data plane.

The synthetic stream is a deterministic per-shard Markov-ish token
sequence (seeded PCG), so restarts reproduce the exact same batches —
required for checkpoint/restart tests.
"""

from __future__ import annotations

import threading
from typing import Dict, Iterator, Optional

import numpy as np

from ..core import mp
from ..core import session as _session

__all__ = ["SyntheticLM", "DataPipeline", "shard_registry"]


class SyntheticLM:
    """Deterministic synthetic next-token data. Batches contain `tokens`
    and `labels` (tokens shifted by one within the stream)."""

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.seed = seed

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        # mixture of repeated motifs + noise => learnable structure
        B, S, V = self.batch_size, self.seq_len, self.vocab_size
        motif_len = 8
        n_motifs = max(2, V // 16)
        motifs = rng.integers(0, V, (n_motifs, motif_len))
        picks = rng.integers(0, n_motifs, (B, S // motif_len + 2))
        stream = motifs[picks].reshape(B, -1)[:, :S + 1]
        noise = rng.random((B, S + 1)) < 0.1
        stream = np.where(noise, rng.integers(0, V, (B, S + 1)), stream)
        return {"tokens": stream[:, :-1].astype(np.int32),
                "labels": stream[:, 1:].astype(np.int32)}


def shard_registry(tag: str, n_shards: int,
                   session: Optional[_session.Session] = None):
    """Exactly-once shard claiming via an atomic counter."""
    store = (session or _session.get_session()).store

    def claim() -> Optional[int]:
        nxt = store.incr(f"{{{tag}}}:shard") - 1
        return nxt if nxt < n_shards else None

    return claim


class DataPipeline:
    """Producer threads fill a bounded mp.Queue with prefetched batches."""

    def __init__(self, dataset: SyntheticLM, prefetch: int = 4,
                 n_producers: int = 1, start_step: int = 0):
        self.dataset = dataset
        self.queue = mp.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._next = start_step
        self._lock = threading.Lock()
        self._threads = [
            threading.Thread(target=self._produce, daemon=True,
                             name=f"data-producer-{i}")
            for i in range(n_producers)]
        for t in self._threads:
            t.start()

    def _produce(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                step = self._next
                self._next += 1
            batch = self.dataset.batch(step)
            try:
                self.queue.put((step, batch), timeout=1.0)
            except Exception:
                if self._stop.is_set():
                    return
                with self._lock:  # retry same step later
                    self._next = min(self._next, step)

    def __iter__(self) -> Iterator:
        while True:
            yield self.queue.get()

    def stop(self) -> None:
        self._stop.set()
