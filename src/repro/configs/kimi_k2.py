"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8 + 1
shared expert [arXiv:2501.kimi2; unverified, paper-table]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    num_experts=384, experts_per_token=8, moe_d_ff=2048,
    num_shared_experts=1,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=64,
    vocab_size=256, num_experts=8, experts_per_token=2, moe_d_ff=64,
    num_shared_experts=1, dtype="float32", param_dtype="float32",
)
