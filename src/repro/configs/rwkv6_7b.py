"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free
[arXiv:2404.05892; hf]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,  # unused
    d_ff=14336, vocab_size=65536, ssm_head_dim=64,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=160,
    vocab_size=256, ssm_head_dim=16, dtype="float32", param_dtype="float32",
)
