"""zamba2-2.7b [hybrid] — Mamba2 backbone + one SHARED attention block
applied periodically [arXiv:2411.15242; hf]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_width=4,
    attn_every=6,
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=256, ssm_state=16, ssm_head_dim=16, attn_every=2,
    dtype="float32", param_dtype="float32",
)
