"""internvl2-2b [vlm] — InternViT frontend (STUB: precomputed patch
embeddings) + InternLM2 backbone [arXiv:2404.16821; hf]."""

from ..models.config import ModelConfig

#: ViT patch grid for the stub frontend (448px / 14px patches -> 1024,
#: pixel-shuffle x4 -> 256 tokens, InternVL2 convention).
NUM_PATCHES = 256

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553,
    num_prefix_embeddings=NUM_PATCHES,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, num_prefix_embeddings=8,
    dtype="float32", param_dtype="float32",
)
