"""seamless-m4t-medium [audio] — enc-dec; audio frontend STUB
(precomputed frame embeddings) [arXiv:2308.11596; hf]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    encoder_layers=12,
)

SMOKE = CONFIG.replace(
    num_layers=2, encoder_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, dtype="float32", param_dtype="float32",
)
