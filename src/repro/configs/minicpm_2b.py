"""minicpm-2b [dense] — WSD schedule, llama-like arch with mu-param style
embedding/residual scaling [arXiv:2404.06395; hf]."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753, tie_embeddings=True,
    emb_scale=12.0, residual_scale=1.4 / (40 ** 0.5),  # scale_depth/sqrt(L)
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=72, num_heads=4, num_kv_heads=4, d_ff=180,
    vocab_size=256, dtype="float32", param_dtype="float32",
    residual_scale=1.4 / (2 ** 0.5),
)
