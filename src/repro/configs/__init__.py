"""Architecture registry: one module per assigned architecture.

``get_config(arch)`` returns the full published configuration;
``smoke_config(arch)`` returns a reduced same-family configuration small
enough for a CPU forward/train step (used by per-arch smoke tests).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

ARCHS: List[str] = [
    "llama3-8b",
    "qwen1.5-4b",
    "qwen1.5-0.5b",
    "minicpm-2b",
    "phi3.5-moe-42b-a6.6b",
    "kimi-k2-1t-a32b",
    "rwkv6-7b",
    "internvl2-2b",
    "zamba2-2.7b",
    "seamless-m4t-medium",
]

_MODULES: Dict[str, str] = {
    "llama3-8b": "llama3_8b",
    "qwen1.5-4b": "qwen1_5_4b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "minicpm-2b": "minicpm_2b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "kimi-k2-1t-a32b": "kimi_k2",
    "rwkv6-7b": "rwkv6_7b",
    "internvl2-2b": "internvl2_2b",
    "zamba2-2.7b": "zamba2_2_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCHS}")
    return importlib.import_module(f".{_MODULES[arch]}", __name__)


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE
