"""AdamW with selectable state precision (fp32 / bf16 / int8).

Hand-rolled (no optax dependency) so state dtype, sharding and update
fusion stay fully under our control — the int8 path is what makes the
kimi-k2 single-pod memory budget even approachable (see EXPERIMENTS.md
§Roofline). Update math follows Loshchilov & Hutter with bias correction.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .quant import QTensor, dequantize_int8, quantize_int8

OptState = Dict[str, Any]


@dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"     # float32 | bfloat16 | int8

    def lr_at(self, step) -> jax.Array:
        if callable(self.lr):
            return jnp.asarray(self.lr(step), jnp.float32)
        return jnp.asarray(self.lr, jnp.float32)


def _encode(x: jax.Array, dtype: str):
    if dtype == "int8":
        return quantize_int8(x)
    return x.astype(jnp.dtype(dtype))


def _decode(x, dtype: str) -> jax.Array:
    if dtype == "int8":
        return dequantize_int8(x)
    return x.astype(jnp.float32)


def adamw_init(cfg: AdamWConfig, params) -> OptState:
    zeros = jax.tree.map(
        lambda p: _encode(jnp.zeros(p.shape, jnp.float32), cfg.state_dtype),
        params, is_leaf=lambda x: hasattr(x, "shape"))
    zeros2 = jax.tree.map(
        lambda p: _encode(jnp.zeros(p.shape, jnp.float32), cfg.state_dtype),
        params, is_leaf=lambda x: hasattr(x, "shape"))
    return {"m": zeros, "v": zeros2, "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads, state: OptState, params
                 ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    gnorm = global_norm(grads)
    if cfg.grad_clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
    else:
        scale = jnp.ones((), jnp.float32)
    lr = cfg.lr_at(state["count"])
    bc1 = 1.0 - cfg.b1 ** cf
    bc2 = 1.0 - cfg.b2 ** cf

    is_q = lambda x: isinstance(x, QTensor)  # noqa: E731

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = _decode(m, cfg.state_dtype)
        vf = _decode(v, cfg.state_dtype)
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * g * g
        mhat = mf / bc1
        vhat = vf / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + cfg.weight_decay * pf)
        return (pf.astype(p.dtype), _encode(mf, cfg.state_dtype),
                _encode(vf, cfg.state_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"], is_leaf=is_q)
    flat_v = jax.tree.leaves(state["v"], is_leaf=is_q)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
