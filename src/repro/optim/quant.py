"""Int8 row-quantization for optimizer state (and gradient compression).

8-bit optimizer state is a distributed-optimization necessity at kimi-k2
scale: Adam's fp32 (m, v) alone is 8 TB for 1T params. The int8 payload
keeps the **original tensor shape** with one f32 scale per last-axis row,
so both payload and scales inherit the parameter's sharding unchanged —
a flat [blocks, 256] layout is 4x denser in scales but its reshape back
to (61, 384, ...) expert dims is not evenly shardable and forces XLA SPMD
to fully rematerialize the f32 state per device (measured: 8.4 TB/device
for kimi-k2; see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_with_keys_class
class QTensor:
    """int8 payload (original shape) + per-row f32 scale."""

    def __init__(self, q: jax.Array, scale: jax.Array, shape: Tuple[int, ...]):
        self.q = q          # int8, original shape
        self.scale = scale  # f32, shape[:-1]
        self.shape = tuple(shape)

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return ((ga("q"), self.q), (ga("scale"), self.scale)), self.shape

    def tree_flatten(self):
        return (self.q, self.scale), self.shape

    @classmethod
    def tree_unflatten(cls, shape, children):
        return cls(children[0], children[1], shape)

    @property
    def dtype(self):  # for sharding-rule traversal
        return jnp.int8

    def __repr__(self):  # pragma: no cover
        return f"QTensor(shape={self.shape})"


def quantize_int8(x: jax.Array) -> QTensor:
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(xf / jnp.maximum(scale, 1e-12)), -127, 127)
    return QTensor(q.astype(jnp.int8), scale[..., 0], x.shape)


def dequantize_int8(t: QTensor) -> jax.Array:
    return t.q.astype(jnp.float32) * t.scale[..., None]
