"""Learning-rate schedules. WSD (warmup-stable-decay) is a paper-listed
feature of minicpm-2b [arXiv:2404.06395]: linear warmup, long stable
plateau, short exponential/linear decay tail."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, base_lr: float, warmup_steps: int):
    s = jnp.asarray(step, jnp.float32)
    return base_lr * jnp.minimum(1.0, (s + 1) / max(1, warmup_steps))


def cosine_schedule(step, base_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (s + 1) / max(1, warmup_steps))
    prog = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps), 0, 1)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos


def wsd_schedule(step, base_lr: float, warmup_steps: int, stable_steps: int,
                 decay_steps: int, final_frac: float = 0.01):
    """Warmup-Stable-Decay: the minicpm schedule."""
    s = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (s + 1) / max(1, warmup_steps))
    decay_start = warmup_steps + stable_steps
    prog = jnp.clip((s - decay_start) / max(1, decay_steps), 0.0, 1.0)
    decay = final_frac ** prog  # exponential anneal to final_frac
    return base_lr * warm * jnp.where(s < decay_start, 1.0, decay)
