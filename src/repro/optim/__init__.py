from .adamw import AdamWConfig, adamw_init, adamw_update, OptState  # noqa: F401
from .schedules import wsd_schedule, cosine_schedule, linear_warmup  # noqa: F401
from .quant import quantize_int8, dequantize_int8  # noqa: F401
