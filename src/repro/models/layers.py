"""Shared transformer layers: norms, rotary, GQA attention, SwiGLU MLP.

Pure-pytree style: ``init_*`` builds a dict of arrays, ``apply_*`` consumes
it. Sharding is annotated at the training-step level (sharding/rules.py
maps parameter paths to PartitionSpecs), so layers stay mesh-agnostic.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .config import ModelConfig

Params = Dict[str, Any]


def _norm_init(D: int, dtype) -> jax.Array:
    return jnp.ones((D,), dtype)


def dense_init(key, fan_in: int, fan_out: int, dtype,
               scale: Optional[float] = None) -> jax.Array:
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, (fan_in, fan_out), jnp.float32) * std).astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    """RMSNorm with a hand-written VJP.

    Autodiff through the f32 variance path materializes f32 [B,S,D]
    cotangents, and XLA then places the per-layer tensor-parallel
    all-reduces on the f32 merged gradient — 2x the bytes (measured at
    llama3/train_4k; EXPERIMENTS.md §Perf cell 2). The custom backward
    does all math in f32 internally but hands back cotangents in the
    activation dtype, keeping every cross-device gradient tensor narrow.

        y  = x * r * w,          r = rsqrt(mean(x^2) + eps)
        dx = r*(w*g) - x * r^3 * mean(x*w*g)
        dw = sum_batch(x * r * g)
    """
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * scale * w.astype(x.dtype)


def _rms_fwd(x, w, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)                     # [..., 1] f32
    y = x * r.astype(x.dtype) * w.astype(x.dtype)
    return y, (x, w, r)


def _rms_bwd(eps, res, g):
    x, w, r = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    xwg = jnp.mean(xf * wf * gf, axis=-1, keepdims=True)   # [..., 1]
    dx = r * wf * gf - xf * (r ** 3) * xwg
    dw = jnp.sum((xf * r * gf).reshape(-1, x.shape[-1]), axis=0)
    return dx.astype(x.dtype), dw.astype(w.dtype)


rms_norm.defvjp(_rms_fwd, _rms_bwd)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., S, H, D]; positions: [..., S].
    Angles are computed in f32; cos/sin are cast to the activation dtype
    before the rotation so large tensors (and their cotangents) stay
    narrow — see rms_norm."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, d_model: Optional[int] = None) -> Params:
    D = d_model or cfg.d_model
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = cfg.p_dtype()
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], D, H * hd, dt),
        "wk": dense_init(ks[1], D, K * hd, dt),
        "wv": dense_init(ks[2], D, K * hd, dt),
        "wo": dense_init(ks[3], H * hd, D, dt, scale=(H * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((K * hd,), dt)
        p["bv"] = jnp.zeros((K * hd,), dt)
    return p


def _project_qkv(p: Params, cfg: ModelConfig, x: jax.Array,
                 positions: jax.Array, use_rope: bool = True):
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = jnp.einsum("bsd,df->bsf", x, p["wq"])
    k = jnp.einsum("bsd,df->bsf", x, p["wk"])
    v = jnp.einsum("bsd,df->bsf", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def apply_attention(p: Params, cfg: ModelConfig, x: jax.Array,
                    positions: jax.Array, causal: bool = True) -> jax.Array:
    """Full-sequence (training / prefill) self-attention."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    o = ops.attention(q, k, v, causal=causal)
    B, S = x.shape[:2]
    return jnp.einsum("bsf,fd->bsd", o.reshape(B, S, -1), p["wo"])


def apply_attention_prefill(p: Params, cfg: ModelConfig, x: jax.Array,
                            positions: jax.Array,
                            ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Prefill: returns output and the (k, v) cache for this layer."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    o = ops.attention(q, k, v, causal=True)
    B, S = x.shape[:2]
    out = jnp.einsum("bsf,fd->bsd", o.reshape(B, S, -1), p["wo"])
    return out, (k, v)


def apply_attention_decode(p: Params, cfg: ModelConfig, x: jax.Array,
                           cache_k: jax.Array, cache_v: jax.Array,
                           lengths: jax.Array,
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: [B, 1, D]; cache_[kv]: [B, S_max, K, hd];
    lengths: [B] valid entries (the new token is written at ``lengths``).

    Cache-update policy (cfg.decode_cache_update):
      * "onehot"  — per-row masked add; handles ragged lengths but reads
        AND rewrites the full cache every step (paper-era baseline).
      * "dynamic" — dynamic_update_slice at the (uniform) position; with
        the cache donated, XLA updates one slot in place. Requires
        synchronized decode (all rows share a position), which the
        serving engine guarantees.
    """
    B = x.shape[0]
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q, k, v = _project_qkv(p, cfg, x, lengths[:, None], use_rope=True)
    if cfg.decode_cache_update == "dynamic":
        pos = lengths[0]
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    else:
        idx = lengths  # [B]
        oh = jax.nn.one_hot(idx, cache_k.shape[1], dtype=cache_k.dtype)
        cache_k = cache_k + oh[:, :, None, None] * k.astype(cache_k.dtype)
        cache_v = cache_v + oh[:, :, None, None] * v.astype(cache_v.dtype)
    o = ops.decode_attention(q[:, 0], cache_k, cache_v, lengths + 1)
    out = jnp.einsum("bf,fd->bd", o.reshape(B, -1), p["wo"])[:, None, :]
    return out, cache_k, cache_v


def apply_attention_decode_paged(p: Params, cfg: ModelConfig, x: jax.Array,
                                 k_pages: jax.Array, v_pages: jax.Array,
                                 page_table: jax.Array, lengths: jax.Array,
                                 slot_mask: jax.Array,
                                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against the shared page slab (continuous batching).

    x: [B, 1, D]; k_pages/v_pages: [P, page, K, hd] — ONE slab shared by
    every sequence, page 0 reserved as the null page; page_table: [B, M]
    per-slot page ids; lengths: [B] valid cache entries (the new token is
    written at position ``lengths``); slot_mask: [B] bool — False rows
    are idle serving slots: their K/V write is redirected to the null
    page and their attention length forced to 0, so a dead slot can
    neither corrupt a live sequence's pages nor read stale ones.

    Equivalent to ``apply_attention_decode`` with the "onehot" policy on
    the gathered contiguous cache — per-slot ragged lengths (and thus
    ragged rope positions) are the normal case here, not an edge case.
    """
    B = x.shape[0]
    page = k_pages.shape[1]
    q, k, v = _project_qkv(p, cfg, x, lengths[:, None], use_rope=True)
    pid = page_table[jnp.arange(B), lengths // page]           # [B]
    pid = jnp.where(slot_mask, pid, 0)
    off = lengths % page
    k_pages = k_pages.at[pid, off].set(k[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[pid, off].set(v[:, 0].astype(v_pages.dtype))
    att_len = jnp.where(slot_mask, lengths + 1, 0)
    o = ops.paged_decode_attention(q[:, 0], k_pages, v_pages, page_table,
                                   att_len)
    out = jnp.einsum("bf,fd->bd", o.reshape(B, -1), p["wo"])[:, None, :]
    return out, k_pages, v_pages


def apply_attention_prefill_paged(p: Params, cfg: ModelConfig, x: jax.Array,
                                  k_pages: jax.Array, v_pages: jax.Array,
                                  page_table: jax.Array, start: jax.Array,
                                  n_valid: jax.Array,
                                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked prefill attention for ONE request writing into the slab.

    x: [1, C, D] — the next chunk of the prompt, padded to the static
    chunk length C; page_table: [M] (this request's row); start: tokens
    already cached by earlier chunks; n_valid: real tokens in this chunk
    (the tail past it is padding: its K/V writes are redirected to the
    null page and no valid query row can attend that far right).

    The chunk's K/V are scattered into the pages FIRST, then the
    request's whole window is gathered back ([M * page] positions) and
    attended causally with the shifted mask ``col <= start + row`` —
    exactly ``ops.attention``'s semantics continued from a cache, f32
    softmax and all, so chunked prefill matches one-shot prefill.
    """
    _, C, _ = x.shape
    P, page, K, hd = k_pages.shape
    M = page_table.shape[0]
    H = cfg.num_heads
    G = H // K
    tpos = start + jnp.arange(C, dtype=jnp.int32)              # [C]
    q, k, v = _project_qkv(p, cfg, x, tpos[None], use_rope=True)
    valid = jnp.arange(C) < n_valid
    pid = jnp.where(valid, page_table[tpos // page], 0)
    off = tpos % page
    k_pages = k_pages.at[pid, off].set(k[0].astype(k_pages.dtype))
    v_pages = v_pages.at[pid, off].set(v[0].astype(v_pages.dtype))
    kc = k_pages[page_table].reshape(M * page, K, hd)
    vc = v_pages[page_table].reshape(M * page, K, hd)
    scale = hd ** -0.5
    qf = q.astype(jnp.float32).reshape(C, K, G, hd) * scale
    logits = jnp.einsum("qkgd,skd->kgqs", qf, kc.astype(jnp.float32))
    cols = jnp.arange(M * page, dtype=jnp.int32)[None, :]      # [1, S]
    causal = cols <= (start + jnp.arange(C, dtype=jnp.int32))[:, None]
    logits = jnp.where(causal[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("kgqs,skd->qkgd", probs, vc.astype(jnp.float32))
    o = o.reshape(1, C, H * hd).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", o, p["wo"])
    return out, k_pages, v_pages


def apply_dense_block_decode_paged(p, cfg, x, k_pages, v_pages, page_table,
                                   lengths, slot_mask):
    r = cfg.residual_scale
    a, kp, vp = apply_attention_decode_paged(
        p["attn"], cfg, rms_norm(x, p["norm1"], cfg.norm_eps),
        k_pages, v_pages, page_table, lengths, slot_mask)
    x = x + r * a
    x = x + r * apply_mlp(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps))
    return x, kp, vp


def apply_dense_block_prefill_paged(p, cfg, x, k_pages, v_pages, page_table,
                                    start, n_valid):
    r = cfg.residual_scale
    a, kp, vp = apply_attention_prefill_paged(
        p["attn"], cfg, rms_norm(x, p["norm1"], cfg.norm_eps),
        k_pages, v_pages, page_table, start, n_valid)
    x = x + r * a
    x = x + r * apply_mlp(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps))
    return x, kp, vp


def init_cross_attention(key, cfg: ModelConfig) -> Params:
    return init_attention(key, cfg)


def apply_cross_attention(p: Params, cfg: ModelConfig, x: jax.Array,
                          enc_kv: Tuple[jax.Array, jax.Array]) -> jax.Array:
    """Decoder cross-attention. enc_kv = (k, v) precomputed from encoder
    output: [B, T, K, hd]."""
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.hd
    q = jnp.einsum("bsd,df->bsf", x, p["wq"]).reshape(B, S, H, hd)
    k, v = enc_kv
    o = ops.attention(q, k, v, causal=False)
    return jnp.einsum("bsf,fd->bsd", o.reshape(B, S, -1), p["wo"])


def encoder_kv(p: Params, cfg: ModelConfig, enc_out: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    B, T, _ = enc_out.shape
    K, hd = cfg.num_kv_heads, cfg.hd
    k = jnp.einsum("btd,df->btf", enc_out, p["wk"]).reshape(B, T, K, hd)
    v = jnp.einsum("btd,df->btf", enc_out, p["wv"]).reshape(B, T, K, hd)
    return k, v


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.p_dtype()
    ks = jax.random.split(key, 3)
    return {
        "wi": dense_init(ks[0], D, F, dt),
        "wg": dense_init(ks[1], D, F, dt),
        "wo": dense_init(ks[2], F, D, dt, scale=F ** -0.5),
    }


def apply_mlp(p: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["wi"])
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embedding(key, cfg: ModelConfig) -> Params:
    dt = cfg.p_dtype()
    ks = jax.random.split(key, 2)
    p = {"tok": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                   jnp.float32) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size, dt)
    return p


def embed(p: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0).astype(cfg.act_dtype())
    return x * cfg.emb_scale


def unembed(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["tok"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["head"].astype(x.dtype))
    if cfg.logit_soft_cap is not None:
        logits = cfg.logit_soft_cap * jnp.tanh(logits / cfg.logit_soft_cap)
    return logits


# ---------------------------------------------------------------------------
# Dense transformer block
# ---------------------------------------------------------------------------


def init_dense_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "attn": init_attention(ks[0], cfg),
        "mlp": init_mlp(ks[1], cfg),
        "norm1": _norm_init(cfg.d_model, cfg.p_dtype()),
        "norm2": _norm_init(cfg.d_model, cfg.p_dtype()),
    }


def apply_dense_block(p: Params, cfg: ModelConfig, x: jax.Array,
                      positions: jax.Array) -> jax.Array:
    # Sub-block boundaries are pinned too: left free, XLA's partitioner
    # shards the f32 rms intermediates over the tensor axis and pays
    # full-width f32 all-reduces in the backward (measured: +2x collective
    # bytes at llama3/train_4k — EXPERIMENTS.md §Perf cell 2 iter 3).
    from ..sharding.ctx import constrain
    r = cfg.residual_scale
    h = constrain(rms_norm(x, p["norm1"], cfg.norm_eps), "batch", "seq", None)
    x = x + r * constrain(apply_attention(p["attn"], cfg, h, positions),
                          "batch", "seq", None)
    h = constrain(rms_norm(x, p["norm2"], cfg.norm_eps), "batch", "seq", None)
    x = x + r * constrain(apply_mlp(p["mlp"], h), "batch", "seq", None)
    return x


def apply_dense_block_prefill(p, cfg, x, positions):
    r = cfg.residual_scale
    a, kv = apply_attention_prefill(p["attn"], cfg,
                                    rms_norm(x, p["norm1"], cfg.norm_eps),
                                    positions)
    x = x + r * a
    x = x + r * apply_mlp(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps))
    return x, kv


def apply_dense_block_decode(p, cfg, x, cache_k, cache_v, lengths):
    r = cfg.residual_scale
    a, ck, cv = apply_attention_decode(
        p["attn"], cfg, rms_norm(x, p["norm1"], cfg.norm_eps),
        cache_k, cache_v, lengths)
    x = x + r * a
    x = x + r * apply_mlp(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps))
    return x, ck, cv
