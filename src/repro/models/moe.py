"""Mixture-of-Experts layer (phi3.5-moe: 16e top-2; kimi-k2: 384e top-8).

Two interchangeable implementations (cfg.moe_impl):

``dense``  — GShard-style capacity-factor dispatch with one-hot einsums.
             pjit-friendly (XLA SPMD partitions the expert dimension over
             the "model" axis = expert parallelism), numerically the
             paper-era baseline. Cost: the dispatch/combine einsums carry
             O(tokens · E·C · D) FLOPs — visible in the roofline and
             attacked in the §Perf hillclimb.

``gather`` — sort-based dispatch + grouped GEMM via jax.lax.ragged_dot,
             FLOPs proportional to routed tokens only. Runs inside
             shard_map over the "model" axis: each shard computes its
             local experts' contributions for all tokens, then psums.

Both apply top-k routing with softmax-renormalized gates and optional
shared experts (kimi-k2) that every token visits.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init

Params = Dict[str, Any]


def init_moe(key, cfg: ModelConfig) -> Params:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    dt = cfg.p_dtype()
    ks = jax.random.split(key, 5)
    std = D ** -0.5
    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),  # router in f32
        "wi": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * std).astype(dt),
        "wg": (jax.random.normal(ks[2], (E, D, F), jnp.float32) * std).astype(dt),
        "wo": (jax.random.normal(ks[3], (E, F, D), jnp.float32) * F ** -0.5).astype(dt),
    }
    if cfg.num_shared_experts:
        Fs = F * cfg.num_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi": dense_init(kss[0], D, Fs, dt),
            "wg": dense_init(kss[1], D, Fs, dt),
            "wo": dense_init(kss[2], Fs, D, dt, scale=Fs ** -0.5),
        }
    return p


def _route(p: Params, cfg: ModelConfig, x: jax.Array):
    """Top-k routing. x: [..., D] -> gates [..., k], idx [..., k], aux."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style)
    E = cfg.num_experts
    me = probs.reshape(-1, E).mean(axis=0)                     # [E]
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / idx.size)
    aux = E * jnp.sum(me * ce)
    return gates, idx, aux


def _expert_ffn(wi, wg, wo, x):
    """x: [..., D] through one expert's SwiGLU. Weights [..., D, F] etc."""
    h = jax.nn.silu(jnp.einsum("...td,...df->...tf", x, wg))
    h = h * jnp.einsum("...td,...df->...tf", x, wi)
    return jnp.einsum("...tf,...fd->...td", h, wo)


def apply_moe_dense(p: Params, cfg: ModelConfig, x: jax.Array):
    """GShard dispatch, grouped by batch row (the standard data-shard
    grouping so dispatch tensors stay O(S·E·C_group) per group).

    x: [B, S, D] -> ([B, S, D], aux_loss).
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    gates, idx, aux = _route(p, cfg, x)                        # [B, S, k]

    # per-group (per batch row) capacity
    C = max(1, int(cfg.capacity_factor * S * k / E))
    onehot_e = jax.nn.one_hot(idx, E, dtype=jnp.int32)         # [B, S, k, E]
    flat = onehot_e.reshape(B, S * k, E)
    pos = jnp.cumsum(flat, axis=1) - 1                         # arrival order
    pos_in_expert = (pos.reshape(B, S, k, E) * onehot_e).sum(-1)  # [B, S, k]
    keep = pos_in_expert < C                                   # drop overflow
    gates = gates * keep.astype(gates.dtype)

    # one-hot dispatch [B, S, k, E, C] -> summed over k: [B, S, E, C]
    oh_c = jax.nn.one_hot(jnp.where(keep, pos_in_expert, C), C + 1,
                          dtype=x.dtype)[..., :C]              # [B, S, k, C]
    oh_e = onehot_e.astype(x.dtype)
    disp = jnp.einsum("bske,bskc->bsec", oh_e, oh_c)
    comb = jnp.einsum("bske,bskc,bsk->bsec", oh_e, oh_c,
                      gates.astype(x.dtype))

    xe = jnp.einsum("bsd,bsec->becd", x, disp)                 # [B, E, C, D]
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["wg"]))
    h = h * jnp.einsum("becd,edf->becf", xe, p["wi"])
    ye = jnp.einsum("becf,efd->becd", h, p["wo"])              # [B, E, C, D]
    y = jnp.einsum("becd,bsec->bsd", ye, comb)                 # [B, S, D]

    if cfg.num_shared_experts:
        sh = p["shared"]
        y = y + _expert_ffn(sh["wi"], sh["wg"], sh["wo"], x)
    return y, aux


def apply_moe_gather(p: Params, cfg: ModelConfig, x: jax.Array,
                     axis_name: Optional[str] = None,
                     axis_size: int = 1):
    """Sort-based grouped-GEMM MoE (runs per model-shard under shard_map).

    When ``axis_name`` is given, ``p['wi']/['wg']/['wo']`` hold only the
    local expert slice [E_local, ...]; every shard routes its local
    tokens, processes the assignments that hit its local experts through
    a fixed-capacity ragged_dot buffer, and the caller psums the partial
    outputs over the axis. Compared to the GShard dense dispatch this
    moves **one activations-sized psum per layer** instead of
    [B,S,E,C]-sized dispatch products, and computes only routed tokens.
    """
    B, S, D = x.shape
    k = cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, D)
    gates, idx, aux = _route(p, cfg, xt)

    E_local = p["wi"].shape[0]
    if axis_name is not None:
        shard = jax.lax.axis_index(axis_name)
        lo = shard * E_local
    else:
        lo = 0

    flat_e = idx.reshape(-1) - lo                              # [T*k]
    flat_g = gates.reshape(-1)
    local = (flat_e >= 0) & (flat_e < E_local)
    flat_e = jnp.where(local, flat_e, E_local)                 # E_local = trash
    order = jnp.argsort(flat_e)                                # stable
    sorted_tok = order // k

    # fixed-capacity compute buffer: expected local assignments x slack
    expected = T * k / max(axis_size, 1)
    C_buf = int(min(T * k, max(1, cfg.capacity_factor * expected)))
    order_c = order[:C_buf]
    tok_c = sorted_tok[:C_buf]
    e_c = flat_e[order_c]
    # overflow beyond capacity is dropped (standard capacity behavior);
    # rows past sum(group_sizes) are zero-filled by ragged_dot
    group_sizes = jnp.bincount(e_c, length=E_local + 1)[:E_local]

    xs = xt[tok_c]                                             # [C_buf, D]
    h = jax.nn.silu(jax.lax.ragged_dot(xs, p["wg"], group_sizes))
    h = h * jax.lax.ragged_dot(xs, p["wi"], group_sizes)
    ys = jax.lax.ragged_dot(h, p["wo"], group_sizes)           # [C_buf, D]
    keep = local[order_c]
    ys = ys * (flat_g[order_c] * keep.astype(flat_g.dtype)
               ).astype(ys.dtype)[:, None]
    yt = jnp.zeros((T, D), ys.dtype).at[tok_c].add(ys)

    if cfg.num_shared_experts and (axis_name is None):
        sh = p["shared"]
        yt = yt + _expert_ffn(sh["wi"], sh["wg"], sh["wo"], xt)
    return yt.reshape(B, S, D), aux


def apply_moe(p: Params, cfg: ModelConfig, x: jax.Array):
    """Dispatch on cfg.moe_impl; 'gather' uses shard_map over the tensor
    axis when an activation-sharding policy is active (production mesh),
    or the single-shard fast path otherwise (CPU tests)."""
    if cfg.moe_impl != "gather":
        return apply_moe_dense(p, cfg, x)

    from ..sharding.ctx import current_rules
    rules = current_rules()
    if rules is None or rules.axis_size(rules.tensor_axis) == 1:
        return apply_moe_gather(p, cfg, x, axis_name=None, axis_size=1)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    ta = rules.tensor_axis
    ba = rules.batch_axes
    tsize = rules.axis_size(ta)
    bspec = ba if x.shape[0] % rules.axis_size(ba) == 0 else None

    routed = {"router": p["router"], "wi": p["wi"], "wg": p["wg"],
              "wo": p["wo"]}

    all_axes = tuple(rules.mesh.axis_names)

    def local_moe(x_loc, router, wi, wg, wo):
        y, aux = apply_moe_gather(
            {"router": router, "wi": wi, "wg": wg, "wo": wo},
            cfg, x_loc, axis_name=ta, axis_size=tsize)
        return jax.lax.psum(y, ta), jax.lax.pmean(aux, all_axes)

    y, aux = shard_map(
        local_moe, mesh=rules.mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P(ta, None, None), P(ta, None, None), P(ta, None, None)),
        out_specs=(P(bspec, None, None), P()),
        check_rep=False,
    )(x, routed["router"], routed["wi"], routed["wg"], routed["wo"])

    if cfg.num_shared_experts:
        sh = p["shared"]
        y = y + _expert_ffn(sh["wi"], sh["wg"], sh["wo"], x)
    return y, aux
