"""RWKV6 "Finch" block (rwkv6-7b): attention-free time mixing with
data-dependent decay + channel mixing.

Faithful to the RWKV6 structure (token shift, LoRA-produced decay,
per-head WKV state, grouped output norm); the low-rank sizes follow the
released 7B (lora 64 for decay/gate). The WKV recurrence itself lives in
kernels (ops.rwkv6_scan) with a chunked Pallas kernel on TPU.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .config import ModelConfig
from .layers import dense_init, rms_norm

Params = Dict[str, Any]

_LORA = 64


def init_rwkv_block(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    H, hd = cfg.ssm_heads, cfg.ssm_head_dim
    F = cfg.d_ff
    dt = cfg.p_dtype()
    ks = jax.random.split(key, 12)
    return {
        "tm": {  # time mixing
            "mu": jnp.full((5, D), 0.5, dt),     # shift-mix for r,k,v,g,w
            "wr": dense_init(ks[0], D, D, dt),
            "wk": dense_init(ks[1], D, D, dt),
            "wv": dense_init(ks[2], D, D, dt),
            "wg": dense_init(ks[3], D, D, dt),
            "w0": jnp.full((D,), -0.6, dt),      # base decay bias
            "wa": dense_init(ks[4], D, _LORA, dt),
            "wb": dense_init(ks[5], _LORA, D, dt),
            "u": (jax.random.normal(ks[6], (H, hd), jnp.float32) * 0.02).astype(dt),
            "wo": dense_init(ks[7], D, D, dt),
            "ln_x": jnp.ones((D,), dt),          # per-head group norm scale
        },
        "cm": {  # channel mixing
            "mu": jnp.full((2, D), 0.5, dt),
            "wk": dense_init(ks[8], D, F, dt),
            "wv": dense_init(ks[9], F, D, dt),
            "wr": dense_init(ks[10], D, D, dt),
        },
        "norm1": jnp.ones((D,), dt),
        "norm2": jnp.ones((D,), dt),
    }


def _token_shift(x: jax.Array, last: Optional[jax.Array] = None) -> jax.Array:
    """x[t-1] per position; `last` is the carried value for step mode."""
    if x.shape[1] == 1 and last is not None:
        return last[:, None, :]
    pad = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _time_mix(p: Params, cfg: ModelConfig, x: jax.Array,
              state: Optional[jax.Array], x_last: Optional[jax.Array]
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, D = x.shape
    H, hd = cfg.ssm_heads, cfg.ssm_head_dim
    xs = _token_shift(x, x_last)
    mu = p["mu"].astype(x.dtype)

    def mix(i):
        return x * mu[i] + xs * (1 - mu[i])

    r = jnp.einsum("bsd,de->bse", mix(0), p["wr"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", mix(1), p["wk"]).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,de->bse", mix(2), p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", mix(3), p["wg"]))
    # data-dependent decay in (0, 1): w = exp(-exp(w0 + lora(x)))
    wlog = (p["w0"].astype(jnp.float32) +
            jnp.einsum("bsl,ld->bsd",
                       jnp.tanh(jnp.einsum("bsd,dl->bsl", mix(4), p["wa"])),
                       p["wb"]).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(wlog)).reshape(B, S, H, hd).astype(x.dtype)

    o, new_state = ops.rwkv6_scan(r, k, v, w, p["u"], state)
    o = o.reshape(B, S, H, hd)
    # grouped rms-norm over each head, then project
    of = o.astype(jnp.float32)
    var = jnp.mean(of * of, axis=-1, keepdims=True)
    o = (of * jax.lax.rsqrt(var + 64e-5)).reshape(B, S, D).astype(x.dtype)
    o = o * p["ln_x"].astype(x.dtype) * g
    out = jnp.einsum("bsd,de->bse", o, p["wo"])
    return out, new_state, x[:, -1, :]


def _channel_mix(p: Params, x: jax.Array, x_last: Optional[jax.Array]
                 ) -> Tuple[jax.Array, jax.Array]:
    xs = _token_shift(x, x_last)
    mu = p["mu"].astype(x.dtype)
    xk = x * mu[0] + xs * (1 - mu[0])
    xr = x * mu[1] + xs * (1 - mu[1])
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"])
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"]))
    return r * kv, x[:, -1, :]


def apply_rwkv_block(p: Params, cfg: ModelConfig, x: jax.Array,
                     state: Optional[Dict[str, jax.Array]] = None
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """state: {"wkv": [B,H,hd,hd], "tm_x": [B,D], "cm_x": [B,D]} or None."""
    st = state or {}
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    o, wkv, tm_x = _time_mix(p["tm"], cfg, h, st.get("wkv"), st.get("tm_x"))
    x = x + o
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    o, cm_x = _channel_mix(p["cm"], h, st.get("cm_x"))
    x = x + o
    return x, {"wkv": wkv, "tm_x": tm_x, "cm_x": cm_x}
