"""Unified model configuration for the 10 assigned architectures."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None          # default d_model // num_heads
    qkv_bias: bool = False                  # qwen1.5
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                       # per-expert hidden dim
    num_shared_experts: int = 0             # kimi-k2 style shared expert
    capacity_factor: float = 1.25
    moe_impl: str = "dense"                 # "dense" (GShard einsum) | "gather"

    # SSM (rwkv6 / mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2                     # mamba2 d_inner = expand * d_model
    conv_width: int = 4

    # hybrid (zamba2): shared attention block applied every `attn_every`
    attn_every: int = 0

    # enc-dec (seamless)
    encoder_layers: int = 0

    # modality frontend stubs (vlm / audio)
    num_prefix_embeddings: int = 0          # patch/frame embeddings prepended

    # scaling / misc
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    emb_scale: float = 1.0                  # minicpm scale_emb
    residual_scale: float = 1.0             # minicpm scale_depth / sqrt(L)
    logit_soft_cap: Optional[float] = None

    # numerics
    dtype: str = "bfloat16"                 # activation dtype
    param_dtype: str = "bfloat16"

    # training-time structure
    remat: str = "full"                     # none | full
    scan_layers: bool = True

    # serving-time structure
    decode_cache_update: str = "onehot"     # onehot | dynamic (see layers)

    # --- derived -----------------------------------------------------------

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:               # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:             # mamba2 / rwkv6 heads
        if self.family == "ssm":            # rwkv6: heads over d_model
            return self.d_model // self.ssm_head_dim
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def act_dtype(self):
        return jnp.dtype(self.dtype)

    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # --- parameter counting (for 6·N·D roofline bookkeeping) ---------------

    def param_counts(self) -> Tuple[int, int]:
        """(total_params, active_params_per_token). Embeddings included in
        total; active excludes the non-routed experts."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        H, K, hd = self.num_heads, self.num_kv_heads, self.hd

        def attn_params() -> int:
            p = D * H * hd + 2 * D * K * hd + H * hd * D
            if self.qkv_bias:
                p += H * hd + 2 * K * hd
            return p

        def mlp_params(f: int) -> int:
            return 3 * D * f  # swiglu: wi, wg, wo

        emb = V * D + (0 if self.tie_embeddings else D * V)
        total = emb
        active = emb

        if self.family in ("dense", "vlm"):
            per_layer = attn_params() + mlp_params(F) + 2 * D
            total += L * per_layer
            active += L * per_layer
        elif self.family == "moe":
            e_all = self.num_experts * 3 * D * self.moe_d_ff
            e_act = (self.experts_per_token + self.num_shared_experts) * 3 * D * self.moe_d_ff
            router = D * self.num_experts
            shared = self.num_shared_experts * 3 * D * self.moe_d_ff
            per_layer_total = attn_params() + e_all + shared + router + 2 * D
            per_layer_active = attn_params() + e_act + router + 2 * D
            total += L * per_layer_total
            active += L * per_layer_active
        elif self.family == "ssm":  # rwkv6
            Hh, hdh = self.ssm_heads, self.ssm_head_dim
            tm = 5 * D * D + D * D + 2 * 64 * D + Hh * hdh + 5 * D  # r,k,v,g,o + decay lora + u + mus
            cm = 2 * D * F // 2 + D * D  # rwkv channel mix (k, v, r)
            per_layer = tm + cm + 2 * D
            total += L * per_layer
            active += L * per_layer
        elif self.family == "hybrid":  # zamba2
            din, N, Hh = self.d_inner, self.ssm_state, self.ssm_heads
            in_proj = D * (2 * din + 2 * N + Hh)
            per_layer = in_proj + self.conv_width * din + din * D + Hh + Hh + 2 * D
            total += L * per_layer
            active += L * per_layer
            shared_attn = attn_params() + mlp_params(F) + 2 * D
            total += shared_attn
            active += shared_attn
        elif self.family == "encdec":
            enc = self.encoder_layers * (attn_params() + mlp_params(F) + 2 * D)
            dec = L * (2 * attn_params() + mlp_params(F) + 3 * D)
            total += enc + dec
            active += enc + dec
        return int(total), int(active)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                   # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

#: archs whose `long_500k` cell is skipped (pure full-attention families)
LONG_CONTEXT_ARCHS = ("rwkv6-7b", "zamba2-2.7b")
