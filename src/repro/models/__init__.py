from .config import ModelConfig, ShapeConfig, SHAPES, LONG_CONTEXT_ARCHS  # noqa: F401
from .model import Model, build_model, cross_entropy  # noqa: F401
