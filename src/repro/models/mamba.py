"""Mamba2 (SSD) block — the zamba2-2.7b backbone.

Structure per Mamba-2: fused in_proj producing (z, x, B, C, dt), causal
depthwise conv over x, SSD recurrence with scalar-per-head decay
(ops.mamba2_scan — chunked Pallas kernel on TPU), gated SiLU output,
out_proj. State for decode = (conv tail, SSM state h).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .config import ModelConfig
from .layers import dense_init, rms_norm

Params = Dict[str, Any]


def init_mamba_block(key, cfg: ModelConfig) -> Params:
    D, din, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.ssm_heads
    dt = cfg.p_dtype()
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], D, 2 * din + 2 * N + H, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, din),
                                     jnp.float32) * 0.2).astype(dt),
        "a_log": jnp.zeros((H,), jnp.float32),        # a = -exp(a_log)
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), dt),
        "out_proj": dense_init(ks[2], din, D, dt, scale=din ** -0.5),
        "norm": jnp.ones((D,), dt),
        "gate_norm": jnp.ones((din,), dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 tail: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: [B, S, C]; w: [W, C]; tail: [B, W-1, C]."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)           # [B, S+W-1, C]
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :].astype(x.dtype)
              for i in range(W))
    new_tail = xp[:, -(W - 1):, :]
    return jax.nn.silu(out), new_tail


def apply_mamba_block(p: Params, cfg: ModelConfig, x: jax.Array,
                      state: Optional[Dict[str, jax.Array]] = None
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """state: {"conv": [B, W-1, din], "ssm": [B, H, P, N]} or None."""
    st = state or {}
    B, S, D = x.shape
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    z, xs, b, c, dt_raw = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1)
    xs, conv_tail = _causal_conv(xs, p["conv_w"], st.get("conv"))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])          # [B, S, H]
    a = -jnp.exp(p["a_log"])                                   # [H]
    xh = xs.reshape(B, S, H, P)
    y, ssm = ops.mamba2_scan(xh, dt, a, b, c, st.get("ssm"))
    y = y + xh * p["d_skip"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(B, S, din)
    y = rms_norm(y, p["gate_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return x + out, {"conv": conv_tail, "ssm": ssm}
