"""Model assembly for all 10 assigned architectures.

One ``Model`` class; family-specific blocks (dense / moe / rwkv / mamba
hybrid / enc-dec) are composed by ``lax.scan`` over stacked per-layer
parameters — essential to keep HLO size (and CPU compile time) bounded at
kimi-k2 scale. Provides:

    init(key)                 -> params pytree
    loss(params, batch)       -> (scalar loss, metrics dict)   [train_step]
    prefill(params, batch, max_len) -> (logits, cache)
    decode(params, cache, tokens)   -> (logits, new cache)     [serve_step]

Cache layout is family-specific (KV cache / WKV state / SSD state) and is
documented next to each prefill implementation.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from ..sharding.ctx import constrain
from . import layers as L
from . import mamba as M
from . import moe as X
from . import rwkv as R

Params = Dict[str, Any]


def _positions(B: int, S: int, offset: int = 0) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None] + offset, (B, S))


def _stack_init(key, n: int, init_fn):
    """Initialize n layers and stack leaves along a leading axis."""
    keys = jax.random.split(key, n)
    per_layer = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)


def scan_over(cfg: ModelConfig, body, carry, xs, length: int = None):
    """lax.scan over stacked layers, or an unrolled python loop when
    cfg.scan_layers=False (dry-run *analysis* compiles use the unrolled
    form so XLA cost analysis sees every layer exactly once)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs, length=length)
    n = length if length is not None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = None if xs is None else jax.tree.map(lambda a, i=i: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def cross_entropy(logits: jax.Array, labels: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Mean CE over labels >= 0. Returns (loss, accuracy).

    Written gather-free: with a vocab-sharded logits tensor, ``argmax`` /
    ``take_along_axis`` over the sharded axis force XLA SPMD to all-gather
    the full [B, S, V] logits (measured: ~17 GB/device per microbatch at
    llama3 scale). The one-hot-masked reductions below keep every
    collective at [B, S] size.
    """
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    vocab = logits.shape[-1]
    m = jnp.max(logits, axis=-1)                              # [B, S]
    logz = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
    onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
              == safe[..., None])                             # fused compare
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)   # [B, S]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    acc = (gold >= m - 1e-6).astype(jnp.float32) * mask       # argmax==label
    return nll.sum() / denom, acc.sum() / denom


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init

    def init(self, key) -> Params:
        cfg = self.cfg
        k_emb, k_layers, k_extra, k_norm = jax.random.split(key, 4)
        params: Params = {"embed": L.init_embedding(k_emb, cfg),
                          "final_norm": jnp.ones((cfg.d_model,), cfg.p_dtype())}
        fam = cfg.family
        if fam in ("dense", "vlm"):
            params["layers"] = _stack_init(
                k_layers, cfg.num_layers, lambda k: L.init_dense_block(k, cfg))
        elif fam == "moe":
            def init_moe_block(k):
                k1, k2 = jax.random.split(k)
                blk = {"attn": L.init_attention(k1, cfg),
                       "moe": X.init_moe(k2, cfg),
                       "norm1": jnp.ones((cfg.d_model,), cfg.p_dtype()),
                       "norm2": jnp.ones((cfg.d_model,), cfg.p_dtype())}
                return blk
            params["layers"] = _stack_init(k_layers, cfg.num_layers, init_moe_block)
        elif fam == "ssm":
            params["layers"] = _stack_init(
                k_layers, cfg.num_layers, lambda k: R.init_rwkv_block(k, cfg))
        elif fam == "hybrid":
            params["layers"] = _stack_init(
                k_layers, cfg.num_layers, lambda k: M.init_mamba_block(k, cfg))
            params["shared_attn"] = L.init_dense_block(k_extra, cfg)
        elif fam == "encdec":
            def init_dec_block(k):
                k1, k2, k3 = jax.random.split(k, 3)
                return {"self_attn": L.init_attention(k1, cfg),
                        "cross_attn": L.init_cross_attention(k2, cfg),
                        "mlp": L.init_mlp(k3, cfg),
                        "norm1": jnp.ones((cfg.d_model,), cfg.p_dtype()),
                        "norm2": jnp.ones((cfg.d_model,), cfg.p_dtype()),
                        "norm3": jnp.ones((cfg.d_model,), cfg.p_dtype())}
            params["enc_layers"] = _stack_init(
                k_layers, cfg.encoder_layers, lambda k: L.init_dense_block(k, cfg))
            params["layers"] = _stack_init(k_extra, cfg.num_layers, init_dec_block)
            params["enc_norm"] = jnp.ones((cfg.d_model,), cfg.p_dtype())
        else:
            raise ValueError(f"unknown family {fam!r}")
        return params

    def abstract_params(self) -> Params:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # -------------------------------------------------------------- forward

    def _maybe_remat(self, fn):
        if self.cfg.remat == "full":
            return jax.checkpoint(fn)
        return fn

    def _backbone(self, params: Params, x: jax.Array, positions: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
        """Run the stacked layers. Returns (hidden, aux_loss)."""
        cfg = self.cfg
        fam = cfg.family

        if fam in ("dense", "vlm"):
            def body(h, layer):
                h = constrain(h, "batch", "seq", None)
                return L.apply_dense_block(layer, cfg, h, positions), None
            body = self._maybe_remat(body)
            x, _ = scan_over(cfg, body, x, params["layers"])
            return x, jnp.zeros((), jnp.float32)

        if fam == "moe":
            def body(carry, layer):
                h, aux = carry
                h = constrain(h, "batch", "seq", None)
                a = L.apply_attention(layer["attn"], cfg,
                                      L.rms_norm(h, layer["norm1"], cfg.norm_eps),
                                      positions)
                h = h + a
                mo, mx = X.apply_moe(layer["moe"], cfg,
                                           L.rms_norm(h, layer["norm2"], cfg.norm_eps))
                return (h + mo, aux + mx), None
            body = self._maybe_remat(body)
            (x, aux), _ = scan_over(cfg, body, (x, jnp.zeros((), jnp.float32)),
                                       params["layers"])
            return x, aux / cfg.num_layers

        if fam == "ssm":
            def body(h, layer):
                h = constrain(h, "batch", "seq", None)
                h, _ = R.apply_rwkv_block(layer, cfg, h)
                return h, None
            body = self._maybe_remat(body)
            x, _ = scan_over(cfg, body, x, params["layers"])
            return x, jnp.zeros((), jnp.float32)

        if fam == "hybrid":
            # groups of `attn_every` mamba layers followed by the SHARED
            # attention block (zamba2: one block's weights reused).
            every = cfg.attn_every or cfg.num_layers
            n_groups = cfg.num_layers // every
            grouped = jax.tree.map(
                lambda a: a.reshape(n_groups, every, *a.shape[1:]),
                params["layers"])

            def inner(h, layer):
                h = constrain(h, "batch", "seq", None)
                h, _ = M.apply_mamba_block(layer, cfg, h)
                return h, None
            inner = self._maybe_remat(inner)
            shared = params["shared_attn"]
            attn_fn = self._maybe_remat(
                lambda h: L.apply_dense_block(shared, cfg, h, positions))
            for g in range(n_groups):
                group = jax.tree.map(lambda a, g=g: a[g], grouped)
                x, _ = scan_over(cfg, inner, x, group)
                x = attn_fn(x)
            return x, jnp.zeros((), jnp.float32)

        raise ValueError(fam)

    def _encoder(self, params: Params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        B, T, _ = frames.shape
        pos = _positions(B, T)

        def body(h, layer):
            return L.apply_dense_block(layer, cfg, h, pos), None  # causal=False below
        # encoder is bidirectional: reuse dense block but non-causal attn
        def body_nc(h, layer):
            h = constrain(h, "batch", "seq", None)
            r = cfg.residual_scale
            a = L.apply_attention(layer["attn"], cfg,
                                  L.rms_norm(h, layer["norm1"], cfg.norm_eps),
                                  pos, causal=False)
            h = h + r * a
            h = h + r * L.apply_mlp(layer["mlp"],
                                    L.rms_norm(h, layer["norm2"], cfg.norm_eps))
            return h, None
        body_nc = self._maybe_remat(body_nc)
        x = frames.astype(cfg.act_dtype())
        x, _ = scan_over(cfg, body_nc, x, params["enc_layers"])
        return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _decoder(self, params: Params, tokens: jax.Array, enc_out: jax.Array
                 ) -> jax.Array:
        cfg = self.cfg
        B, S = tokens.shape
        pos = _positions(B, S)
        x = L.embed(params["embed"], cfg, tokens)

        def body(h, layer):
            h = constrain(h, "batch", "seq", None)
            a = L.apply_attention(layer["self_attn"], cfg,
                                  L.rms_norm(h, layer["norm1"], cfg.norm_eps), pos)
            h = h + a
            kv = L.encoder_kv(layer["cross_attn"], cfg, enc_out)
            ca = L.apply_cross_attention(layer["cross_attn"], cfg,
                                         L.rms_norm(h, layer["norm2"], cfg.norm_eps),
                                         kv)
            h = h + ca
            h = h + L.apply_mlp(layer["mlp"],
                                L.rms_norm(h, layer["norm3"], cfg.norm_eps))
            return h, None
        body = self._maybe_remat(body)
        x, _ = scan_over(cfg, body, x, params["layers"])
        return x

    def forward(self, params: Params, batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, jax.Array]:
        """Teacher-forcing logits. Returns (logits, aux_loss)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            enc_out = self._encoder(params, batch["frames"])
            x = self._decoder(params, batch["tokens"], enc_out)
            aux = jnp.zeros((), jnp.float32)
        else:
            tokens = batch["tokens"]
            x = constrain(L.embed(params["embed"], cfg, tokens),
                          "batch", "seq", None)
            offset = 0
            if cfg.family == "vlm":
                patches = batch["patches"].astype(cfg.act_dtype())
                x = jnp.concatenate([patches, x], axis=1)
                offset = patches.shape[1]
            B, S = x.shape[:2]
            pos = _positions(B, S)
            x, aux = self._backbone(params, x, pos)
            if offset:
                x = x[:, offset:, :]
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = constrain(L.unembed(params["embed"], cfg, x),
                           "batch", None, "tensor")
        return logits, aux

    def loss(self, params: Params, batch: Dict[str, jax.Array]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux = self.forward(params, batch)
        ce, acc = cross_entropy(logits, batch["labels"])
        loss = ce + 0.01 * aux
        return loss, {"loss": loss, "ce": ce, "aux": aux, "accuracy": acc}

    # ------------------------------------------------------------- serving

    def init_cache(self, batch_size: int, max_len: int,
                   enc_len: int = 0) -> Dict[str, Any]:
        """Abstract/zeroed cache pytree for decode."""
        cfg = self.cfg
        dt = cfg.act_dtype()
        B, Lc = batch_size, cfg.num_layers
        K, hd = cfg.num_kv_heads, cfg.hd
        fam = cfg.family
        cache: Dict[str, Any] = {"lengths": jnp.zeros((B,), jnp.int32)}
        if fam in ("dense", "vlm", "moe"):
            cache["k"] = jnp.zeros((Lc, B, max_len, K, hd), dt)
            cache["v"] = jnp.zeros((Lc, B, max_len, K, hd), dt)
        elif fam == "ssm":
            H, shd = cfg.ssm_heads, cfg.ssm_head_dim
            D = cfg.d_model
            cache.update(
                wkv=jnp.zeros((Lc, B, H, shd, shd), jnp.float32),
                tm_x=jnp.zeros((Lc, B, D), dt),
                cm_x=jnp.zeros((Lc, B, D), dt))
        elif fam == "hybrid":
            H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            W, din = cfg.conv_width, cfg.d_inner
            n_groups = cfg.num_layers // (cfg.attn_every or cfg.num_layers)
            cache.update(
                conv=jnp.zeros((Lc, B, W - 1, din), dt),
                ssm=jnp.zeros((Lc, B, H, P, N), jnp.float32),
                attn_k=jnp.zeros((n_groups, B, max_len, K, hd), dt),
                attn_v=jnp.zeros((n_groups, B, max_len, K, hd), dt))
        elif fam == "encdec":
            cache["k"] = jnp.zeros((Lc, B, max_len, K, hd), dt)
            cache["v"] = jnp.zeros((Lc, B, max_len, K, hd), dt)
            cache["enc_k"] = jnp.zeros((Lc, B, enc_len, K, hd), dt)
            cache["enc_v"] = jnp.zeros((Lc, B, enc_len, K, hd), dt)
        return cache

    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                max_len: int) -> Tuple[jax.Array, Dict[str, Any]]:
        """Process a full prompt; returns (last-position logits, cache)."""
        cfg = self.cfg
        fam = cfg.family
        if fam == "encdec":
            return self._prefill_encdec(params, batch, max_len)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = L.embed(params["embed"], cfg, tokens)
        offset = 0
        if fam == "vlm":
            patches = batch["patches"].astype(cfg.act_dtype())
            x = jnp.concatenate([patches, x], axis=1)
            offset = patches.shape[1]
        Sp = x.shape[1]
        pos = _positions(B, Sp)
        cache = self.init_cache(B, max_len)

        if fam in ("dense", "vlm", "moe"):
            def body(h, xs):
                layer = xs
                if fam == "moe":
                    a = L.apply_attention_prefill(
                        layer["attn"], cfg,
                        L.rms_norm(h, layer["norm1"], cfg.norm_eps), pos)
                    h = h + a[0]
                    mo, _ = X.apply_moe(
                        layer["moe"], cfg,
                        L.rms_norm(h, layer["norm2"], cfg.norm_eps))
                    h = h + mo
                    kv = a[1]
                else:
                    h, kv = L.apply_dense_block_prefill(layer, cfg, h, pos)
                return h, kv
            x, (ks, vs) = scan_over(cfg, body, x, params["layers"])
            pad = max_len - Sp
            cache["k"] = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache["v"] = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache["lengths"] = jnp.full((B,), Sp, jnp.int32)
        elif fam == "ssm":
            def body(h, layer):
                h, st = R.apply_rwkv_block(layer, cfg, h)
                return h, st
            x, st = scan_over(cfg, body, x, params["layers"])
            cache.update(wkv=st["wkv"], tm_x=st["tm_x"], cm_x=st["cm_x"])
            cache["lengths"] = jnp.full((B,), Sp, jnp.int32)
        elif fam == "hybrid":
            every = cfg.attn_every or cfg.num_layers
            n_groups = cfg.num_layers // every
            grouped = jax.tree.map(
                lambda a: a.reshape(n_groups, every, *a.shape[1:]),
                params["layers"])
            convs, ssms, aks, avs = [], [], [], []
            for g in range(n_groups):
                group = jax.tree.map(lambda a, g=g: a[g], grouped)

                def inner(h, layer):
                    h, st = M.apply_mamba_block(layer, cfg, h)
                    return h, st
                x, st = scan_over(cfg, inner, x, group)
                convs.append(st["conv"])
                ssms.append(st["ssm"])
                blk = params["shared_attn"]
                a, kv = L.apply_attention_prefill(
                    blk["attn"], cfg,
                    L.rms_norm(x, blk["norm1"], cfg.norm_eps), pos)
                x = x + a
                x = x + L.apply_mlp(blk["mlp"],
                                    L.rms_norm(x, blk["norm2"], cfg.norm_eps))
                pad = max_len - Sp
                aks.append(jnp.pad(kv[0], ((0, 0), (0, pad), (0, 0), (0, 0))))
                avs.append(jnp.pad(kv[1], ((0, 0), (0, pad), (0, 0), (0, 0))))
            cache.update(conv=jnp.concatenate(convs), ssm=jnp.concatenate(ssms),
                         attn_k=jnp.stack(aks), attn_v=jnp.stack(avs),
                         lengths=jnp.full((B,), Sp, jnp.int32))
        x = L.rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
        logits = L.unembed(params["embed"], cfg, x)[:, 0]
        return logits, cache

    def _prefill_encdec(self, params, batch, max_len):
        cfg = self.cfg
        enc_out = self._encoder(params, batch["frames"])
        B = enc_out.shape[0]
        # precompute per-layer cross-attention KV from the encoder output
        def kv_body(_, layer):
            return None, L.encoder_kv(layer["cross_attn"], cfg, enc_out)
        _, (eks, evs) = scan_over(cfg, kv_body, None, params["layers"])
        cache = self.init_cache(B, max_len, enc_len=enc_out.shape[1])
        cache["enc_k"], cache["enc_v"] = eks, evs
        # run the BOS token through decode to get first logits
        bos = batch.get("tokens", jnp.zeros((B, 1), jnp.int32))[:, :1]
        logits, cache = self.decode(params, cache, bos[:, 0])
        return logits, cache

    # ---------------------------------------------------- paged serving
    # Continuous-batching entry points (serve/engine.py). The KV cache is
    # a single page slab shared by every serving slot; per-slot page
    # tables map token position t to (table[t // page], t % page). Page 0
    # is reserved as the null page. Only KV-cache families support this.

    def _check_paged(self):
        if self.cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError(
                f"paged serving requires a KV-cache family, "
                f"got {self.cfg.family!r}")

    def init_paged_cache(self, num_pages: int, page_size: int
                         ) -> Dict[str, jax.Array]:
        """Zeroed page slab: {'k_pages','v_pages': [L, P, page, K, hd]}."""
        self._check_paged()
        cfg = self.cfg
        shape = (cfg.num_layers, num_pages, page_size,
                 cfg.num_kv_heads, cfg.hd)
        dt = cfg.act_dtype()
        return {"k_pages": jnp.zeros(shape, dt),
                "v_pages": jnp.zeros(shape, dt)}

    def decode_paged(self, params: Params, pages: Dict[str, jax.Array],
                     tokens: jax.Array, page_tables: jax.Array,
                     lengths: jax.Array, slot_mask: jax.Array
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """One decode step over the page slab.

        tokens: [B] int32; page_tables: [B, M] int32; lengths: [B]
        (cache entries already written; the new token lands at position
        ``lengths``); slot_mask: [B] bool — idle slots write to the null
        page and produce garbage logits the engine ignores.
        Returns ([B, V] logits, new pages).
        """
        self._check_paged()
        cfg = self.cfg
        fam = cfg.family
        x = L.embed(params["embed"], cfg, tokens[:, None])

        def body(h, xs):
            layer, kp, vp = xs
            if fam == "moe":
                a, nk, nv = L.apply_attention_decode_paged(
                    layer["attn"], cfg,
                    L.rms_norm(h, layer["norm1"], cfg.norm_eps),
                    kp, vp, page_tables, lengths, slot_mask)
                h = h + a
                mo, _ = X.apply_moe(
                    layer["moe"], cfg,
                    L.rms_norm(h, layer["norm2"], cfg.norm_eps))
                h = h + mo
            else:
                h, nk, nv = L.apply_dense_block_decode_paged(
                    layer, cfg, h, kp, vp, page_tables, lengths, slot_mask)
            return h, (nk, nv)

        x, (nks, nvs) = scan_over(
            cfg, body, x,
            (params["layers"], pages["k_pages"], pages["v_pages"]))
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(params["embed"], cfg, x)[:, 0]
        return logits, {"k_pages": nks, "v_pages": nvs}

    def prefill_paged_chunk(self, params: Params,
                            pages: Dict[str, jax.Array],
                            tokens: jax.Array, page_table: jax.Array,
                            start: jax.Array, n_valid: jax.Array
                            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Prefill ONE request's next prompt chunk into the slab.

        tokens: [1, C] padded to the static chunk length; page_table:
        [M] (this request's row); start: tokens already cached; n_valid:
        real tokens in this chunk (traced — one compile covers every
        chunk including the ragged tail). Returns ([1, V] logits at the
        chunk's last VALID position, new pages).
        """
        self._check_paged()
        cfg = self.cfg
        fam = cfg.family
        x = L.embed(params["embed"], cfg, tokens)

        def body(h, xs):
            layer, kp, vp = xs
            if fam == "moe":
                a, nk, nv = L.apply_attention_prefill_paged(
                    layer["attn"], cfg,
                    L.rms_norm(h, layer["norm1"], cfg.norm_eps),
                    kp, vp, page_table, start, n_valid)
                h = h + a
                mo, _ = X.apply_moe(
                    layer["moe"], cfg,
                    L.rms_norm(h, layer["norm2"], cfg.norm_eps))
                h = h + mo
            else:
                h, nk, nv = L.apply_dense_block_prefill_paged(
                    layer, cfg, h, kp, vp, page_table, start, n_valid)
            return h, (nk, nv)

        x, (nks, nvs) = scan_over(
            cfg, body, x,
            (params["layers"], pages["k_pages"], pages["v_pages"]))
        last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
        last = L.rms_norm(last, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(params["embed"], cfg, last)[:, 0]
        return logits, {"k_pages": nks, "v_pages": nvs}

    def decode(self, params: Params, cache: Dict[str, Any],
               tokens: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
        """One decode step. tokens: [B] int32. Returns ([B, V] logits, cache)."""
        cfg = self.cfg
        fam = cfg.family
        B = tokens.shape[0]
        lengths = cache["lengths"]
        x = L.embed(params["embed"], cfg, tokens[:, None])

        if fam in ("dense", "vlm", "moe"):
            def body(h, xs):
                layer, ck, cv = xs
                if fam == "moe":
                    a, nk, nv = L.apply_attention_decode(
                        layer["attn"], cfg,
                        L.rms_norm(h, layer["norm1"], cfg.norm_eps),
                        ck, cv, lengths)
                    h = h + a
                    mo, _ = X.apply_moe(
                        layer["moe"], cfg,
                        L.rms_norm(h, layer["norm2"], cfg.norm_eps))
                    h = h + mo
                else:
                    h, nk, nv = L.apply_dense_block_decode(
                        layer, cfg, h, ck, cv, lengths)
                return h, (nk, nv)
            x, (nks, nvs) = scan_over(cfg, 
                body, x, (params["layers"], cache["k"], cache["v"]))
            cache = dict(cache, k=nks, v=nvs, lengths=lengths + 1)
        elif fam == "ssm":
            def body(h, xs):
                layer, wkv, tm_x, cm_x = xs
                h, st = R.apply_rwkv_block(
                    layer, cfg, h, {"wkv": wkv, "tm_x": tm_x, "cm_x": cm_x})
                return h, (st["wkv"], st["tm_x"], st["cm_x"])
            x, (wkv, tm_x, cm_x) = scan_over(cfg, 
                body, x, (params["layers"], cache["wkv"], cache["tm_x"],
                          cache["cm_x"]))
            cache = dict(cache, wkv=wkv, tm_x=tm_x, cm_x=cm_x,
                         lengths=lengths + 1)
        elif fam == "hybrid":
            every = cfg.attn_every or cfg.num_layers
            n_groups = cfg.num_layers // every
            grouped = jax.tree.map(
                lambda a: a.reshape(n_groups, every, *a.shape[1:]),
                params["layers"])
            conv = cache["conv"].reshape(n_groups, every, *cache["conv"].shape[1:])
            ssm = cache["ssm"].reshape(n_groups, every, *cache["ssm"].shape[1:])
            new_conv, new_ssm, new_ak, new_av = [], [], [], []
            for g in range(n_groups):
                group = jax.tree.map(lambda a, g=g: a[g], grouped)

                def inner(h, xs):
                    layer, cv_, sm_ = xs
                    h, st = M.apply_mamba_block(
                        layer, cfg, h, {"conv": cv_, "ssm": sm_})
                    return h, (st["conv"], st["ssm"])
                x, (cvs, sms) = scan_over(cfg, inner, x, (group, conv[g], ssm[g]))
                new_conv.append(cvs)
                new_ssm.append(sms)
                blk = params["shared_attn"]
                a, nk, nv = L.apply_attention_decode(
                    blk["attn"], cfg,
                    L.rms_norm(x, blk["norm1"], cfg.norm_eps),
                    cache["attn_k"][g], cache["attn_v"][g], lengths)
                x = x + a
                x = x + L.apply_mlp(blk["mlp"],
                                    L.rms_norm(x, blk["norm2"], cfg.norm_eps))
                new_ak.append(nk)
                new_av.append(nv)
            cache = dict(cache,
                         conv=jnp.concatenate(new_conv), ssm=jnp.concatenate(new_ssm),
                         attn_k=jnp.stack(new_ak), attn_v=jnp.stack(new_av),
                         lengths=lengths + 1)
        elif fam == "encdec":
            def body(h, xs):
                layer, ck, cv, ek, ev = xs
                a, nk, nv = L.apply_attention_decode(
                    layer["self_attn"], cfg,
                    L.rms_norm(h, layer["norm1"], cfg.norm_eps),
                    ck, cv, lengths)
                h = h + a
                ca = L.apply_cross_attention(
                    layer["cross_attn"], cfg,
                    L.rms_norm(h, layer["norm2"], cfg.norm_eps), (ek, ev))
                h = h + ca
                h = h + L.apply_mlp(layer["mlp"],
                                    L.rms_norm(h, layer["norm3"], cfg.norm_eps))
                return h, (nk, nv)
            x, (nks, nvs) = scan_over(cfg, 
                body, x, (params["layers"], cache["k"], cache["v"],
                          cache["enc_k"], cache["enc_v"]))
            cache = dict(cache, k=nks, v=nvs, lengths=lengths + 1)
        else:
            raise ValueError(fam)

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = constrain(L.unembed(params["embed"], cfg, x),
                           "batch", None, "tensor")[:, 0]
        return logits, cache


@functools.lru_cache(maxsize=None)
def _cached_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def build_model(cfg: ModelConfig) -> Model:
    return _cached_model(cfg)
