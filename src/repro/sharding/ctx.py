"""Activation-sharding constraint context.

Model code is mesh-agnostic; the launcher activates a policy and the
model pins its activations at block boundaries via ``constrain``:

    with activation_sharding(rules):
        lowered = jax.jit(step, ...).lower(...)

Without constraints, XLA's SPMD partitioner may resolve FSDP-weight vs
batch conflicts on the shared "data" axis by all-gathering *activations*
to the full global batch (measured: a 33 GB/device logits gather at
llama3 scale). Pinning activations to P(batch, ...) forces the cheap
direction — weight-shard gathers — which is what production frameworks
(MaxText et al.) do with logical axis rules.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_tls = threading.local()


def current_rules():
    return getattr(_tls, "rules", None)


@contextmanager
def activation_sharding(rules):
    prev = getattr(_tls, "rules", None)
    _tls.rules = rules
    try:
        yield
    finally:
        _tls.rules = prev


def constrain(x: jax.Array, *dims: Optional[str]) -> jax.Array:
    """dims entries: "batch" | "tensor" | "seq" | None, one per axis of x.
    "seq" maps to the tensor axis only under a sequence-parallel policy.
    Axes whose size doesn't divide the named mesh axes stay unsharded."""
    rules = current_rules()
    if rules is None:
        return x
    parts = []
    for d, size in zip(dims, x.shape):
        if d == "seq":
            d = "tensor" if getattr(rules, "sequence_parallel", False) else None
        if d == "batch":
            ax = rules.batch_axes
            parts.append(ax if size % rules.axis_size(ax) == 0 else None)
        elif d == "tensor":
            ax = rules.tensor_axis
            parts.append(ax if size % rules.axis_size(ax) == 0 else None)
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*parts)))
