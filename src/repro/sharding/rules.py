"""Parameter/activation/cache partitioning rules (DP / FSDP / TP / EP).

Axis conventions over the production mesh (launch/mesh.py):

  * ``data``  (+ ``pod`` when multi-pod)  — batch dimension of activations;
    optionally FSDP shards of parameters/optimizer state.
  * ``model`` — tensor parallelism: attention heads / MLP hidden dim /
    vocab, and **expert parallelism** for MoE (experts live on the model
    axis, the standard TPU EP mapping).

Rules are name-based over the parameter tree path — megatron-style:

  wq/wk/wv : [.., D, H*hd]  -> (.., fsdp?, model)     column-parallel
  attn wo  : [.., H*hd, D]  -> (.., model, fsdp?)     row-parallel
  mlp wi/wg: [.., D, F]     -> (.., fsdp?, model)
  mlp wo   : [.., F, D]     -> (.., model, fsdp?)
  moe wi/wg/wo: [L, E, ...] -> (None, model, ...)     expert-parallel
  embed tok: [V, D]         -> (model, fsdp?)         vocab-parallel
  lm head  : [D, V]         -> (fsdp?, model)
  norms/biases/scalars      -> replicated

KV caches shard batch over data and sequence over model (kv-head counts
rarely divide the model axis); B==1 long-context shards sequence over
every axis.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..optim.quant import QTensor


@dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    tensor_axis: str = "model"
    fsdp: bool = False                     # shard params over data axes too
    fsdp_axes: Tuple[str, ...] = ("data",)
    #: Megatron-style sequence parallelism: between-block activations are
    #: sharded over (batch, seq) instead of (batch,), turning per-layer
    #: all-reduces into reduce-scatter/all-gather pairs and making the
    #: (token-local) MLP communication-free.
    sequence_parallel: bool = False

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    def axis_size(self, name) -> int:
        if isinstance(name, tuple):
            return int(np.prod([self.axis_size(n) for n in name]))
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[name]


def _last2(path: Tuple[str, ...]) -> Tuple[str, str]:
    names = [p for p in path]
    leaf = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    return parent, leaf


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


# column-parallel (output dim sharded on model axis)
_COL = {"wq", "wk", "wv", "wi", "wg", "in_proj", "wr", "head"}
# row-parallel (input dim sharded on model axis)
_ROW = {"wo", "wv_cm", "out_proj"}
# always replicated
_REPL = {"router", "wa", "wb", "conv_w", "mu", "w0", "u", "ln_x", "a_log",
         "dt_bias", "d_skip", "norm", "norm1", "norm2", "norm3", "gate_norm",
         "final_norm", "enc_norm", "bq", "bk", "bv"}


def _base_spec(rules: MeshRules, path: Tuple[str, ...], ndim: int,
               shape: Tuple[int, ...]) -> P:
    parent, leaf = _last2(path)
    ta = rules.tensor_axis
    tsize = rules.axis_size(ta)

    # channel-mix wv is row-parallel but shares the name "wv"
    if parent == "cm" and leaf == "wv":
        leaf = "wv_cm"
    if parent == "cm" and leaf == "wk":
        leaf = "wi"  # [D, F] column-parallel

    if leaf == "tok":  # embedding [V, D]
        return P(ta, None) if shape[0] % tsize == 0 else P(None, None)

    is_moe = parent in ("moe",) or (len(path) >= 2 and "moe" in path)
    if is_moe and leaf in ("wi", "wg", "wo") and ndim >= 3:
        # [L?, E, D, F] — expert parallel on E
        spec = [None] * ndim
        e_dim = ndim - 3
        if shape[e_dim] % tsize == 0:
            spec[e_dim] = ta
        return P(*spec)

    if leaf in _REPL:
        return P(*([None] * ndim))

    if leaf in _COL and ndim >= 2:
        spec = [None] * ndim
        if shape[-1] % tsize == 0:
            spec[-1] = ta
        return P(*spec)

    if leaf in _ROW and ndim >= 2:
        spec = [None] * ndim
        if shape[-2] % tsize == 0:
            spec[-2] = ta
        return P(*spec)

    return P(*([None] * ndim))


def _add_fsdp(rules: MeshRules, spec: P, shape: Tuple[int, ...],
              skip_first: bool) -> P:
    """Shard the first free (None) dim over the fsdp axes if divisible."""
    if not rules.fsdp:
        return spec
    fa = rules.fsdp_axes if len(rules.fsdp_axes) > 1 else rules.fsdp_axes[0]
    fsize = rules.axis_size(rules.fsdp_axes if len(rules.fsdp_axes) > 1
                            else rules.fsdp_axes[0])
    parts = list(spec) + [None] * (len(shape) - len(spec))
    start = 1 if skip_first and len(shape) > 2 else 0
    for i in range(start, len(shape)):
        if parts[i] is None and shape[i] % fsize == 0 and shape[i] >= 512:
            parts[i] = fa
            break
    return P(*parts)


def param_spec(rules: MeshRules, path, leaf) -> P:
    names = _path_names(path)
    shape = tuple(leaf.shape)
    spec = _base_spec(rules, names, len(shape), shape)
    stacked = "layers" in names or "enc_layers" in names
    return _add_fsdp(rules, spec, shape, skip_first=stacked)


def param_sharding(rules: MeshRules, params_shape) -> Any:
    """Tree of NamedSharding matching an (abstract) params tree."""
    def one(path, leaf):
        return NamedSharding(rules.mesh, param_spec(rules, path, leaf))
    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_state_sharding(rules: MeshRules, opt_shape) -> Any:
    """m/v mirror the param sharding. An int8 QTensor's payload keeps the
    parameter's shape (and therefore its sharding); its per-row scale
    drops the last spec entry."""

    def one(path, leaf):
        names = _path_names(path)
        if isinstance(leaf, QTensor):
            raise TypeError("flatten QTensors before sharding")
        if names and names[-1] == "count":
            return NamedSharding(rules.mesh, P())
        if names and names[-1] == "q":
            # strip "m"/"v" prefix and the "q" leaf key
            spec = param_spec(rules, path[1:-1], leaf)
            return NamedSharding(rules.mesh, spec)
        if names and names[-1] == "scale":
            parent = path[1:-1]

            class _Fake:  # parameter-shaped stand-in (scale = shape[:-1])
                shape = tuple(leaf.shape) + (1,)
                dtype = leaf.dtype
            spec = param_spec(rules, parent, _Fake)
            return NamedSharding(rules.mesh, P(*tuple(spec)[:len(leaf.shape)]))
        # plain m/v leaf: strip the leading "m"/"v" key, reuse param rule
        return NamedSharding(rules.mesh, param_spec(rules, path[1:], leaf))
    return jax.tree_util.tree_map_with_path(one, opt_shape)


def batch_sharding(rules: MeshRules, batch_shape) -> Any:
    """tokens/labels [B, S]; frames/patches [B, T, D]."""
    ba = rules.batch_axes
    bsize = rules.axis_size(ba)

    def one(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(rules.mesh, P())
        if leaf.shape[0] % bsize == 0:
            return NamedSharding(rules.mesh,
                                 P(ba, *([None] * (leaf.ndim - 1))))
        return NamedSharding(rules.mesh, P(*([None] * leaf.ndim)))
    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_sharding(rules: MeshRules, cache_shape) -> Any:
    """KV caches [L, B, S, K, hd]; ssm states [L, B, H, ...]."""
    ba = rules.batch_axes
    ta = rules.tensor_axis
    bsize = rules.axis_size(ba)
    tsize = rules.axis_size(ta)

    def one(path, leaf):
        names = _path_names(path)
        leafname = names[-1] if names else ""
        nd = leaf.ndim
        if leafname == "lengths":
            shard_b = leaf.shape[0] % bsize == 0
            return NamedSharding(rules.mesh, P(ba) if shard_b else P(None))
        spec = [None] * nd
        if leafname in ("k", "v", "enc_k", "enc_v", "attn_k", "attn_v"):
            # [L|G, B, S, K, hd]
            B, S, K = leaf.shape[1], leaf.shape[2], leaf.shape[3]
            if B % bsize == 0:
                spec[1] = ba
                if K % tsize == 0:
                    spec[3] = ta
                elif S % tsize == 0:
                    spec[2] = ta
            else:  # B == 1 long-context: shard sequence over everything
                both = ba + (ta,)
                if S % rules.axis_size(both) == 0:
                    spec[2] = both
                elif S % tsize == 0:
                    spec[2] = ta
        elif leafname in ("wkv", "ssm"):
            # [L, B, H, ...] — heads over model, batch over data
            B, H = leaf.shape[1], leaf.shape[2]
            if B % bsize == 0:
                spec[1] = ba
            if H % tsize == 0:
                spec[2] = ta
        elif leafname in ("tm_x", "cm_x", "conv"):
            B = leaf.shape[1]
            if B % bsize == 0:
                spec[1] = ba
            if leaf.shape[-1] % tsize == 0:
                spec[-1] = ta
        return NamedSharding(rules.mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def state_sharding(rules: MeshRules, state_shape) -> Dict[str, Any]:
    return {
        "params": param_sharding(rules, state_shape["params"]),
        "opt": opt_state_sharding(rules, state_shape["opt"]),
    }
