from .rules import (MeshRules, param_sharding, param_spec,  # noqa: F401
                    opt_state_sharding, batch_sharding, cache_sharding,
                    state_sharding)
