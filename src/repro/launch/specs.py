"""ShapeDtypeStruct stand-ins for every model input (dry-run inputs).

``build_cell(arch, shape, rules)`` returns the function to lower and the
abstract, sharding-annotated arguments for one (architecture x input
shape) cell — no device memory is ever allocated.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import configs
from ..models import SHAPES, LONG_CONTEXT_ARCHS, build_model
from ..models.config import ModelConfig, ShapeConfig
from ..optim import AdamWConfig, adamw_init
from ..serve import make_serve_step
from ..sharding import (MeshRules, batch_sharding, cache_sharding,
                        opt_state_sharding, param_sharding)
from ..sharding.ctx import activation_sharding
from ..train import make_train_step

#: per-arch dry-run knobs: microbatches for train_4k, optimizer state dtype
CELL_TUNING: Dict[str, Dict[str, Any]] = {
    "llama3-8b": dict(microbatches=16),
    "qwen1.5-4b": dict(microbatches=16),
    "qwen1.5-0.5b": dict(microbatches=4),
    "minicpm-2b": dict(microbatches=8),
    "phi3.5-moe-42b-a6.6b": dict(microbatches=8),
    "kimi-k2-1t-a32b": dict(microbatches=16, state_dtype="int8"),
    "rwkv6-7b": dict(microbatches=8),
    "internvl2-2b": dict(microbatches=8),
    "zamba2-2.7b": dict(microbatches=8),
    "seamless-m4t-medium": dict(microbatches=4),
}

#: decoder-side encoder-memory length for enc-dec decode cells
ENCDEC_ENC_LEN = 4096


def cell_is_skipped(arch: str, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return ("full-attention arch: long_500k needs sub-quadratic "
                "attention (see DESIGN.md §4)")
    return None


def _abstract(tree, shardings):
    def one(leaf, sh):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=sh)
    return jax.tree.map(one, tree, shardings)


def make_opt_config(cfg: ModelConfig, tuning: Dict[str, Any]) -> AdamWConfig:
    return AdamWConfig(lr=3e-4, state_dtype=tuning.get("state_dtype", "float32"))


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.num_prefix_embeddings, cfg.d_model), cfg.act_dtype())
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               cfg.act_dtype())
    return batch


def _with_activation_ctx(fn, rules: MeshRules):
    """Trace-time activation-constraint policy (see sharding/ctx.py)."""
    def wrapped(*args):
        with activation_sharding(rules):
            return fn(*args)
    return wrapped


@dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    kind: str                       # train | prefill | decode
    fn: Callable                    # the function to jit
    args: Tuple[Any, ...]           # abstract, sharded arguments
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


def build_cell(arch: str, shape_name: str, rules: MeshRules,
               overrides: Optional[Dict[str, Any]] = None) -> Cell:
    """overrides: microbatches, state_dtype, num_layers (analysis),
    scan_layers (analysis), unroll_microbatches (analysis)."""
    shape = SHAPES[shape_name]
    cfg = configs.get_config(arch)
    tuning = dict(CELL_TUNING.get(arch, {}))
    tuning.update(overrides or {})
    if tuning.get("num_layers"):
        repl = dict(num_layers=int(tuning["num_layers"]))
        if cfg.is_encdec:
            repl["encoder_layers"] = int(tuning["num_layers"])
        cfg = cfg.replace(**repl)
    if "scan_layers" in tuning:
        cfg = cfg.replace(scan_layers=bool(tuning["scan_layers"]))
    for knob in ("moe_impl", "decode_cache_update", "remat",
                 "capacity_factor"):
        if knob in tuning:
            cfg = cfg.replace(**{knob: tuning[knob]})
    model = build_model(cfg)

    params_shape = model.abstract_params()
    p_shard = param_sharding(rules, params_shape)

    if shape.kind == "train":
        opt_cfg = make_opt_config(cfg, tuning)
        state_shape = jax.eval_shape(
            lambda p: {"params": p, "opt": adamw_init(opt_cfg, p)},
            params_shape)
        s_shard = {"params": p_shard,
                   "opt": opt_state_sharding(rules, state_shape["opt"])}
        b_shape = batch_struct(cfg, shape)
        b_shard = batch_sharding(rules, b_shape)
        step = make_train_step(
            model, opt_cfg,
            num_microbatches=tuning.get("microbatches", 1),
            unroll_microbatches=bool(tuning.get("unroll_microbatches")))
        args = (_abstract(state_shape, s_shard), _abstract(b_shape, b_shard))
        return Cell(arch, shape, cfg, "train",
                    _with_activation_ctx(step, rules), args,
                    in_shardings=(s_shard, b_shard),
                    out_shardings=(s_shard, None),
                    donate_argnums=(0,))

    if shape.kind == "prefill":
        b_shape = dict(batch_struct(cfg, shape))
        b_shape.pop("labels")
        b_shard = batch_sharding(rules, b_shape)
        max_len = shape.seq_len + (cfg.num_prefix_embeddings or 0)
        if cfg.family == "encdec":
            max_len = shape.seq_len

        def prefill_fn(params, batch):
            return model.prefill(params, batch, max_len)

        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, max_len,
                                     enc_len=shape.seq_len
                                     if cfg.family == "encdec" else 0))
        c_shard = cache_sharding(rules, cache_shape)
        args = (_abstract(params_shape, p_shard), _abstract(b_shape, b_shard))
        return Cell(arch, shape, cfg, "prefill",
                    _with_activation_ctx(prefill_fn, rules), args,
                    in_shardings=(p_shard, b_shard),
                    out_shardings=(None, c_shard))

    # decode: one new token against a cache of seq_len
    B = shape.global_batch
    enc_len = ENCDEC_ENC_LEN if cfg.family == "encdec" else 0
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len, enc_len=enc_len))
    c_shard = cache_sharding(rules, cache_shape)
    tok_shape = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_shard = batch_sharding(rules, tok_shape)
    serve = make_serve_step(model)
    args = (_abstract(params_shape, p_shard),
            _abstract(cache_shape, c_shard),
            _abstract(tok_shape, tok_shard))
    return Cell(arch, shape, cfg, "decode",
                _with_activation_ctx(serve, rules), args,
                in_shardings=(p_shard, c_shard, tok_shard),
                out_shardings=(tok_shard, None, c_shard),
                donate_argnums=(1,))
