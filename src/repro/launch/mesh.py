"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch JAX device state — the dry-run must set
``xla_force_host_platform_device_count`` before any device query.
"""

from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — "
            "run under launch/dryrun.py (it forces 512 host devices)")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devices[:need], axis_types=auto)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires >= prod(shape) host devices)."""
    need = int(np.prod(shape))
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:need],
                         axis_types=auto)
