import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -------------------------------------
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production mesh, prove the sharding is coherent, and extract
the roofline inputs (memory analysis, FLOPs, bytes, collective schedule).

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Artifacts: one JSON per cell under benchmarks/artifacts/dryrun/ —
consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Honored environment overrides (must be set before launch):
    REPRO_DRYRUN_DEVICES   host device count (default 512)
    REPRO_DRYRUN_MB        override microbatch count
"""

if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, List, Optional, Tuple  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from .. import configs  # noqa: E402
from ..models import SHAPES  # noqa: E402
from ..sharding import MeshRules  # noqa: E402
from .cost_model import estimate_cost  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .specs import CELL_TUNING, build_cell, cell_is_skipped  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/artifacts/dryrun")

# TPU v5e constants (per chip) — given by the assignment brief.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:%\S+\s*=\s*)?"
    r"(?P<outtype>\(?[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(", re.MULTILINE)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of every tensor shape in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Per-op collective byte totals from compiled (post-SPMD) HLO.

    Bytes counted are the per-device *output* sizes of each collective op
    (operand bytes as seen by one participant). The roofline's collective
    term divides the summed bytes by per-chip link bandwidth, matching the
    assignment's formula.
    """
    per_op: Dict[str, Dict[str, float]] = {}
    biggest: List[Tuple[int, str, str]] = []
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        op = m.group("op")
        nbytes = _shape_bytes(m.group("outtype"))
        d = per_op.setdefault(op, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += nbytes
        biggest.append((nbytes, op, m.group("outtype")[:80]))
    biggest.sort(reverse=True)
    total = sum(d["bytes"] for d in per_op.values())
    return {"per_op": per_op, "total_bytes": int(total),
            "largest": [{"bytes": b, "op": o, "type": t}
                        for b, o, t in biggest[:8]]}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE), D = tokens."""
    total, active = cfg.param_counts()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch


def _compile_collectives(arch: str, shape_name: str, rules,
                         overrides: Dict[str, Any]) -> Dict[str, float]:
    """Compile one (small, fully unrolled) analysis variant and return its
    collective bytes + raw cost-analysis numbers (per device)."""
    cell = build_cell(arch, shape_name, rules, overrides)
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate_argnums)
    with rules.mesh:
        compiled = jitted.lower(*cell.args).compile()
    coll = parse_collectives(compiled.as_text())
    cost = compiled.cost_analysis() or {}
    return {
        "coll_bytes": float(coll["total_bytes"]),
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "per_op": coll["per_op"],
    }


def extrapolate_collectives(arch: str, shape_name: str, rules,
                            tuning: Dict[str, Any]) -> Dict[str, Any]:
    """Fit cost(L, MB) = A0 + L·A1 + MB·B + MB·L·C on fully-unrolled
    analysis compiles, then evaluate at the real (L, MB).

    Needed because XLA cost analysis counts while bodies once: the
    analysis variants unroll layers and microbatches so every collective
    (and FLOP) is visible exactly once, and the fit recovers the full-size
    program exactly for linearly-layered models.
    """
    tuning = {k: v for k, v in tuning.items() if k != "sequence_parallel"}
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    L_full = cfg.num_layers
    step = cfg.attn_every if cfg.family == "hybrid" else 1
    l1, l2 = step, 2 * step
    base = dict(tuning, scan_layers=False, num_layers=l1)

    out: Dict[str, Any] = {"fit_points": {}}
    if shape.kind == "train":
        MB_full = tuning.get("microbatches", 1)
        # MB fit points {2, 4}: at MB=1 XLA merges/elides all-reduces,
        # making the point a bilinear-fit outlier (measured; EXPERIMENTS.md)
        mb1, mb2 = 2, 4
        runs = {}
        for (l, mb) in [(l1, mb1), (l2, mb1), (l1, mb2), (l2, mb2)]:
            ov = dict(base, num_layers=l, microbatches=mb,
                      unroll_microbatches=True)
            runs[(l, mb)] = _compile_collectives(arch, shape_name, rules, ov)
        out["fit_points"] = {f"L{l}_MB{mb}": r["coll_bytes"]
                             for (l, mb), r in runs.items()}

        def fit(key: str) -> float:
            # bilinear cost = a + b·L + c·MB + d·L·MB
            m1, m2 = runs[(l1, mb1)][key], runs[(l2, mb1)][key]
            m3, m4 = runs[(l1, mb2)][key], runs[(l2, mb2)][key]
            d = ((m4 - m3) - (m2 - m1)) / ((l2 - l1) * (mb2 - mb1))
            b = (m2 - m1) / (l2 - l1) - mb1 * d
            c = (m3 - m1) / (mb2 - mb1) - l1 * d
            a = m1 - l1 * b - mb1 * c - l1 * mb1 * d
            return max(0.0, a + L_full * b + MB_full * c
                       + L_full * MB_full * d)

        out["coll_bytes_per_device"] = fit("coll_bytes")
        out["xla_flops_per_device"] = fit("flops")
        out["xla_bytes_per_device"] = fit("bytes")
        out["per_op_sample"] = runs[(l2, mb1)]["per_op"]
    else:
        runs = {}
        for l in (l1, l2):
            ov = dict(base, num_layers=l)
            runs[l] = _compile_collectives(arch, shape_name, rules, ov)
        out["fit_points"] = {f"L{l}": r["coll_bytes"] for l, r in runs.items()}

        def fit(key: str) -> float:
            m1, m2 = runs[l1][key], runs[l2][key]
            C = (m2 - m1) / (l2 - l1)
            A = m1 - l1 * C
            return max(0.0, A + L_full * C)

        out["coll_bytes_per_device"] = fit("coll_bytes")
        out["xla_flops_per_device"] = fit("flops")
        out["xla_bytes_per_device"] = fit("bytes")
        out["per_op_sample"] = runs[l2]["per_op"]
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: Optional[Dict[str, Any]] = None,
             save: bool = True) -> Dict[str, Any]:
    mesh_name = "multi" if multi_pod else "single"
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_devices": 512 if multi_pod else 256,
    }
    skip = cell_is_skipped(arch, shape_name)
    if skip:
        record.update(status="skipped", reason=skip)
        return _finish(record, save)

    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        record["n_devices"] = mesh.devices.size
        rules = MeshRules(mesh=mesh, fsdp=True,
                          sequence_parallel=bool(
                              (overrides or {}).get("sequence_parallel")))
        if os.environ.get("REPRO_DRYRUN_MB"):
            overrides = dict(overrides or {},
                             microbatches=int(os.environ["REPRO_DRYRUN_MB"]))
        cell = build_cell(arch, shape_name, rules, overrides)

        t0 = time.time()
        with mesh:
            jitted = jax.jit(cell.fn,
                             in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings,
                             donate_argnums=cell.donate_argnums)
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        record["lower_s"] = round(t_lower, 2)
        record["compile_s"] = round(t_compile, 2)

        mem = compiled.memory_analysis()
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    record[k] = int(v)
            args_b = record.get("argument_size_in_bytes", 0)
            temp_b = record.get("temp_size_in_bytes", 0)
            alias_b = record.get("alias_size_in_bytes", 0)
            record["bytes_per_device"] = int(args_b + temp_b)
            record["hbm_ok"] = bool(args_b + temp_b <= 16e9)

        cost = compiled.cost_analysis()
        if cost:  # raw (while-bodies-once) numbers, kept for reference
            record["raw_flops_per_device"] = float(cost.get("flops", 0.0))
            record["raw_bytes_per_device"] = float(
                cost.get("bytes accessed", 0.0))

        hlo = compiled.as_text()
        record["collectives_raw"] = parse_collectives(hlo)
        record["hlo_ops"] = {
            op: hlo.count(f" {op}(") + hlo.count(f" {op}-start(")
            for op in ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute", "fusion",
                       "while", "dot", "convolution")
        }
        del hlo, compiled, lowered, jitted

        # --- scan-aware analytical FLOPs/bytes (global) -------------------
        t0 = time.time()
        est = estimate_cost(cell.fn, *cell.args,
                            n_devices=record["n_devices"])
        record["walk_s"] = round(time.time() - t0, 2)
        record["flops_global"] = est.flops
        record["hbm_bytes_global"] = est.bytes
        record["flops_breakdown"] = {
            k: v for k, v in sorted(est.by_prim.items(),
                                    key=lambda kv: -kv[1])[:8]}

        # --- collective bytes via unrolled-extrapolation compiles ---------
        if not os.environ.get("REPRO_DRYRUN_SKIP_COLL"):
            t0 = time.time()
            tuning = dict(CELL_TUNING.get(arch, {}))
            tuning.update(overrides or {})
            coll = extrapolate_collectives(arch, shape_name, rules, tuning)
            record["coll_fit_s"] = round(time.time() - t0, 2)
            record["collectives"] = coll
            coll_per_dev = coll["coll_bytes_per_device"]
            record["xla_flops_extrapolated_per_device"] = coll[
                "xla_flops_per_device"]
        else:
            coll_per_dev = record["collectives_raw"]["total_bytes"]

        # --- roofline terms (seconds), per the assignment formulas ---------
        n = record["n_devices"]
        record["model_flops"] = model_flops(cell.cfg, cell.shape)
        record["t_compute"] = est.flops / (n * PEAK_FLOPS)
        record["t_memory"] = est.bytes / (n * HBM_BW)
        record["t_collective"] = coll_per_dev / ICI_BW
        terms = {"compute": record["t_compute"], "memory": record["t_memory"],
                 "collective": record["t_collective"]}
        record["bottleneck"] = max(terms, key=terms.get)
        record["t_step"] = max(terms.values())
        if record["t_step"] > 0:
            ideal = record["model_flops"] / (n * PEAK_FLOPS)
            record["roofline_fraction"] = ideal / record["t_step"]
            record["useful_flops_fraction"] = (
                record["model_flops"] / est.flops if est.flops else 0.0)
        record["status"] = "ok"
    except Exception as exc:
        record["status"] = "error"
        record["error"] = f"{type(exc).__name__}: {exc}"
        record["traceback"] = traceback.format_exc()[-4000:]
    return _finish(record, save)


def _finish(record: Dict[str, Any], save: bool) -> Dict[str, Any]:
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        name = f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
        with open(os.path.join(ARTIFACT_DIR, name), "w") as f:
            json.dump(record, f, indent=2, default=str)
    status = record["status"]
    extra = ""
    if status == "ok":
        extra = (f"compile={record.get('compile_s')}s "
                 f"bottleneck={record.get('bottleneck')} "
                 f"roofline={record.get('roofline_fraction', 0):.3f} "
                 f"mem/dev={record.get('bytes_per_device', 0) / 1e9:.2f}GB")
    elif status == "error":
        extra = record.get("error", "")[:200]
    else:
        extra = record.get("reason", "")[:80]
    print(f"[dryrun] {record['arch']:24s} {record['shape']:12s} "
          f"{record['mesh']:6s} {status:8s} {extra}", flush=True)
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = configs.ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                if args.skip_existing:
                    name = (f"{arch}__{shape}__"
                            f"{'multi' if multi else 'single'}.json")
                    path = os.path.join(ARTIFACT_DIR, name)
                    if os.path.exists(path):
                        with open(path) as f:
                            if json.load(f).get("status") in ("ok", "skipped"):
                                continue
                rec = run_cell(arch, shape, multi)
                if rec["status"] == "error":
                    failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
