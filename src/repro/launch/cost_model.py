"""Scan-aware analytical cost model over jaxprs.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body **once**,
so anything under ``lax.scan`` (layers, microbatches, recurrences) is
undercounted by its trip count (verified empirically — see
EXPERIMENTS.md §Dry-run "methodology"). This walker traverses the traced
jaxpr instead, multiplying scan bodies by their static lengths, giving
trip-count-exact FLOPs and a *fused-ideal* HBM byte estimate.

FLOP conventions:
  * dot_general / ragged_dot: 2·M·N·K (×batch dims)
  * elementwise / reduce: 1 flop per element (transcendentals included —
    documented simplification)
  * everything else: 0

Byte model ("fused-ideal" — what a perfectly fused TPU program must still
move through HBM):
  * dot operands + outputs, EXCEPT (a) operands that are the enclosing
    scan's per-iteration xs/carry (already counted at the scan level) and
    (b) outputs whose per-device size fits VMEM (attention score tiles,
    online-softmax state — a flash kernel never spills them);
  * gather/scatter/dynamic-slice/-update outputs (+ operand for scatter)
  * scan: per-iteration xs slices + ys slices ×length; carry read/write
    ×length only when the per-device carry exceeds the VMEM budget
    (a layer-scan's [B,S,D] activations stream through HBM; an SSM
    recurrence's [heads, P, N] state stays resident)
  * top-level invars (params/opt/batch read once) + outvars (state write)
  * elementwise / broadcast / transpose / reshape / convert: free (fused)

Both terms are computed on the *global* (pre-SPMD) program; divide by
chip count for per-chip values. Sharding-induced redundancy (e.g. remat
of replicated compute) is therefore not included — the extrapolated
cost-analysis cross-check in dryrun.py covers that direction.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict

import jax
import numpy as np
from jax.extend import core

__all__ = ["estimate_cost", "CostEstimate"]

#: per-device bytes below which an intermediate is assumed VMEM-resident
VMEM_BUDGET = 8 * 1024 * 1024


@dataclass
class CostEstimate:
    flops: float = 0.0
    bytes: float = 0.0
    by_prim: Dict[str, float] = field(default_factory=dict)
    bytes_by_prim: Dict[str, float] = field(default_factory=dict)

    def add(self, prim: str, flops: float, nbytes: float) -> None:
        self.flops += flops
        self.bytes += nbytes
        self.by_prim[prim] = self.by_prim.get(prim, 0.0) + flops
        self.bytes_by_prim[prim] = self.bytes_by_prim.get(prim, 0.0) + nbytes


def _nbytes(aval) -> float:
    try:
        return float(np.prod(aval.shape) * aval.dtype.itemsize)
    except Exception:
        return 0.0


def _size(aval) -> float:
    try:
        return float(np.prod(aval.shape))
    except Exception:
        return 0.0


_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "pow", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "neg", "sign", "abs", "floor", "ceil",
    "round", "erf", "exp2", "log1p", "expm1", "integer_pow", "select_n",
    "ge", "gt", "le", "lt", "eq", "ne", "and", "or", "not", "xor", "rem",
    "clamp", "nextafter", "is_finite", "square", "cos", "sin", "atan2",
    "cumsum", "cumprod", "cummax",
}

_REDUCE = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
           "reduce_and", "reduce_or", "argmax", "argmin", "reduce_precision",
           "logsumexp"}

_GATHERISH = {"gather", "dynamic_slice", "take", "take_along_axis"}
_SCATTERISH = {"scatter", "scatter-add", "scatter_add", "dynamic_update_slice",
               "scatter_apply"}

_FREE = {"broadcast_in_dim", "reshape", "transpose", "convert_element_type",
         "squeeze", "expand_dims", "slice", "rev", "iota", "copy",
         "stop_gradient", "device_put", "sharding_constraint", "pad",
         "concatenate", "split"}

_CALL_PARAM_NAMES = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr")


def _dot_flops(eqn) -> float:
    (lhs, rhs) = eqn.invars[:2]
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    lshape = lhs.aval.shape
    m = np.prod([d for i, d in enumerate(lshape)
                 if i not in lc and i not in lb], initial=1.0)
    k = np.prod([lshape[i] for i in lc], initial=1.0)
    b = np.prod([lshape[i] for i in lb], initial=1.0)
    rshape = rhs.aval.shape
    n = np.prod([d for i, d in enumerate(rshape)
                 if i not in rc and i not in rb], initial=1.0)
    return 2.0 * b * m * n * k


def _ragged_dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval.shape, eqn.invars[1].aval.shape
    # lhs [m, k], rhs [g, k, n] -> [m, n]; every row multiplies one group
    m, k = lhs[-2], lhs[-1]
    n = rhs[-1]
    return 2.0 * m * k * n


def _walk(jaxpr, est: CostEstimate, mult: float, n_dev: int,
          loop_vars: frozenset) -> None:
    """loop_vars: body invars fed by the enclosing scan's xs/carry — their
    bytes are already accounted at the scan level."""
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name

        if name == "scan":
            length = float(eqn.params.get("length", 1))
            num_consts = eqn.params.get("num_consts", 0)
            num_carry = eqn.params.get("num_carry", 0)
            inner = eqn.params["jaxpr"].jaxpr
            body_loop_vars = frozenset(inner.invars[num_consts:])
            _walk(inner, est, mult * length, n_dev, body_loop_vars)
            # xs per-iteration slices + ys writes. An xs whose only body
            # use is as the in-place target of dynamic_update_slice is a
            # pass-through buffer (donated KV cache): no full read.
            uses = {}
            for beqn in inner.eqns:
                for iv in beqn.invars:
                    if not isinstance(iv, core.Literal):
                        uses.setdefault(iv, []).append(beqn)
            xs_bytes = 0.0
            for bv in inner.invars[num_consts + num_carry:]:
                bv_uses = uses.get(bv, [])
                inplace_only = bool(bv_uses) and all(
                    u.primitive.name == "dynamic_update_slice"
                    and u.invars and u.invars[0] is bv for u in bv_uses)
                if not inplace_only:
                    xs_bytes += _nbytes(bv.aval)
            xs_bytes *= length  # body invars are per-iteration slices
            carry_bytes = sum(_nbytes(v.aval)
                              for v in eqn.invars[num_consts:num_consts + num_carry])
            # ys produced in place (dynamic_update_slice of a body input,
            # e.g. a donated KV cache) cost only their update slice
            ys_bytes = 0.0
            def_eqn = {}
            for beqn in inner.eqns:
                for ov in beqn.outvars:
                    def_eqn[ov] = beqn
            for ov in inner.outvars[num_carry:]:
                src = def_eqn.get(ov, None) if hasattr(ov, "aval") else None
                if (src is not None and
                        src.primitive.name == "dynamic_update_slice" and
                        src.invars and not isinstance(src.invars[0],
                                                      core.Literal)
                        and src.invars[0] in body_loop_vars):
                    ys_bytes += _nbytes(src.invars[1].aval)  # update slice
                else:
                    ys_bytes += _nbytes(ov.aval)             # per-iter full
            ys_bytes *= length
            traffic = xs_bytes + ys_bytes
            # carry streams HBM per iteration only if it exceeds VMEM
            if carry_bytes / n_dev > VMEM_BUDGET:
                traffic += 2 * length * carry_bytes
            else:
                traffic += 2 * carry_bytes
            est.add("scan_traffic", 0.0, mult * traffic)
            continue

        if name == "shard_map":
            # body shapes are PER-SHARD: global cost = body x mesh size
            mesh = eqn.params.get("mesh")
            size = getattr(mesh, "size", None) or int(
                np.prod(getattr(mesh, "axis_sizes", (1,))))
            inner = eqn.params["jaxpr"]
            inner = inner.jaxpr if hasattr(inner, "jaxpr") else inner
            _walk(inner, est, mult * size, n_dev, frozenset())
            continue

        if name == "while":
            # we never emit unbounded whiles in model code; count body once
            _walk(eqn.params["body_jaxpr"].jaxpr, est, mult, n_dev,
                  frozenset())
            est.by_prim["UNSCALED_WHILE"] = est.by_prim.get(
                "UNSCALED_WHILE", 0) + 1
            continue

        if name == "cond":
            branches = eqn.params["branches"]
            sub = CostEstimate()
            for br in branches:
                b_est = CostEstimate()
                _walk(br.jaxpr, b_est, mult, n_dev, frozenset())
                if b_est.flops > sub.flops:
                    sub = b_est
            est.flops += sub.flops
            est.bytes += sub.bytes
            continue

        handled_call = False
        for pname in _CALL_PARAM_NAMES:
            if pname in eqn.params:
                inner = eqn.params[pname]
                inner = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                # map loop-var status through the call boundary
                sub_loop = frozenset(
                    bv for bv, ov in zip(inner.invars, eqn.invars)
                    if not isinstance(ov, core.Literal) and ov in loop_vars)
                _walk(inner, est, mult, n_dev, sub_loop)
                handled_call = True
                break
        if handled_call:
            continue

        # propagate loop-var (already-counted) status through layout ops so
        # e.g. a convert(xs_slice) fed to a dot is not double-counted
        if name in _FREE or name == "convert_element_type":
            if (eqn.invars and all(
                    isinstance(v, core.Literal) or v in loop_vars
                    for v in eqn.invars if hasattr(v, "aval"))):
                loop_vars = loop_vars | frozenset(
                    ov for ov in eqn.outvars)
            continue

        out_bytes = sum(_nbytes(v.aval) for v in eqn.outvars)
        in_bytes = sum(_nbytes(v.aval) for v in eqn.invars
                       if hasattr(v, "aval") and
                       (isinstance(v, core.Literal) or v not in loop_vars))
        out_size = sum(_size(v.aval) for v in eqn.outvars)
        # small outputs stay VMEM-resident in a fused kernel
        out_traffic = out_bytes if out_bytes / n_dev > VMEM_BUDGET else 0.0

        if name == "dot_general":
            est.add("dot_general", mult * _dot_flops(eqn),
                    mult * (in_bytes + out_traffic))
        elif name == "ragged_dot":
            est.add("ragged_dot", mult * _ragged_dot_flops(eqn),
                    mult * (in_bytes + out_traffic))
        elif name in ("conv_general_dilated",):
            # depthwise convs in mamba are tiny; approximate via im2col dot
            est.add(name, mult * 2 * out_size *
                    np.prod(eqn.invars[1].aval.shape[:2]),
                    mult * (in_bytes + out_traffic))
        elif name in _ELEMENTWISE:
            est.add("elementwise", mult * out_size, 0.0)
        elif name in _REDUCE:
            est.add("reduce", mult * sum(_size(v.aval) for v in eqn.invars
                                         if hasattr(v, "aval")), 0.0)
        elif name in _GATHERISH:
            # gathered/sliced data streams from HBM regardless of size
            # (e.g. KV blocks re-read per query block in flash attention);
            # downstream consumers of the fetched block don't re-pay
            est.add("gather", 0.0, mult * out_bytes)
            loop_vars = loop_vars | frozenset(eqn.outvars)
        elif name == "dynamic_update_slice":
            # in-place update model: only the slice (+indices) moves; the
            # big operand was counted where it was produced/read
            est.add("scatter", 0.0, mult * in_bytes)
        elif name in _SCATTERISH:
            est.add("scatter", 0.0, mult * (in_bytes + out_traffic))
        elif name in ("sort", "top_k"):
            n = max(out_size, 1.0)
            est.add(name, mult * n * math.log2(max(n, 2)),
                    mult * (in_bytes + out_traffic))
        elif name in _FREE:
            pass
        else:
            est.add(f"other:{name}", mult * out_size, 0.0)


def estimate_cost(fn, *abstract_args, n_devices: int = 256) -> CostEstimate:
    """Trace ``fn`` with abstract args and walk the jaxpr.

    Traffic is attributed at the op that moves it (dots read weights,
    scans stream xs/ys, gathers/scatters move slices); there is no
    separate top-level io term, so purely-elementwise passes over state
    (the optimizer update's read-modify-write) are a documented
    undercount, bounded by ~3x the parameter+state bytes."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    est = CostEstimate()
    _walk(closed.jaxpr, est, 1.0, max(n_devices, 1), frozenset())
    return est
