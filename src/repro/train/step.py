"""train_step: loss + grads (optionally microbatched) + AdamW update.

The returned function is pure and jit/pjit-friendly:

    new_state, metrics = train_step(state, batch)

Microbatch gradient accumulation runs as a ``lax.scan`` over microbatch
slices (activation memory / num_microbatches), composing with per-layer
remat inside the model. This is the standard memory-for-FLOPs knob the
roofline analysis iterates on.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.model import Model
from ..optim import AdamWConfig, adamw_init, adamw_update

TrainState = Dict[str, Any]


def init_train_state(model: Model, opt_cfg: AdamWConfig, rng) -> TrainState:
    params = model.init(rng)
    return {"params": params, "opt": adamw_init(opt_cfg, params)}


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    num_microbatches: int = 1, unroll_microbatches: bool = False):
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if num_microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics

        def slice_mb(x, i):
            mb = x.shape[0] // num_microbatches
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def body(carry, i):
            acc, msum = carry
            mb_batch = jax.tree.map(lambda x: slice_mb(x, i), batch)
            (_, metrics), grads = grad_fn(params, mb_batch)
            acc = jax.tree.map(jnp.add, acc, grads)
            msum = jax.tree.map(jnp.add, msum, metrics)
            return (acc, msum), None

        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        zero_metrics = {k: jnp.zeros((), jnp.float32)
                        for k in ("loss", "ce", "aux", "accuracy")}
        carry = (zero_grads, zero_metrics)
        if unroll_microbatches:
            # analysis mode: every microbatch visible to XLA cost analysis
            for i in range(num_microbatches):
                carry, _ = body(carry, jnp.int32(i))
            grads, msum = carry
        else:
            (grads, msum), _ = jax.lax.scan(
                body, carry, jnp.arange(num_microbatches))
        inv = 1.0 / num_microbatches
        grads = jax.tree.map(lambda g: g * inv, grads)
        metrics = jax.tree.map(lambda m: m * inv, msum)
        return grads, metrics

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        grads, metrics = compute_grads(state["params"], batch)
        # Pin gradients to the parameter sharding before the optimizer:
        # without this XLA SPMD may realize FSDP gradient reduction as
        # full all-reduces (2x the bytes of reduce-scatter) since the
        # unconstrained grads have no preferred placement.
        from ..sharding.ctx import current_rules
        rules = current_rules()
        if rules is not None:
            from ..sharding import param_sharding
            shardings = param_sharding(rules, grads)
            grads = jax.tree.map(jax.lax.with_sharding_constraint,
                                 grads, shardings)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state["opt"], state["params"])
        metrics = dict(metrics, **opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
