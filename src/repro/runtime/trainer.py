"""ServerlessTrainer: the paper's technique as a training control plane.

The data plane is a jitted ``train_step``; the control plane is the
transparent multiprocessing substrate:

  * the *step loop* is resumable: state checkpoints to object storage
    (CheckpointManager), step counter + metrics live in the KV store;
  * **fault tolerance**: on construction the trainer restores the newest
    checkpoint and continues — kill the process at any step and rerun,
    the loss curve is bit-identical (tests/test_trainer.py);
  * optional **serverless data parallelism**: per-step gradient shards
    are computed by JobRunner workers (lease + retry + speculation) and
    merged by the orchestrator — message-passing all the way (the paper's
    Table 3 lesson), with optional top-k/int8 compression to keep the
    KV-store hop off the critical path.

On real TPU fleets the inner ``train_step`` is the pjit program from
launch/specs.py and one "worker" = one pod; on this CPU container workers
are threads and the model is a smoke-sized config — same control path.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..core import session as _session
from .checkpoint import CheckpointManager
from .compression import ErrorFeedback
from .jobs import JobRunner

__all__ = ["ServerlessTrainer"]


class ServerlessTrainer:
    def __init__(self, train_step: Callable, init_state: Callable[[], Any],
                 data_fn: Callable[[int], Dict[str, np.ndarray]],
                 ckpt_prefix: str = "trainer",
                 checkpoint_every: int = 50,
                 session: Optional[_session.Session] = None,
                 runner: Optional[JobRunner] = None):
        self.session = session or _session.get_session()
        self.store = self.session.store
        self.train_step = jax.jit(train_step, donate_argnums=(0,))
        self.data_fn = data_fn
        self.checkpoint_every = checkpoint_every
        self.ckpt = CheckpointManager(prefix=ckpt_prefix, session=self.session,
                                      runner=runner)
        self.metrics_key = f"{{{ckpt_prefix}}}:metrics"
        # resume-or-init
        latest = self.ckpt.latest_step()
        if latest is not None:
            self.step, self.state = self.ckpt.restore(latest)
        else:
            self.step, self.state = 0, init_state()

    def run(self, num_steps: int, log_every: int = 10,
            on_metrics: Optional[Callable[[int, Dict], None]] = None) -> Dict:
        last = {}
        t0 = time.time()
        end = self.step + num_steps
        while self.step < end:
            batch = self.data_fn(self.step)
            self.state, metrics = self.train_step(self.state, batch)
            self.step += 1
            if self.step % log_every == 0 or self.step == end:
                last = {k: float(v) for k, v in metrics.items()}
                last["step"] = self.step
                last["steps_per_s"] = log_every / max(time.time() - t0, 1e-9)
                t0 = time.time()
                self.store.rpush(self.metrics_key,
                                 repr(last).encode())
                if on_metrics:
                    on_metrics(self.step, last)
            if self.step % self.checkpoint_every == 0:
                self.ckpt.save(self.step, self.state)
        # final checkpoint so a subsequent run resumes exactly here
        self.ckpt.save(self.step, self.state)
        return last


class DataParallelTrainer:
    """Gradient computation fanned out over JobRunner workers; the
    orchestrator merges (optionally compressed) gradient messages and
    applies the optimizer — 'serverless DP' per the paper's main/worker
    pattern."""

    def __init__(self, grad_fn: Callable, apply_fn: Callable,
                 init_state: Callable[[], Any],
                 data_fn: Callable[[int, int], Dict[str, np.ndarray]],
                 n_workers: int = 4, compress_ratio: Optional[float] = None,
                 session: Optional[_session.Session] = None):
        self.session = session or _session.get_session()
        self.runner = JobRunner(n_workers=n_workers, session=self.session)
        self.grad_fn = grad_fn          # (params, batch) -> grads (pure)
        self.apply_fn = jax.jit(apply_fn)  # (state, grads) -> state, metrics
        self.state = init_state()
        self.n_workers = n_workers
        self.compress = (ErrorFeedback(compress_ratio)
                         if compress_ratio else None)
        self.data_fn = data_fn
        self.step = 0
        self.bytes_moved = 0

    def train_steps(self, num_steps: int):
        history = []
        for _ in range(num_steps):
            params = self.state["params"]
            grad_fn = self.grad_fn

            def shard_task(shard_id, step=self.step, params=params,
                           grad_fn=grad_fn, data_fn=self.data_fn):
                batch = data_fn(step, shard_id)
                g = grad_fn(params, batch)
                return jax.tree.map(np.asarray, g)

            shard_grads = self.runner.run(shard_task,
                                          list(range(self.n_workers)))
            avg = jax.tree.map(
                lambda *gs: np.mean(np.stack(gs), axis=0), *shard_grads)
            self.bytes_moved += sum(g.nbytes for g in jax.tree.leaves(avg))
            self.state, metrics = self.apply_fn(self.state, avg)
            self.step += 1
            history.append({k: float(v) for k, v in metrics.items()})
        return history

    def shutdown(self):
        self.runner.shutdown()
