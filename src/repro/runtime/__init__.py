from .jobs import JobRunner, JobFailedError  # noqa: F401
from .checkpoint import CheckpointManager  # noqa: F401
from .elastic import ElasticPolicy, ElasticController  # noqa: F401
from .compression import (topk_compress, topk_decompress,  # noqa: F401
                          int8_compress, int8_decompress, ErrorFeedback)
