"""Elastic scaling policy (the paper's core value proposition, §5.3/§6.4:
serverless resources attach instantly and without prior provisioning).

``ElasticController`` watches the job queue depth and worker idleness in
the KV store and resizes a Pool/JobRunner between [min_workers,
max_workers]. Scale-up is aggressive (the whole point of FaaS — §6.4
shows a VM "vertically scaled" with +48 lambdas mid-run); scale-down is
conservative (hysteresis) to avoid thrashing warm containers.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Optional

__all__ = ["ElasticPolicy", "ElasticController"]


@dataclass
class ElasticPolicy:
    min_workers: int = 1
    max_workers: int = 64
    backlog_per_worker: float = 2.0    # scale up above this queue depth
    idle_cycles_before_shrink: int = 5
    step: int = 4                      # workers added per decision

    def decide(self, n_workers: int, backlog: int, idle_cycles: int) -> int:
        if backlog > self.backlog_per_worker * max(n_workers, 1):
            want = min(self.max_workers,
                       max(n_workers + self.step,
                           int(backlog / self.backlog_per_worker)))
            return want
        if backlog == 0 and idle_cycles >= self.idle_cycles_before_shrink:
            return max(self.min_workers, n_workers - self.step)
        return n_workers


class ElasticController:
    """Background controller bound to a Pool or JobRunner (anything with
    ``resize(n)``, ``n_workers`` and a ``{tag}:jobs`` KV list)."""

    def __init__(self, target: Any, policy: Optional[ElasticPolicy] = None,
                 interval: float = 0.2):
        self.target = target
        self.policy = policy or ElasticPolicy()
        self.interval = interval
        self._stop = threading.Event()
        self._idle_cycles = 0
        self.decisions: list = []
        self._thread: Optional[threading.Thread] = None

    def _backlog(self) -> int:
        store = self.target.session.store
        tag = getattr(self.target, "_tag")
        return store.llen(f"{tag}:jobs")

    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            backlog = self._backlog()
            self._idle_cycles = self._idle_cycles + 1 if backlog == 0 else 0
            cur = self.target.n_workers
            want = self.policy.decide(cur, backlog, self._idle_cycles)
            if want != cur:
                self.decisions.append((time.monotonic(), cur, want, backlog))
                self.target.resize(want)
                self._idle_cycles = 0

    def start(self) -> "ElasticController":
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="elastic-controller")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
