"""Elastic scaling policy (the paper's core value proposition, §5.3/§6.4:
serverless resources attach instantly and without prior provisioning).

``ElasticController`` drives a :class:`repro.core.pool.Pool` (or any
object with the same public contract) between ``[min_workers,
max_workers]``. Scale-up is aggressive (the whole point of FaaS — §6.4
shows a VM "vertically scaled" with +48 lambdas mid-run); scale-down is
conservative (hysteresis via ``idle_cycles_before_shrink``) to avoid
thrashing warm containers.

Public contract (PR 9)
----------------------

The controller consumes exactly three documented target members — no
private key-layout knowledge, no reaching into ``target.session``:

* ``target.backlog() -> int`` — outstanding work (queue depth +
  in-flight), one pipelined KV read, **zero KV commands when idle**;
* ``target.n_workers -> int`` — live workers;
* ``target.resize(n)`` — the actuator (graceful drain on scale-down
  when the pool was built with ``elastic`` truthy).

When the backlog hits zero and the fleet has shrunk to the floor, the
controller *parks* on the target's activity event (set by every job
submission) instead of polling — an idle elastic pool adds **no KV
load and no busy polling**; the next submit wakes it immediately.

The usual way to get a controller is ``Pool(elastic=ElasticPolicy(...))``
(or ``configure(pool_defaults={"elastic": {...}})``), which starts one
automatically and stops it in ``close()``/``terminate()``. Constructing
``ElasticController(pool, policy)`` by hand still works for custom
targets and for tests.
"""

from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass
from typing import Any, Optional

__all__ = ["ElasticPolicy", "ElasticController"]


@dataclass
class ElasticPolicy:
    """Threshold policy mapping (n_workers, backlog, idleness) to a
    target fleet size. ``decide()`` is pure — trivially unit-testable —
    and clamps every answer into ``[min_workers, max_workers]``."""

    min_workers: int = 1
    max_workers: int = 64
    backlog_per_worker: float = 2.0    # scale up above this queue depth
    idle_cycles_before_shrink: int = 5
    step: int = 4                      # max workers added/removed per decision

    def __post_init__(self) -> None:
        if self.min_workers < 0:
            raise ValueError("min_workers must be >= 0")
        if self.max_workers < max(self.min_workers, 1):
            raise ValueError("max_workers must be >= max(min_workers, 1)")
        if self.backlog_per_worker <= 0:
            raise ValueError("backlog_per_worker must be > 0")
        if self.step < 1:
            raise ValueError("step must be >= 1")

    def decide(self, n_workers: int, backlog: int, idle_cycles: int) -> int:
        """Target fleet size given the current observation.

        * Overload (``backlog > backlog_per_worker * n_workers``): grow
          toward ``backlog / backlog_per_worker``, by at most ``step``,
          capped at ``max_workers``.
        * Idle (``backlog == 0``) for ``idle_cycles_before_shrink``
          consecutive observations: shrink by ``step``, floored at
          ``min_workers`` (hysteresis — one quiet sample never shrinks).
        * Otherwise: hold steady.
        """
        if backlog > self.backlog_per_worker * max(n_workers, 1):
            want = min(n_workers + self.step,
                       math.ceil(backlog / self.backlog_per_worker))
            return min(self.max_workers, max(self.min_workers, want))
        if backlog == 0 and idle_cycles >= self.idle_cycles_before_shrink:
            return min(n_workers, max(self.min_workers,
                                      n_workers - self.step))
        return n_workers


class ElasticController:
    """Background controller bound to a Pool-contract target (PR 9:
    ``backlog()`` / ``n_workers`` / ``resize(n)`` — see module doc).

    Also integrates **worker-seconds** (∫ n_workers dt) while running:
    the provisioning-cost metric ``benchmarks/bench_elastic.py``
    compares against fixed fleets.
    """

    def __init__(self, target: Any, policy: Optional[ElasticPolicy] = None,
                 interval: float = 0.2, park_timeout: float = 30.0):
        self.target = target
        self.policy = policy or ElasticPolicy()
        self.interval = float(interval)
        #: safety heartbeat while parked: even with no submit activity
        #: the loop wakes this often (backlog() still costs zero KV
        #: commands on an idle pool, so this is CPU-only insurance).
        self.park_timeout = float(park_timeout)
        self._stop = threading.Event()
        self._idle_cycles = 0
        #: (monotonic_t, n_before, n_after, backlog) per resize decision
        self.decisions: list = []
        self._thread: Optional[threading.Thread] = None
        self._ws_lock = threading.Lock()
        self._ws = 0.0
        self._ws_last: Optional[float] = None
        self._ws_n = 0

    # -- worker-seconds accounting -----------------------------------------

    def _integrate(self, now: float, n: int) -> None:
        with self._ws_lock:
            if self._ws_last is not None:
                self._ws += self._ws_n * (now - self._ws_last)
            self._ws_last, self._ws_n = now, n

    def worker_seconds(self) -> float:
        """∫ n_workers dt since ``start()`` — the elastic fleet's
        provisioning cost, comparable to ``n * wall_clock`` for a fixed
        fleet of ``n`` workers."""
        with self._ws_lock:
            ws = self._ws
            if self._ws_last is not None:
                ws += self._ws_n * (time.monotonic() - self._ws_last)
            return ws

    # -- control loop -------------------------------------------------------

    def _observe_once(self) -> None:
        """One observe→decide→act pass (exposed for deterministic tests)."""
        act = getattr(self.target, "_activity", None)
        if act is not None:
            # clear BEFORE sampling: a submit landing after the sample
            # re-sets the event, so the park below can never miss it
            act.clear()
        backlog = int(self.target.backlog())
        self._idle_cycles = self._idle_cycles + 1 if backlog == 0 else 0
        cur = int(self.target.n_workers)
        self._integrate(time.monotonic(), cur)
        want = self.policy.decide(cur, backlog, self._idle_cycles)
        if want != cur:
            self.decisions.append((time.monotonic(), cur, want, backlog))
            self.target.resize(want)
            self._integrate(time.monotonic(), want)
            self._idle_cycles = 0
        self._last_backlog, self._last_n = backlog, min(cur, want)

    def _loop(self) -> None:
        self._last_backlog, self._last_n = 1, 0
        while not self._stop.is_set():
            try:
                self._observe_once()
            except Exception:
                pass  # a decision pass must never kill the controller
            act = getattr(self.target, "_activity", None)
            if (act is not None and self._last_backlog == 0
                    and self._last_n <= self.policy.min_workers):
                # fully drained and at the floor: park event-driven —
                # zero KV commands, zero polling until the next submit
                act.wait(self.park_timeout)
            else:
                self._stop.wait(self.interval)

    def start(self) -> "ElasticController":
        self._integrate(time.monotonic(),
                        int(getattr(self.target, "n_workers", 0)))
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="elastic-controller")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        act = getattr(self.target, "_activity", None)
        if act is not None:
            act.set()  # unpark so the loop observes the stop flag
        if self._thread is not None:
            self._thread.join(timeout=2)
        self._integrate(time.monotonic(), int(self._ws_n))

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
