"""Gradient compression for KV-store-mediated data parallelism.

The paper's Redis saturates around 256 concurrent workers (§6.3) because
a single-threaded store caps aggregate bandwidth. When gradients move
through the disaggregated memory layer (our "serverless DP" examples),
the fix on the *sender* side is compression:

  * top-k sparsification with **error feedback** (residual accumulation,
    Stich et al.) — ~1-2% of values at k=1%, convergence-safe;
  * int8 row quantization (shared with the 8-bit optimizer state).

Both are pure-jnp and measured end-to-end in
benchmarks/bench_compression.py.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..optim.quant import QTensor, dequantize_int8, quantize_int8

__all__ = ["topk_compress", "topk_decompress", "int8_compress",
           "int8_decompress", "ErrorFeedback"]


def topk_compress(x: jax.Array, ratio: float = 0.01
                  ) -> Tuple[jax.Array, jax.Array, Tuple[int, ...]]:
    """Keep the k = ratio*n largest-magnitude entries.
    Returns (indices int32, values, shape)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.size * ratio))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return idx.astype(jnp.int32), flat[idx], x.shape


def topk_decompress(idx: jax.Array, vals: jax.Array,
                    shape: Tuple[int, ...]) -> jax.Array:
    n = int(np.prod(shape))
    return jnp.zeros((n,), vals.dtype).at[idx].set(vals).reshape(shape)


def int8_compress(x: jax.Array) -> QTensor:
    return quantize_int8(x)


def int8_decompress(t: QTensor) -> jax.Array:
    return dequantize_int8(t)


class ErrorFeedback:
    """Residual-accumulating wrapper: compress(g + residual), keep what
    was dropped for the next round. Makes top-k unbiased over time."""

    def __init__(self, ratio: float = 0.01):
        self.ratio = ratio
        self._residual: Dict[str, jax.Array] = {}

    def compress_tree(self, grads):
        out = {}
        flat = jax.tree_util.tree_flatten_with_path(grads)[0]
        for path, g in flat:
            key = jax.tree_util.keystr(path)
            r = self._residual.get(key)
            corrected = g + r if r is not None else g
            idx, vals, shape = topk_compress(corrected, self.ratio)
            self._residual[key] = corrected - topk_decompress(idx, vals, shape)
            out[key] = (np.asarray(idx), np.asarray(vals), shape)
        return out

    @staticmethod
    def decompress_tree(payload, like):
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, g in flat:
            idx, vals, shape = payload[jax.tree_util.keystr(path)]
            leaves.append(topk_decompress(jnp.asarray(idx), jnp.asarray(vals),
                                          shape))
        return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])

    def compressed_bytes(self, payload) -> int:
        return sum(i.nbytes + v.nbytes for i, v, _ in payload.values())
