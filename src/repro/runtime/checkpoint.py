"""Checkpoint/restart over disaggregated object storage (paper §3.3 +
§7.5: fault tolerance comes from retries *plus* durable state).

Array leaves are serialized individually and written **in parallel**
through the job queue — the paper's Fig. 8 point: aggregate object-store
bandwidth (80 GB/s from many functions) dwarfs any single writer, so
checkpoint walls scale with the fleet, not the orchestrator.

Layout:   {prefix}/step-{N}/manifest        (pickled tree structure)
          {prefix}/step-{N}/leaf-{i}        (one object per array)
          {prefix}/LATEST                   (atomic pointer, written last)

``save`` is synchronous by default; ``save_async`` runs in a background
thread so the train loop overlaps checkpoint I/O with compute.
"""

from __future__ import annotations

import io
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core import serialization
from ..core import session as _session

__all__ = ["CheckpointManager"]


def _encode_leaf(x) -> bytes:
    buf = io.BytesIO()
    np.save(buf, np.asarray(x), allow_pickle=False)
    return buf.getvalue()


def _decode_leaf(blob: bytes):
    return np.load(io.BytesIO(blob), allow_pickle=False)


def _put_leaf(key: str, blob: bytes) -> int:
    _session.get_session().get_storage().put(key, blob)
    return len(blob)


def _get_leaf(key: str) -> bytes:
    return _session.get_session().get_storage().get(key)


class CheckpointManager:
    def __init__(self, prefix: str = "ckpt", keep: int = 3,
                 runner: Optional[Any] = None,
                 session: Optional[_session.Session] = None):
        self.prefix = prefix.rstrip("/")
        self.keep = keep
        self.session = session or _session.get_session()
        self._runner = runner          # optional JobRunner for parallel IO
        self._async_thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    # ----------------------------------------------------------------- save

    def save(self, step: int, state: Any) -> Dict[str, Any]:
        storage = self.session.get_storage()
        leaves, treedef = jax.tree.flatten(state)
        base = f"{self.prefix}/step-{step}"
        blobs = [_encode_leaf(x) for x in leaves]
        keys = [f"{base}/leaf-{i}" for i in range(len(blobs))]
        if self._runner is not None:
            self._runner.run(_put_leaf, list(zip(keys, blobs)))
        else:
            for k, b in zip(keys, blobs):
                storage.put(k, b)
        manifest = serialization.dumps(
            {"treedef": treedef, "n_leaves": len(leaves), "step": step})
        storage.put(f"{base}/manifest", manifest)
        # pointer written last => a crash mid-save never corrupts LATEST
        storage.put(f"{self.prefix}/LATEST", str(step).encode())
        self._gc(step)
        return {"step": step, "n_leaves": len(leaves),
                "bytes": sum(len(b) for b in blobs)}

    def save_async(self, step: int, state: Any) -> None:
        """Snapshot to host, then write in the background."""
        host_state = jax.tree.map(np.asarray, state)
        with self._lock:
            self.wait()
            self._async_thread = threading.Thread(
                target=self.save, args=(step, host_state), daemon=True)
            self._async_thread.start()

    def wait(self) -> None:
        t = self._async_thread
        if t is not None and t.is_alive():
            t.join()

    def _gc(self, newest: int) -> None:
        storage = self.session.get_storage()
        steps = sorted(self.steps())
        for old in steps[:-self.keep] if len(steps) > self.keep else []:
            for key in storage.list(f"{self.prefix}/step-{old}/"):
                storage.delete(key)

    # -------------------------------------------------------------- restore

    def steps(self) -> List[int]:
        storage = self.session.get_storage()
        out = set()
        for key in storage.list(f"{self.prefix}/step-"):
            tail = key[len(self.prefix) + 6:]
            out.add(int(tail.split("/", 1)[0]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        storage = self.session.get_storage()
        try:
            return int(storage.get(f"{self.prefix}/LATEST").decode())
        except KeyError:
            return None

    def restore(self, step: Optional[int] = None) -> Tuple[int, Any]:
        storage = self.session.get_storage()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError("no checkpoint found")
        base = f"{self.prefix}/step-{step}"
        meta = serialization.loads(storage.get(f"{base}/manifest"))
        keys = [f"{base}/leaf-{i}" for i in range(meta["n_leaves"])]
        if self._runner is not None:
            blobs = self._runner.run(_get_leaf, keys)
        else:
            blobs = [storage.get(k) for k in keys]
        leaves = [_decode_leaf(b) for b in blobs]
        return step, jax.tree.unflatten(meta["treedef"], leaves)
