"""Fault-tolerant, straggler-mitigating job execution (control plane).

This is the paper's job-queue Pool (§3.1.2) hardened for 1000+-node
operation, with the failure semantics of §7.5 implemented rather than
assumed:

  * every task attempt holds a **lease** (KV key with TTL) heart-beaten by
    the worker; a monitor requeues tasks whose lease lapsed (worker died);
  * **speculative execution**: tasks running beyond ``speculate_after``
    (a multiple of the observed median runtime) are re-enqueued on
    another worker — the paper's warm-container strategy removes
    cold-start stragglers, this removes slow-node stragglers;
  * results are **idempotent**: the first attempt to finish wins via an
    atomic SETNX; duplicates are discarded;
  * ``max_retries`` bounds re-execution of genuinely failing tasks.

Workers are long-lived serverless functions; tasks are submitted with one
RPUSH. Everything rides on repro.core primitives (KV store + executor),
i.e. the transparent substrate *is* the scheduler's state store.
"""

from __future__ import annotations

import threading
import time
import traceback
from typing import Any, Callable, Dict, List, Optional, Sequence

from ..core import serialization
from ..core import session as _session
from ..core.executor import FunctionExecutor, RemoteError
from ..core.reference import fresh_uid

__all__ = ["JobRunner", "JobFailedError"]


class JobFailedError(RuntimeError):
    def __init__(self, idx: int, message: str, tb: str = ""):
        super().__init__(f"task {idx} failed permanently: {message}")
        self.idx = idx
        self.remote_traceback = tb


def _runner_worker(tag: str, worker_id: int, lease_ttl: float) -> None:
    sess = _session.get_session()
    store, storage = sess.store, sess.get_storage()
    job_key = f"{tag}:jobs"
    result_key = f"{tag}:results"
    func_cache: Dict[str, Callable] = {}

    while True:
        got = store.blpop(job_key, timeout=0.25)
        if got is None:
            if store.get(f"{tag}:stop"):
                return
            continue
        if got[1] == b"__stop__":
            return
        job_id, idx, attempt, func_key, args = serialization.loads(got[1])
        lease_key = f"{tag}:lease:{job_id}:{idx}"
        store.set(lease_key, f"{worker_id}:{attempt}", ex=lease_ttl)

        stop_hb = threading.Event()

        def heartbeat():
            while not stop_hb.wait(lease_ttl / 3):
                store.expire(lease_key, lease_ttl)

        hb = threading.Thread(target=heartbeat, daemon=True)
        hb.start()
        try:
            func = func_cache.get(func_key)
            if func is None:
                func = serialization.loads(storage.get(func_key))
                func_cache[func_key] = func
            try:
                value = func(*args)
                status, body = "ok", value
            except Exception as exc:
                status, body = "error", (f"{type(exc).__name__}: {exc}",
                                         traceback.format_exc())
        finally:
            stop_hb.set()
            store.delete(lease_key)
        # idempotent result: first finished attempt wins (job-scoped key)
        if store.setnx(f"{tag}:done:{job_id}:{idx}", attempt):
            store.rpush(result_key, serialization.dumps(
                (idx, attempt, status, body, worker_id)))


class JobRunner:
    def __init__(self, n_workers: int = 4, lease_ttl: float = 2.0,
                 speculate_factor: float = 3.0, max_retries: int = 3,
                 session: Optional[_session.Session] = None,
                 monitor_interval: float = 0.1):
        self.session = session or _session.get_session()
        self._store = self.session.store
        self._storage = self.session.get_storage()
        self.uid = fresh_uid("jobs")
        self._tag = "{" + self.uid + "}"
        self.lease_ttl = lease_ttl
        self.speculate_factor = speculate_factor
        self.max_retries = max_retries
        self.monitor_interval = monitor_interval
        self.n_workers = n_workers
        self._executor = FunctionExecutor(
            name=f"jobs-{self.uid}", session=self.session,
            **{k: v for k, v in self.session.executor_defaults.items()
               if k in ("backend", "monitoring")})
        for wid in range(n_workers):
            self._executor.call_async(_runner_worker,
                                      (self._tag, wid, lease_ttl))
        self.stats: Dict[str, int] = {"retries": 0, "speculations": 0,
                                      "duplicates_discarded": 0}

    # ------------------------------------------------------------------ api

    def run(self, func: Callable, items: Sequence[Any],
            timeout: Optional[float] = None) -> List[Any]:
        """Execute func(*item) for every item; returns ordered results.
        Tolerates worker death and stragglers; raises JobFailedError after
        max_retries."""
        job_id = fresh_uid("job")
        func_key = f"jobs/{self.uid}/{job_id}/func"
        self._storage.put(func_key, serialization.dumps(func))
        n = len(items)
        norm = [tuple(it) if isinstance(it, tuple) else (it,) for it in items]

        def enqueue(idx: int, attempt: int) -> None:
            self._store.rpush(f"{self._tag}:jobs", serialization.dumps(
                (job_id, idx, attempt, func_key, norm[idx])))

        start = {i: time.monotonic() for i in range(n)}
        attempts = {i: 0 for i in range(n)}
        speculated = set()
        for i in range(n):
            enqueue(i, 0)

        results: Dict[int, Any] = {}
        errors: Dict[int, tuple] = {}
        durations: List[float] = []
        deadline = None if timeout is None else time.monotonic() + timeout

        while len(results) < n:
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(f"job {job_id}: {n - len(results)} "
                                   "tasks unfinished")
            got = self._store.blpop(f"{self._tag}:results",
                                    timeout=self.monitor_interval)
            if got is not None:
                idx, attempt, status, body, _wid = serialization.loads(got[1])
                if idx in results or idx in errors:
                    self.stats["duplicates_discarded"] += 1
                    continue
                if status == "ok":
                    results[idx] = body
                    durations.append(time.monotonic() - start[idx])
                else:
                    if attempts[idx] + 1 > self.max_retries:
                        errors[idx] = body
                        raise JobFailedError(idx, body[0], body[1])
                    attempts[idx] += 1
                    self.stats["retries"] += 1
                    self._store.delete(f"{self._tag}:done:{job_id}:{idx}")
                    start[idx] = time.monotonic()
                    enqueue(idx, attempts[idx])
                continue

            # monitor pass: dead leases + stragglers
            now = time.monotonic()
            median = sorted(durations)[len(durations) // 2] if durations else None
            for i in range(n):
                if i in results or i in errors:
                    continue
                running = now - start[i]
                has_lease = self._store.exists(
                    f"{self._tag}:lease:{job_id}:{i}")
                queued = False  # approximation: lease appears once picked up
                if not has_lease and running > self.lease_ttl * 1.5:
                    # worker died before finishing (or task lost)
                    if attempts[i] + 1 > self.max_retries:
                        raise JobFailedError(i, "lost task (worker death)")
                    attempts[i] += 1
                    self.stats["retries"] += 1
                    start[i] = now
                    enqueue(i, attempts[i])
                elif (median is not None and i not in speculated
                      and running > max(self.speculate_factor * median,
                                        self.lease_ttl)):
                    speculated.add(i)
                    self.stats["speculations"] += 1
                    enqueue(i, attempts[i] + 1000)  # marked speculative
        return [results[i] for i in range(n)]

    def resize(self, n_workers: int) -> None:
        """Elastic scaling: grow the worker fleet (shrink via stop pills)."""
        if n_workers > self.n_workers:
            for wid in range(self.n_workers, n_workers):
                self._executor.call_async(_runner_worker,
                                          (self._tag, wid, self.lease_ttl))
        elif n_workers < self.n_workers:
            for _ in range(self.n_workers - n_workers):
                self._store.rpush(f"{self._tag}:jobs", b"__stop__")
        self.n_workers = n_workers

    def backlog(self) -> int:
        """Outstanding queued tasks — the elastic public contract
        (:mod:`repro.runtime.elastic`): lets an ``ElasticController``
        drive a JobRunner exactly like a Pool."""
        try:
            return int(self._store.llen(f"{self._tag}:jobs"))
        except (ConnectionError, OSError):
            return 0

    def shutdown(self) -> None:
        self._store.set(f"{self._tag}:stop", 1, ex=600)
        for _ in range(self.n_workers):
            self._store.rpush(f"{self._tag}:jobs", b"__stop__")
        self._executor.shutdown(wait=False)
