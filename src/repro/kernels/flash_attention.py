"""Pallas TPU flash attention (forward + backward), GQA-aware.

TPU adaptation notes (vs the CUDA FlashAttention the literature targets):
  * tiling is driven by BlockSpecs over (head, q-block, kv-block) grid —
    the kv axis is the innermost, sequential grid dimension, so the
    online-softmax running state (m, l, acc) lives in VMEM scratch that
    persists across kv steps; there is no cross-"block" shared memory.
  * tile shapes default to 512x512 with the head dim padded to a multiple
    of 128 (MXU lane width) by the wrapper; fp32 accumulation throughout.
  * causal masking skips whole blocks above the diagonal via pl.when
    (compute guard), matching the FLOPs-proportional reference.

Backward follows the standard two-kernel split: dKV iterates q-blocks per
kv-block, dQ iterates kv-blocks per q-block, both reusing the saved
row-logsumexp L = m + log(l).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 512
NEG_INF = -1e30


def _pad_head(x: jax.Array, mult: int = 128) -> Tuple[jax.Array, int]:
    d = x.shape[-1]
    pad = (-d) % mult
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, d


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *, sm_scale, causal, block_q, block_k,
                logits_soft_cap):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (qi >= ki) if causal else True

    @pl.when(run)
    def compute():
        q = q_ref[0].astype(jnp.float32) * sm_scale          # [bq, d]
        k = k_ref[0].astype(jnp.float32)                     # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        if logits_soft_cap is not None:
            s = logits_soft_cap * jnp.tanh(s / logits_soft_cap)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None] +
                        jax.lax.dot(p.astype(v.dtype), v))
        m_scr[...] = m_new

    is_last = (ki == qi) if causal else (ki == nk - 1)

    @pl.when(is_last)
    def emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_scr[...] + jnp.log(l)


# scratch_shapes needs pltpu; import guarded so CPU-only envs still load
try:  # pragma: no cover - trivial import guard
    from jax.experimental.pallas import tpu as pltpu
    _HAVE_PLTPU = True
except Exception:  # pragma: no cover
    pltpu = None
    _HAVE_PLTPU = False


def _scratch(block_q: int, d: int):
    if _HAVE_PLTPU:
        return [pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q, d), jnp.float32)]
    raise RuntimeError("pallas TPU scratch unavailable")


def _fwd_call(q, k, v, sm_scale, causal, block_q, block_k, logits_soft_cap,
              interpret):
    N, S, D = q.shape
    NK, T = k.shape[0], k.shape[1]
    G = N // NK
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    nq, nk = S // block_q, T // block_k
    kernel = functools.partial(
        _fwd_kernel, sm_scale=sm_scale, causal=causal,
        block_q=block_q, block_k=block_k, logits_soft_cap=logits_soft_cap)
    return pl.pallas_call(
        kernel,
        grid=(N, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, i, j, G=G: (h // G, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, i, j, G=G: (h // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_q), lambda h, i, j: (h, i)),
        ],
        scratch_shapes=_scratch(block_q, D),
        out_shape=[
            jax.ShapeDtypeStruct((N, S, D), q.dtype),
            jax.ShapeDtypeStruct((N, S), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Backward kernels
# ---------------------------------------------------------------------------


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_scr, dv_scr,
                    *, sm_scale, causal, block_q, block_k):
    ki = pl.program_id(1)
    qi = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(qi == 0)
    def init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    run = (qi >= ki) if causal else True

    @pl.when(run)
    def compute():
        q = q_ref[0].astype(jnp.float32) * sm_scale          # [bq, d]
        k = k_ref[0].astype(jnp.float32)                     # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)                   # [bq, d]
        lse = lse_ref[0]                                     # [bq]
        delta = delta_ref[0]                                 # [bq]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                        # [bq, bk]
        dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta[:, None]) * sm_scale
        dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(qi == nq - 1)
    def emit():
        dk_ref[0] = (dk_scr[...] / sm_scale).astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, dq_scr, *, sm_scale, causal, block_q, block_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    run = (qi >= ki) if causal else True

    @pl.when(run)
    def compute():
        q = q_ref[0].astype(jnp.float32) * sm_scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta[:, None]) * sm_scale
        dq_scr[...] += jax.lax.dot(ds, k)

    last = (ki == qi) if causal else (ki == nk - 1)

    @pl.when(last)
    def emit():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _bwd(q, k, v, o, lse, do, sm_scale, causal, block_q, block_k, interpret):
    N, S, D = q.shape
    NK, T = k.shape[0], k.shape[1]
    G = N // NK
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    nq, nk = S // block_q, T // block_k
    delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1)

    # dKV accumulates over the q-heads of the group: run per (q-head) and
    # sum the G contributions outside the kernel.
    dkv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, sm_scale=sm_scale, causal=causal,
                          block_q=block_q, block_k=block_k),
        grid=(N, nk, nq),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, j, i: (h, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, j, i, G=G: (h // G, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, j, i, G=G: (h // G, j, 0)),
            pl.BlockSpec((1, block_q, D), lambda h, j, i: (h, i, 0)),
            pl.BlockSpec((1, block_q), lambda h, j, i: (h, i)),
            pl.BlockSpec((1, block_q), lambda h, j, i: (h, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, D), lambda h, j, i: (h, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, j, i: (h, j, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, D), jnp.float32),
                        pltpu.VMEM((block_k, D), jnp.float32)]
        if _HAVE_PLTPU else None,
        out_shape=[
            jax.ShapeDtypeStruct((N, T, D), jnp.float32),
            jax.ShapeDtypeStruct((N, T, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    dk_per_head, dv_per_head = dkv
    dk = dk_per_head.reshape(NK, G, T, D).sum(axis=1).astype(k.dtype)
    dv = dv_per_head.reshape(NK, G, T, D).sum(axis=1).astype(v.dtype)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, sm_scale=sm_scale, causal=causal,
                          block_q=block_q, block_k=block_k),
        grid=(N, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, i, j, G=G: (h // G, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, i, j, G=G: (h // G, j, 0)),
            pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_q), lambda h, i, j: (h, i)),
            pl.BlockSpec((1, block_q), lambda h, i, j: (h, i)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)]
        if _HAVE_PLTPU else None,
        out_shape=jax.ShapeDtypeStruct((N, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Public entry with custom VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, sm_scale, causal, block_q, block_k, logits_soft_cap,
           interpret):
    o, _ = _fwd_call(q, k, v, sm_scale, causal, block_q, block_k,
                     logits_soft_cap, interpret)
    return o


def _flash_fwd(q, k, v, sm_scale, causal, block_q, block_k, logits_soft_cap,
               interpret):
    o, lse = _fwd_call(q, k, v, sm_scale, causal, block_q, block_k,
                       logits_soft_cap, interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd(sm_scale, causal, block_q, block_k, logits_soft_cap,
               interpret, res, do):
    if logits_soft_cap is not None:
        raise NotImplementedError("soft-cap backward not implemented")
    q, k, v, o, lse = res
    return _bwd(q, k, v, o, lse, do, sm_scale, causal, block_q, block_k,
                interpret)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: Optional[float] = None,
                    logits_soft_cap: Optional[float] = None,
                    block_q: int = DEFAULT_BLOCK, block_k: int = DEFAULT_BLOCK,
                    interpret: bool = False) -> jax.Array:
    """q: [B, S, H, D]; k, v: [B, T, K, D] -> [B, S, H, D]."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    scale = sm_scale if sm_scale is not None else D ** -0.5
    # fold batch & heads; pad head dim to the MXU lane width
    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, S, D)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * K, T, D)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * K, T, D)
    qf, _ = _pad_head(qf)
    kf, _ = _pad_head(kf)
    vf, _ = _pad_head(vf)
    o = _flash(qf, kf, vf, scale, causal, block_q, block_k, logits_soft_cap,
               interpret)
    o = o[..., :D].reshape(B, H, S, D)
    return jnp.moveaxis(o, 1, 2)
