"""Pallas RWKV6 WKV scan: VMEM-resident recurrent state.

TPU adaptation: the GPU implementations (flash-linear-attention CUDA)
tile the recurrence over warps with shared-memory staging. On TPU the
win is different — the [D, D] per-head state lives in VMEM *scratch*
across the whole sequence (grid-sequential chunk axis), so HBM traffic is
exactly r/k/v/w streamed once plus the output, instead of a state
round-trip per step. The per-step update is a rank-1 outer product +
elementwise decay (VPU work); the chunk loop is unrolled at compile time.

RWKV6's decay is *per-channel per-step* (a vector, not a scalar), which
breaks the matmul-form chunking usable for Mamba-2 (see mamba2_scan.py);
a DPLR-style matrix chunking exists but is out of scope — documented in
DESIGN.md.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pragma: no cover
    from jax.experimental.pallas import tpu as pltpu
    _HAVE_PLTPU = True
except Exception:  # pragma: no cover
    pltpu = None
    _HAVE_PLTPU = False

DEFAULT_CHUNK = 32


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                o_ref, sT_ref, s_scr, *, chunk):
    j = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(j == 0)
    def init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)      # [chunk, D]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)      # [D]

    s = s_scr[...]                        # [D, D]
    outs = []
    for t in range(chunk):                # static unroll: VREG-friendly
        kv = k[t][:, None] * v[t][None, :]            # [D, D]
        outs.append((r[t][:, None] * (s + u[:, None] * kv)).sum(axis=0))
        s = w[t][:, None] * s + kv
    s_scr[...] = s
    o_ref[0] = jnp.stack(outs).astype(o_ref.dtype)

    @pl.when(j == nc - 1)
    def emit_state():
        sT_ref[0] = s_scr[...].astype(sT_ref.dtype)


def rwkv6_chunked(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                  u: jax.Array, state: Optional[jax.Array] = None, *,
                  chunk: int = DEFAULT_CHUNK, interpret: bool = False
                  ) -> Tuple[jax.Array, jax.Array]:
    """Same contract as ref.rwkv6_scan: r/k/v/w [B,S,H,D], u [H,D],
    state [B,H,D,D] -> (out [B,S,H,D], state [B,H,D,D])."""
    B, S, H, D = r.shape
    if state is None:
        state = jnp.zeros((B, H, D, D), jnp.float32)
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError(f"S={S} not divisible by chunk={chunk}")
    nc = S // chunk

    def fold(x):  # [B,S,H,D] -> [B*H, S, D]
        return jnp.moveaxis(x, 2, 1).reshape(B * H, S, D)

    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(w)
    s0 = state.reshape(B * H, D, D)

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    scratch = [pltpu.VMEM((D, D), jnp.float32)] if _HAVE_PLTPU else None
    o, sT = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, D), lambda i, j, H=H: (i % H, 0)),
            pl.BlockSpec((1, D, D), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, D, D), lambda i, j: (i, 0, 0)),
        ],
        scratch_shapes=scratch,
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, D), r.dtype),
            jax.ShapeDtypeStruct((B * H, D, D), jnp.float32),
        ],
        interpret=interpret,
    )(rf, kf, vf, wf, u, s0)
    out = jnp.moveaxis(o.reshape(B, H, S, D), 1, 2)
    return out, sT.reshape(B, H, D, D)
