"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each Pallas kernel's test sweeps
shapes/dtypes and asserts allclose against the function here. They are
also the CPU execution path (``ops.py`` dispatches to them off-TPU), so
the multi-pod dry-run lowers these exact computations.

Conventions: inputs arrive in model dtype (bf16/f32); softmax and
accumulations are f32; outputs are cast back to the query dtype.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Attention (prefill / training): causal GQA
# ---------------------------------------------------------------------------


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, sm_scale: Optional[float] = None,
              logits_soft_cap: Optional[float] = None) -> jax.Array:
    """Multi-head attention with grouped KV heads (naive; the oracle).

    q: [B, S, H, D]; k, v: [B, T, K, D] with H % K == 0 (T == S if causal).
    Returns [B, S, H, D] in q.dtype. Materializes the full [S, T] logits —
    use :func:`attention_blocked` for long sequences.
    """
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    scale = sm_scale if sm_scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(B, S, K, G, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf)
    if logits_soft_cap is not None:
        logits = logits_soft_cap * jnp.tanh(logits / logits_soft_cap)
    if causal:
        assert S == T, "causal attention requires S == T"
        mask = jnp.tril(jnp.ones((S, T), dtype=bool))
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, vf)
    return out.reshape(B, S, H, D).astype(q.dtype)


def attention_blocked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, sm_scale: Optional[float] = None,
                      logits_soft_cap: Optional[float] = None,
                      block_q: int = 512, block_k: int = 512) -> jax.Array:
    """Flash-style blocked attention in pure JAX (online softmax).

    Structure mirrors the Pallas kernel: a static outer loop over query
    blocks, each with a ``lax.scan`` over exactly the kv blocks it needs
    (qi+1 for causal rows), carrying only the small (m, l, acc) online-
    softmax state and emitting each output block once. Memory is
    O(S·block) instead of O(S²), causal FLOPs are exact (no masked waste
    beyond the diagonal block), and the byte pattern matches a fused flash
    implementation — which is what the dry-run roofline should see.
    """
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = sm_scale if sm_scale is not None else D ** -0.5
    # largest block that divides both S and T (prefix lengths vary: 33024
    # for vlm prefill = 32768 tokens + 256 patches)
    for cand in (block_q, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= min(S, T) and S % cand == 0 and T % cand == 0:
            block_q = block_k = cand
            break
    nq, nk = S // block_q, T // block_k
    if causal:
        assert S == T and block_q == block_k

    # [B, K, nq, block_q, G, D] query blocks; KV: [B, K, nk, block_k, D]
    # (kept in input dtype; blocks are cast to f32 per-iteration)
    qf = q.reshape(B, S, K, G, D)
    qf = jnp.moveaxis(qf.reshape(B, nq, block_q, K, G, D), 3, 1)
    kf = jnp.moveaxis(k.reshape(B, nk, block_k, K, D), 3, 1)
    vf = jnp.moveaxis(v.reshape(B, nk, block_k, K, D), 3, 1)

    pos_q = jnp.arange(block_q)
    pos_k = jnp.arange(block_k)

    def q_block(qi: int):
        qb = qf[:, :, qi].astype(jnp.float32) * scale      # [B, K, bq, G, D]
        n_kv = (qi + 1) if causal else nk

        def body(carry, ki):
            m, l, acc = carry
            # dynamic-index the shared KV (a [:n_kv] prefix slice per q
            # block would materialize O(nq) partial copies of the cache)
            kb = jax.lax.dynamic_index_in_dim(kf, ki, 2, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vf, ki, 2, keepdims=False)
            kb = kb.astype(jnp.float32)
            vb = vb.astype(jnp.float32)
            s = jnp.einsum("bkqgd,bksd->bkqgs", qb, kb)
            if logits_soft_cap is not None:
                s = logits_soft_cap * jnp.tanh(s / logits_soft_cap)
            if causal:
                mask = ((qi * block_q + pos_q)[:, None] >=
                        (ki * block_k + pos_k)[None, :])
                s = jnp.where(jnp.logical_or(ki < qi,
                                             mask[None, None, :, None, :]),
                              s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bkqgs,bksd->bkqgd",
                                                      p, vb)
            return (m_new, l, acc), None

        m0 = jnp.full((B, K, block_q, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, block_q, G), jnp.float32)
        acc0 = jnp.zeros((B, K, block_q, G, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                      jnp.arange(n_kv))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    blocks = [q_block(qi) for qi in range(nq)]             # [B, K, bq, G, D]
    out = jnp.stack(blocks, axis=2)                        # [B, K, nq, bq, G, D]
    out = jnp.moveaxis(out, 1, 3).reshape(B, S, K, G, D)
    return out.reshape(B, S, H, D)


# ---------------------------------------------------------------------------
# Decode attention: one new token against a KV cache
# ---------------------------------------------------------------------------


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *,
                     sm_scale: Optional[float] = None) -> jax.Array:
    """Single-position attention against a (padded) KV cache.

    q: [B, H, D] (the new token's queries)
    k_cache, v_cache: [B, S_max, K, D]
    lengths: [B] int32 — number of valid cache entries per sequence
    Returns [B, H, D]. Rows with ``lengths == 0`` return zeros (nothing
    to attend to), matching the flash-decode kernel, whose online-
    softmax accumulator never runs for a zero-length row — the finite
    NEG_INF mask alone would instead softmax to a uniform average.
    """
    B, H, D = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = sm_scale if sm_scale is not None else D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, K, G, D) * scale
    # keep the cache in its storage dtype: the MXU accumulates in f32 via
    # preferred_element_type, and HBM traffic stays at bf16 width
    logits = jnp.einsum("bkgd,bskd->bkgs", qf.astype(k_cache.dtype), k_cache,
                        preferred_element_type=jnp.float32)
    valid = jnp.arange(S)[None, :] < lengths[:, None]          # [B, S]
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out * (lengths > 0).astype(out.dtype)[:, None, None, None]
    return out.reshape(B, H, D).astype(q.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           lengths: jax.Array, *,
                           sm_scale: Optional[float] = None) -> jax.Array:
    """Decode attention through a paged KV cache (the oracle).

    q: [B, H, D]; k_pages, v_pages: [P, page_size, K, D] — the shared
    page slab; page_table: [B, M] int32 — per-sequence page ids (entries
    past the allocated prefix point at the reserved null page 0 and are
    masked by ``lengths``); lengths: [B] valid tokens. Token ``t`` of
    sequence ``b`` lives at ``(page_table[b, t // page_size],
    t % page_size)``. Gathers each sequence's pages into the contiguous
    [B, M * page_size, K, D] view and defers to :func:`decode_attention`,
    so paged and contiguous decode are numerically identical by
    construction.
    """
    B = q.shape[0]
    _, page_size, K, D = k_pages.shape
    M = page_table.shape[1]
    kc = k_pages[page_table].reshape(B, M * page_size, K, D)
    vc = v_pages[page_table].reshape(B, M * page_size, K, D)
    return decode_attention(q, kc, vc, lengths, sm_scale=sm_scale)


# ---------------------------------------------------------------------------
# Chunked time scan (bounded-memory BPTT for the recurrences)
# ---------------------------------------------------------------------------


def _chunked_time_scan(step, state, xs, chunk: int = 64):
    """Two-level scan: outer over chunks (rematerialized), inner over
    steps. Naive BPTT through a length-S scan saves the carry every step
    (e.g. 4 MB x 4096 steps = 16 GB/device for rwkv6 at train_4k); with
    remat chunking the backward keeps S/chunk checkpoints + one chunk of
    transients — the standard production treatment of linear recurrences.
    """
    S = xs[0].shape[0]
    if S <= chunk or S % chunk != 0:
        return jax.lax.scan(step, state, xs)
    n = S // chunk
    xs_c = tuple(x.reshape(n, chunk, *x.shape[1:]) for x in xs)

    @jax.checkpoint
    def chunk_body(s, xc):
        return jax.lax.scan(step, s, xc)

    state, ys = jax.lax.scan(chunk_body, state, xs_c)
    ys = jax.tree.map(lambda y: y.reshape(S, *y.shape[2:]), ys)
    return state, ys


# ---------------------------------------------------------------------------
# RWKV6 "Finch" WKV scan (data-dependent decay)
# ---------------------------------------------------------------------------


def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, state: Optional[jax.Array] = None,
               ) -> Tuple[jax.Array, jax.Array]:
    """RWKV6 recurrence.

    r, k, v: [B, S, H, D]; w: [B, S, H, D] (per-step decay, in (0,1));
    u: [H, D] bonus for the current token. state: [B, H, D, D] or None.

        S_t = diag(w_t) @ S_{t-1} + k_t^T v_t
        o_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)

    Returns (out [B, S, H, D], final state [B, H, D, D]).
    """
    B, S, H, D = r.shape
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((B, H, D, D), jnp.float32)
    else:
        state = state.astype(jnp.float32)

    def step(s, inputs):
        rt, kt, vt, wt = inputs  # [B, H, D]
        kv = kt[..., :, None] * vt[..., None, :]          # [B, H, D, D]
        out = jnp.einsum("bhd,bhde->bhe", rt, s + uf[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rf, kf, vf, wf))
    state, outs = _chunked_time_scan(step, state, xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 SSD scan (scalar-per-head decay)
# ---------------------------------------------------------------------------


def mamba2_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, state: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Mamba-2 state space duality recurrence.

    x:  [B, S, H, P]   (P = head dim)
    dt: [B, S, H]      (positive step sizes)
    a:  [H]            (negative; decay = exp(a * dt))
    b, c: [B, S, N]    (N = ssm state size; B/C shared across heads)
    state: [B, H, P, N] or None.

        h_t = exp(a dt_t) h_{t-1} + dt_t * x_t b_t^T
        y_t = h_t c_t
    Returns (y [B, S, H, P], final state [B, H, P, N]).
    """
    Bsz, S, H, P = x.shape
    N = b.shape[-1]
    xf, dtf, bf, cf = (t.astype(jnp.float32) for t in (x, dt, b, c))
    af = a.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((Bsz, H, P, N), jnp.float32)
    else:
        state = state.astype(jnp.float32)

    def step(h, inputs):
        xt, dtt, bt, ct = inputs  # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(af[None, :] * dtt)                     # [B, H]
        dbx = (dtt[..., None] * xt)[..., None] * bt[:, None, None, :]
        h = decay[..., None, None] * h + dbx                   # [B,H,P,N]
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    state, ys = _chunked_time_scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state
