"""Pallas Mamba-2 SSD chunked scan (matmul-form, MXU-friendly).

TPU adaptation of the SSD algorithm: Mamba-2's scalar-per-head decay
admits an exact chunk-parallel form where intra-chunk work is two
[T, T] x [T, P/N] matmuls (MXU) and only the [P, N] chunk-boundary state
recurses — carried in VMEM scratch across the sequential chunk axis of
the grid, never round-tripping HBM. Decay factors use cumulative log
space; all exponents are <= 0, so no rescaling is needed.

    cum[t]   = sum_{r<=t} a*dt[r]                     (per chunk)
    L[t,s]   = exp(cum[t]-cum[s]) for t>=s else 0
    y_intra  = ((C B^T) o L) @ (dt*x)
    y_inter  = exp(cum) * (C @ h_prev^T)
    h_next   = exp(cum[-1]) h_prev + (dt*x * exp(cum[-1]-cum))^T @ B
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pragma: no cover
    from jax.experimental.pallas import tpu as pltpu
    _HAVE_PLTPU = True
except Exception:  # pragma: no cover
    pltpu = None
    _HAVE_PLTPU = False

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                y_ref, hT_ref, h_scr, *, chunk):
    j = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(j == 0)
    def init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)          # [T, P]
    dt = dt_ref[0].astype(jnp.float32)        # [T]
    a = a_ref[0].astype(jnp.float32)          # scalar (this head)
    b = b_ref[0].astype(jnp.float32)          # [T, N]
    c = c_ref[0].astype(jnp.float32)          # [T, N]
    h = h_scr[...]                            # [P, N]

    cum = jnp.cumsum(a * dt)                  # [T], <= 0
    # intra-chunk: scores[t,s] = (c_t . b_s) * exp(cum[t]-cum[s]) (t>=s)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))  # [T, T]
    decay = jnp.exp(cum[:, None] - cum[None, :])
    T = x.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    scores = jnp.where(rows >= cols, scores * decay, 0.0)
    xdt = x * dt[:, None]                     # [T, P]
    y = jax.lax.dot(scores, xdt)              # [T, P]
    # inter-chunk: y += exp(cum) * (c @ h^T)
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, h, (((1,), (1,)), ((), ())))       # [T, P]
    # boundary state update
    w = jnp.exp(cum[-1] - cum)                # [T]
    h_scr[...] = (jnp.exp(cum[-1]) * h +
                  jax.lax.dot_general(xdt * w[:, None], b,
                                      (((0,), (0,)), ((), ()))))  # [P, N]
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(j == nc - 1)
    def emit_state():
        hT_ref[0] = h_scr[...].astype(hT_ref.dtype)


def mamba2_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                   c: jax.Array, state: Optional[jax.Array] = None, *,
                   chunk: int = DEFAULT_CHUNK, interpret: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """Same contract as ref.mamba2_scan: x [B,S,H,P], dt [B,S,H], a [H],
    b/c [B,S,N], state [B,H,P,N] -> (y [B,S,H,P], state)."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    if state is None:
        state = jnp.zeros((B, H, P, N), jnp.float32)
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError(f"S={S} not divisible by chunk={chunk}")
    nc = S // chunk

    xf = jnp.moveaxis(x, 2, 1).reshape(B * H, S, P)
    dtf = jnp.moveaxis(dt, 2, 1).reshape(B * H, S)
    bf = jnp.repeat(b[:, None], H, axis=1).reshape(B * H, S, N)
    cf = jnp.repeat(c[:, None], H, axis=1).reshape(B * H, S, N)
    h0 = state.reshape(B * H, P, N)
    af = jnp.tile(a, B)                       # [B*H]

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    scratch = [pltpu.VMEM((P, N), jnp.float32)] if _HAVE_PLTPU else None
    y, hT = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, chunk, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, P, N), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, P, N), lambda i, j: (i, 0, 0)),
        ],
        scratch_shapes=scratch,
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B * H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(xf, dtf, af, bf, cf, h0)
    out = jnp.moveaxis(y.reshape(B, H, S, P), 1, 2)
    return out, hT.reshape(B, H, P, N)
