"""Jit'd public wrappers for the Pallas kernels, with platform dispatch.

On TPU these call the Pallas kernels (``flash_attention.py``,
``decode_attention.py``, ``rwkv6_scan.py``, ``mamba2_scan.py``); elsewhere
(CPU dry-runs, tests, this container) they fall back to the pure-jnp
oracles in ``ref.py`` — identical semantics, validated by the per-kernel
allclose sweeps in tests/test_kernels.py (which run the Pallas bodies in
``interpret=True`` mode).

Set ``REPRO_FORCE_REF=1`` to force the reference path, or
``REPRO_FORCE_PALLAS=interpret`` to force interpret-mode Pallas (testing).
"""

from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref

__all__ = ["attention", "decode_attention", "paged_decode_attention",
           "rwkv6_scan", "mamba2_scan", "pallas_mode"]


@functools.lru_cache(None)
def pallas_mode() -> str:
    """'tpu' | 'interpret' | 'off'."""
    if os.environ.get("REPRO_FORCE_REF"):
        return "off"
    forced = os.environ.get("REPRO_FORCE_PALLAS", "")
    if forced == "interpret":
        return "interpret"
    try:
        if jax.default_backend() == "tpu":
            return "tpu"
    except Exception:
        pass
    return "off"


def attention(q, k, v, *, causal: bool = True,
              sm_scale: Optional[float] = None,
              logits_soft_cap: Optional[float] = None):
    """Flash attention (prefill/training). See ref.attention for semantics."""
    mode = pallas_mode()
    if mode != "off":
        from .flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal, sm_scale=sm_scale,
                               logits_soft_cap=logits_soft_cap,
                               interpret=(mode == "interpret"))
    if q.shape[1] > 1024 or k.shape[1] > 1024:
        # Long sequences: flash-style blocked path so the lowered program
        # has O(S) memory and causal-proportional FLOPs (dry-run realism).
        return ref.attention_blocked(q, k, v, causal=causal,
                                     sm_scale=sm_scale,
                                     logits_soft_cap=logits_soft_cap)
    return ref.attention(q, k, v, causal=causal, sm_scale=sm_scale,
                         logits_soft_cap=logits_soft_cap)


def decode_attention(q, k_cache, v_cache, lengths, *,
                     sm_scale: Optional[float] = None):
    """Flash-decode attention against a KV cache."""
    mode = pallas_mode()
    if mode != "off":
        from .decode_attention import flash_decode
        return flash_decode(q, k_cache, v_cache, lengths, sm_scale=sm_scale,
                            interpret=(mode == "interpret"))
    return ref.decode_attention(q, k_cache, v_cache, lengths,
                                sm_scale=sm_scale)


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           sm_scale: Optional[float] = None):
    """Flash-decode through a page table (continuous-batching serving).

    See ref.paged_decode_attention for semantics. The Pallas path keeps
    the contiguous kernel's grid — pages are block_k-sized blocks, the
    table only changes the BlockSpec index map (scalar prefetch)."""
    mode = pallas_mode()
    if mode != "off":
        from .decode_attention import flash_decode_paged
        return flash_decode_paged(q, k_pages, v_pages, page_table, lengths,
                                  sm_scale=sm_scale,
                                  interpret=(mode == "interpret"))
    return ref.paged_decode_attention(q, k_pages, v_pages, page_table,
                                      lengths, sm_scale=sm_scale)


def rwkv6_scan(r, k, v, w, u, state=None):
    """RWKV6 WKV recurrence (chunked kernel on TPU)."""
    mode = pallas_mode()
    if mode != "off":
        from .rwkv6_scan import rwkv6_chunked
        return rwkv6_chunked(r, k, v, w, u, state,
                             interpret=(mode == "interpret"))
    return ref.rwkv6_scan(r, k, v, w, u, state)


def mamba2_scan(x, dt, a, b, c, state=None):
    """Mamba2 SSD recurrence (chunked kernel on TPU)."""
    mode = pallas_mode()
    if mode != "off":
        from .mamba2_scan import mamba2_chunked
        return mamba2_chunked(x, dt, a, b, c, state,
                              interpret=(mode == "interpret"))
    return ref.mamba2_scan(x, dt, a, b, c, state)
