"""Pallas flash-decode: one query token vs a (padded) KV cache.

TPU adaptation of flash-decoding: the kv-block axis is the sequential
inner grid dimension; the online-softmax state for all G grouped query
heads rides in VMEM scratch across kv blocks (GPU flash-decode's
split-k + cross-SM reduction becomes grid-sequential accumulation —
there is no shared-memory combine step to port). Per-sequence ``lengths``
mask out unwritten cache tail blocks.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pragma: no cover
    from jax.experimental.pallas import tpu as pltpu
    _HAVE_PLTPU = True
except Exception:  # pragma: no cover
    pltpu = None
    _HAVE_PLTPU = False

NEG_INF = -1e30
DEFAULT_BLOCK_K = 512


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, sm_scale, block_k):
    j = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(j == 0)
    def init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    # skip whole blocks beyond the valid length
    @pl.when(j * block_k < length)
    def compute():
        q = q_ref[0].astype(jnp.float32) * sm_scale        # [G, D]
        k = k_ref[0].astype(jnp.float32)                   # [bk, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, bk]
        cols = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None] + jax.lax.dot(p, v))
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 lengths: jax.Array, *, sm_scale: Optional[float] = None,
                 block_k: int = DEFAULT_BLOCK_K,
                 interpret: bool = False) -> jax.Array:
    """q: [B, H, D]; caches [B, S, K, D]; lengths [B] -> [B, H, D]."""
    B, H, D = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = sm_scale if sm_scale is not None else D ** -0.5
    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:  # masked by lengths
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S += pad
    nk = S // block_k

    qf = q.reshape(B, K, G, D).reshape(B * K, G, D)
    kf = jnp.moveaxis(k_cache, 2, 1).reshape(B * K, S, D)
    vf = jnp.moveaxis(v_cache, 2, 1).reshape(B * K, S, D)
    lens = lengths.astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, sm_scale=scale,
                               block_k=block_k)
    scratch = ([pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32)]
               if _HAVE_PLTPU else None)
    o = pl.pallas_call(
        kernel,
        grid=(B * K, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, K=K: (i // K,)),
            pl.BlockSpec((1, G, D), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda i, j: (i, 0, 0)),
        scratch_shapes=scratch,
        out_shape=jax.ShapeDtypeStruct((B * K, G, D), q.dtype),
        interpret=interpret,
    )(lens, qf, kf, vf)
    return o.reshape(B, H, D)
