"""Pallas flash-decode: one query token vs a (padded) KV cache.

TPU adaptation of flash-decoding: the kv-block axis is the sequential
inner grid dimension; the online-softmax state for all G grouped query
heads rides in VMEM scratch across kv blocks (GPU flash-decode's
split-k + cross-SM reduction becomes grid-sequential accumulation —
there is no shared-memory combine step to port). Per-sequence ``lengths``
mask out unwritten cache tail blocks.

:func:`flash_decode_paged` is the same kernel body gathering K/V through
a per-sequence **page table** instead of a contiguous cache: pages are
``block_k``-sized, so the grid is unchanged — ``(B * K, n_blocks)`` with
``n_blocks == max_pages`` — and the only difference is the K/V BlockSpec
index map, which resolves block ``j`` of sequence ``b`` to slab page
``page_table[b, j]`` via scalar prefetch (``PrefetchScalarGridSpec``:
the table rides in SMEM and is available to the index map before the
body runs, so the page indirection costs zero extra DMA steps). Rows
with ``lengths == 0`` emit zeros (the accumulator never runs).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pragma: no cover
    from jax.experimental.pallas import tpu as pltpu
    _HAVE_PLTPU = True
except Exception:  # pragma: no cover
    pltpu = None
    _HAVE_PLTPU = False

NEG_INF = -1e30
DEFAULT_BLOCK_K = 512


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, sm_scale, block_k):
    j = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(j == 0)
    def init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    # skip whole blocks beyond the valid length
    @pl.when(j * block_k < length)
    def compute():
        q = q_ref[0].astype(jnp.float32) * sm_scale        # [G, D]
        k = k_ref[0].astype(jnp.float32)                   # [bk, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, bk]
        cols = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None] + jax.lax.dot(p, v))
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 lengths: jax.Array, *, sm_scale: Optional[float] = None,
                 block_k: int = DEFAULT_BLOCK_K,
                 interpret: bool = False) -> jax.Array:
    """q: [B, H, D]; caches [B, S, K, D]; lengths [B] -> [B, H, D]."""
    B, H, D = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = sm_scale if sm_scale is not None else D ** -0.5
    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:  # masked by lengths
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S += pad
    nk = S // block_k

    qf = q.reshape(B, K, G, D).reshape(B * K, G, D)
    kf = jnp.moveaxis(k_cache, 2, 1).reshape(B * K, S, D)
    vf = jnp.moveaxis(v_cache, 2, 1).reshape(B * K, S, D)
    lens = lengths.astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, sm_scale=scale,
                               block_k=block_k)
    scratch = ([pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32)]
               if _HAVE_PLTPU else None)
    o = pl.pallas_call(
        kernel,
        grid=(B * K, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, K=K: (i // K,)),
            pl.BlockSpec((1, G, D), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda i, j: (i, 0, 0)),
        scratch_shapes=scratch,
        out_shape=jax.ShapeDtypeStruct((B * K, G, D), q.dtype),
        interpret=interpret,
    )(lens, qf, kf, vf)
    return o.reshape(B, H, D)


def _paged_kernel(len_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, sm_scale, page_size, n_kv):
    """Same online-softmax body as :func:`_decode_kernel`; the page
    indirection happened in the BlockSpec index map, so block ``j`` of
    grid row ``i`` already holds page ``page_table[i // K, j]``."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(j == 0)
    def init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[i // n_kv]

    @pl.when(j * page_size < length)
    def compute():
        q = q_ref[0].astype(jnp.float32) * sm_scale        # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)             # [page, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, page]
        cols = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None] + jax.lax.dot(p, v))
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode_paged(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       page_table: jax.Array, lengths: jax.Array, *,
                       sm_scale: Optional[float] = None,
                       interpret: bool = False) -> jax.Array:
    """Flash-decode gathering K/V through a page table.

    q: [B, H, D]; k_pages, v_pages: [P, page_size, K, D] (shared slab,
    page 0 reserved as the null page); page_table: [B, M] int32;
    lengths: [B] -> [B, H, D]. Token ``t`` of sequence ``b`` lives at
    ``(page_table[b, t // page_size], t % page_size)``; table entries at
    or past ``ceil(lengths[b] / page_size)`` may point anywhere (the
    null page by convention) — the length mask skips those blocks.
    Requires ``pltpu`` (scalar prefetch); ``ops.paged_decode_attention``
    falls back to the pure-JAX reference elsewhere.
    """
    if not _HAVE_PLTPU:  # pragma: no cover - guarded by ops dispatch
        raise RuntimeError("flash_decode_paged requires pallas TPU support")
    B, H, D = q.shape
    _, page_size, K, _ = k_pages.shape
    M = page_table.shape[1]
    G = H // K
    scale = sm_scale if sm_scale is not None else D ** -0.5

    qf = q.reshape(B, K, G, D).reshape(B * K, G, D)
    kernel = functools.partial(_paged_kernel, sm_scale=scale,
                               page_size=page_size, n_kv=K)

    def kv_map(i, j, lens, tbl, K=K):
        return (tbl[i // K, j], 0, i % K, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # lengths + page_table feed the index maps
        grid=(B * K, M),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda i, j, lens, tbl: (i, 0, 0)),
            pl.BlockSpec((1, page_size, 1, D), kv_map),
            pl.BlockSpec((1, page_size, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda i, j, lens, tbl: (i, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G,), jnp.float32),
                        pltpu.VMEM((G,), jnp.float32),
                        pltpu.VMEM((G, D), jnp.float32)],
    )
    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * K, G, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), page_table.astype(jnp.int32),
      qf, k_pages, v_pages)
    return o.reshape(B, H, D)
