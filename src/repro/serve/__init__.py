from .engine import make_serve_step, make_prefill, ServeEngine  # noqa: F401
