from .engine import (make_serve_step, make_prefill, ServeEngine,  # noqa: F401
                     ContinuousEngine, ServeClient, ServeRequest)
from .paging import PageAllocator  # noqa: F401
