"""Serving: prefill + single-token decode (serve_step) + a small batched
engine for the examples.

``make_serve_step`` builds the function the decode-shape dry-runs lower:
one new token against a KV cache of ``seq_len`` (the assignment's
``decode_*`` semantics). The cache is donated so XLA updates it in place.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.model import Model


def make_prefill(model: Model, max_len: int):
    def prefill(params, batch):
        return model.prefill(params, batch, max_len)
    return prefill


def make_serve_step(model: Model, greedy: bool = True):
    """serve_step(params, cache, tokens) -> (next_tokens, logits, cache)."""

    def serve_step(params, cache, tokens):
        logits, cache = model.decode(params, cache, tokens)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, logits, cache

    return serve_step


class ServeEngine:
    """Minimal batched generation engine (examples/serve_lm.py).

    Static batch, greedy decoding, eos-aware early exit bookkeeping —
    enough to demonstrate batched serving through the public API without
    pretending to be a full continuous-batching scheduler.
    """

    def __init__(self, model: Model, params, max_len: int = 256,
                 eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.eos_id = eos_id
        self._prefill = jax.jit(make_prefill(model, max_len))
        self._step = jax.jit(make_serve_step(model))

    def generate(self, prompts: jax.Array, max_new_tokens: int = 32
                 ) -> jax.Array:
        """prompts: [B, S] int32 (right-aligned, no padding support needed
        for the demo). Returns [B, max_new_tokens]."""
        logits, cache = self._prefill(self.params, {"tokens": prompts})
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out: List[jax.Array] = [tok]
        done = jnp.zeros(tok.shape, bool)
        for _ in range(max_new_tokens - 1):
            tok, _, cache = self._step(self.params, cache, tok)
            if self.eos_id is not None:
                done = done | (tok == self.eos_id)
                tok = jnp.where(done, self.eos_id, tok)
            out.append(tok)
        return jnp.stack(out, axis=1)
