"""LM serving engines over the KV plane: static batch and continuous batching.

Two engines share the model's serving entry points:

``ServeEngine`` — the legacy static batcher: one prefill over the whole
batch, lock-step greedy decode, the batch held until every row finishes
(with eos-aware early exit). It issues **no** KV-store commands and
allocates **no** page slab — plain contiguous caches only — so code that
never opts into continuous serving pays nothing for it.

``ContinuousEngine`` — continuous batching over a **paged** decode cache
with admission on the KV plane's bounded queues.

Admission contract
------------------
Requests arrive on a ``core.queues.Queue`` (or via local ``submit``).
Producers (``ServeClient.submit``) push the **raw lease triple**
``(attempt, request_id, payload)`` with the store's fused commands —
``blpop_rpush(slots, items, entry)`` when the queue is bounded (so a
full queue back-pressures producers: that is the admission control), or
a plain ``rpush`` otherwise. Because the entry is a raw triple rather
than an opaque serialized blob, the engine can pop it with
``blpop_lease`` and inherit the pool plane's at-least-once machinery:
the lease is renewed every ``ttl/3`` while the request is in flight and
``lease_release``d on completion, so a crashed engine's requests are
reclaimable by ``lease_reap`` exactly like pool tasks. Several engines
may share one queue — ``blpop`` atomicity gives exactly-once admission
across replicas. Results return on the per-request list
``<queue>:resp:<request_id>``.

Scheduling contract
-------------------
The decode step is jitted once over a **fixed batch shape**: per-slot
token / length / page-table arrays of size ``max_slots`` plus a boolean
``slot_mask``. Requests joining or leaving the batch only change array
*contents*, never shapes, so batch-membership churn causes zero
recompilation (asserted by ``decode_compiles`` staying at 1). Each
``step()`` does: (1) admit requests into free slots while pages last;
(2) run at most ONE length-``prefill_chunk`` prompt chunk for the oldest
still-prefilling slot — chunking bounds how long a long prompt can
starve decode; (3) run one decode step for all decoding slots. A slot
mid-prefill is masked out of the decode batch (null-page write, zero
attention length) until its prompt completes.

Page table layout & eviction contract
-------------------------------------
The KV cache is a shared slab ``[L, num_pages, page_size, K, hd]``;
token ``t`` of the request in slot ``b`` lives at page
``table[b, t // page_size]``, offset ``t % page_size``. Page 0 is the
null page (never referenced by a live table; absorbs masked writes).
Pages are allocated at admission (enough for the prompt) and grown one
page at a time when decode crosses a page boundary. On eos or on
reaching ``max_new_tokens`` the slot's pages return to the free list
and the slot frees up — that is the only *eviction*. When growth finds
the free list empty, the **youngest** active request is preempted by
recompute: its pages are freed, its generated tokens discarded, and the
request re-queued locally for re-prefill (greedy decoding is
deterministic, so the final output is unchanged; only latency suffers).
A request that cannot fit even alone (prompt + output > pages) is
rejected with an error result rather than thrashing.
"""

from __future__ import annotations

import collections
import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import serialization
from ..models.model import Model
from .paging import PageAllocator


def make_prefill(model: Model, max_len: int):
    def prefill(params, batch):
        return model.prefill(params, batch, max_len)
    return prefill


def make_serve_step(model: Model, greedy: bool = True):
    """serve_step(params, cache, tokens) -> (next_tokens, logits, cache)."""

    def serve_step(params, cache, tokens):
        logits, cache = model.decode(params, cache, tokens)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, logits, cache

    return serve_step


class ServeEngine:
    """Minimal static-batch generation engine (examples/serve_lm.py).

    Static batch, greedy decoding, eos-aware early exit: once every row
    has emitted ``eos_id`` the decode loop stops and the remaining
    columns are padded with ``eos_id`` (output shape stays
    ``[B, max_new_tokens]``). Issues no KV-store commands and allocates
    no page slab — the continuous-batching machinery is pay-as-you-go.
    """

    def __init__(self, model: Model, params, max_len: int = 256,
                 eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.eos_id = eos_id
        self._prefill = jax.jit(make_prefill(model, max_len))
        self._step = jax.jit(make_serve_step(model))
        self._steps_run = 0  # decode steps in the last generate() call

    def generate(self, prompts: jax.Array, max_new_tokens: int = 32,
                 on_first_token: Optional[Callable[[jax.Array], None]] = None
                 ) -> jax.Array:
        """prompts: [B, S] int32 (right-aligned, no padding support needed
        for the demo). Returns [B, max_new_tokens]. ``on_first_token``
        fires with the [B] first sampled tokens as soon as prefill
        produces them (TTFT measurement hook)."""
        self._steps_run = 0
        logits, cache = self._prefill(self.params, {"tokens": prompts})
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if on_first_token is not None:
            on_first_token(jax.block_until_ready(tok))
        out: List[jax.Array] = [tok]
        done = jnp.zeros(tok.shape, bool)
        if self.eos_id is not None:
            done = tok == self.eos_id
        for _ in range(max_new_tokens - 1):
            if self.eos_id is not None and bool(done.all()):
                break  # early exit: every row finished
            tok, _, cache = self._step(self.params, cache, tok)
            self._steps_run += 1
            if self.eos_id is not None:
                done = done | (tok == self.eos_id)
                tok = jnp.where(done, self.eos_id, tok)
            out.append(tok)
        while len(out) < max_new_tokens:  # pad early-exited columns
            out.append(jnp.full_like(tok, self.eos_id))
        return jnp.stack(out, axis=1)


# --------------------------------------------------------------- continuous


@dataclass
class ServeRequest:
    """One generation request as it travels the admission queue."""
    id: str
    tokens: List[int]
    max_new_tokens: int
    submitted_at: Optional[float] = None

    def to_payload(self) -> bytes:
        return serialization.dumps({
            "id": self.id, "tokens": list(map(int, self.tokens)),
            "max_new_tokens": int(self.max_new_tokens),
            "submitted_at": self.submitted_at})

    @staticmethod
    def from_payload(payload: bytes) -> "ServeRequest":
        d = serialization.loads(payload)
        return ServeRequest(id=d["id"], tokens=list(d["tokens"]),
                            max_new_tokens=int(d["max_new_tokens"]),
                            submitted_at=d.get("submitted_at"))


class ServeClient:
    """Submit requests to (and fetch results from) engines on a queue.

    Pushes raw lease triples so engine-side ``blpop_lease`` works (see
    module docstring); a bounded queue back-pressures ``submit`` via the
    fused ``blpop_rpush`` on the slots list — one store command per
    submit, inheriting whatever transport/mux the session store uses.
    """

    def __init__(self, queue):
        self.queue = queue
        self._store = queue._store

    def _resp_key(self, rid: str) -> str:
        return self.queue._key(f"resp:{rid}")

    def submit(self, tokens, max_new_tokens: int = 16,
               rid: Optional[str] = None,
               timeout: Optional[float] = None) -> str:
        rid = rid or uuid.uuid4().hex[:12]
        req = ServeRequest(rid, list(map(int, tokens)), max_new_tokens,
                           submitted_at=time.time())
        entry = (0, rid, req.to_payload())
        if self.queue._maxsize > 0:
            tok = self._store.blpop_rpush(self.queue._slots_key,
                                          self.queue._items_key,
                                          entry, timeout)
            if tok is None:
                raise TimeoutError(f"admission queue full for {timeout}s")
        else:
            self._store.rpush(self.queue._items_key, entry)
        return rid

    def result(self, rid: str, timeout: Optional[float] = None
               ) -> Dict[str, Any]:
        got = self._store.blpop(self._resp_key(rid), timeout)
        if got is None:
            raise TimeoutError(f"no result for {rid} within {timeout}s")
        return serialization.loads(got[1])


@dataclass
class _Slot:
    req: ServeRequest
    attempt: int
    leased: bool            # lease held in the store's inflight hash
    local: bool             # submitted via engine.submit, result kept local
    seq: int                # admission order (preemption picks the youngest)
    pages: List[int] = field(default_factory=list)
    state: str = "prefill"  # 'prefill' -> 'decode'
    prompt_pos: int = 0     # prompt tokens already prefilled
    length: int = 0         # KV cache entries written
    out_tokens: List[int] = field(default_factory=list)
    cur_token: int = 0      # last sampled token (next decode input)
    t_admit: float = 0.0
    t_first: Optional[float] = None


class ContinuousEngine:
    """Continuous-batching engine over the paged KV slab.

    See the module docstring for the admission / scheduling / eviction
    contract. Families: dense / vlm / moe (KV-cache caches only).
    """

    def __init__(self, model: Model, params, *, max_slots: int = 4,
                 page_size: int = 16, max_len: int = 128,
                 num_pages: Optional[int] = None, prefill_chunk: int = 16,
                 eos_id: Optional[int] = None, request_queue=None,
                 lease: bool = False, lease_ttl_s: float = 30.0,
                 worker_id: Optional[str] = None):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.page_size = page_size
        self.max_len = max_len
        self.max_pages_per_slot = -(-max_len // page_size)
        if num_pages is None:
            # roomy default: every slot can hold max_len without preemption
            num_pages = max_slots * self.max_pages_per_slot + 1
        self.prefill_chunk = prefill_chunk
        self.eos_id = eos_id
        self.queue = request_queue
        self.lease = lease and request_queue is not None
        self.lease_ttl_s = lease_ttl_s
        self.worker_id = worker_id or f"serve-{uuid.uuid4().hex[:8]}"
        self._store = None if request_queue is None else request_queue._store

        self.alloc = PageAllocator(num_pages, page_size)
        self._pages = model.init_paged_cache(num_pages, page_size)
        M = self.max_pages_per_slot
        self._tables = np.zeros((max_slots, M), np.int32)   # 0 = null page
        self._lengths = np.zeros((max_slots,), np.int32)
        self._mask = np.zeros((max_slots,), bool)
        self._tokens = np.zeros((max_slots,), np.int32)
        self._slots: List[Optional[_Slot]] = [None] * max_slots
        self._pending: collections.deque = collections.deque()  # local + requeued
        self._seq = 0
        self._last_renew = time.monotonic()
        self.results: Dict[str, Dict[str, Any]] = {}  # local submissions
        self.metrics = {"admitted": 0, "completed": 0, "preempted": 0,
                        "rejected": 0, "decode_steps": 0,
                        "prefill_chunks": 0}

        def decode_step(params, pages, tokens, tables, lengths, mask):
            logits, pages = model.decode_paged(params, pages, tokens,
                                               tables, lengths, mask)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), pages

        def prefill_step(params, pages, tokens, table, start, n_valid):
            logits, pages = model.prefill_paged_chunk(params, pages, tokens,
                                                      table, start, n_valid)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), pages

        donate = (1,) if jax.default_backend() == "tpu" else ()
        self._decode = jax.jit(decode_step, donate_argnums=donate)
        self._prefill_chunk = jax.jit(prefill_step, donate_argnums=donate)

    # ------------------------------------------------------------- metrics

    @property
    def decode_compiles(self) -> int:
        return self._decode._cache_size()

    @property
    def active(self) -> int:
        return sum(s is not None for s in self._slots)

    # ------------------------------------------------------------ requests

    def submit(self, tokens, max_new_tokens: int = 16,
               rid: Optional[str] = None,
               submitted_at: Optional[float] = None) -> str:
        """Local (queue-less) submission; result lands in ``self.results``.
        ``submitted_at`` (time.time base) backdates the arrival so open-
        loop benchmarks charge queue wait to the request."""
        rid = rid or uuid.uuid4().hex[:12]
        req = ServeRequest(rid, list(map(int, tokens)), max_new_tokens,
                           submitted_at=submitted_at or time.time())
        self._pending.append((req, 0, False, True))
        return rid

    def _resp_key(self, rid: str) -> str:
        return self.queue._key(f"resp:{rid}")

    def _poll_queue(self) -> Optional[Tuple[ServeRequest, int, bool, bool]]:
        """Pop one request from the shared queue (non-blocking-ish)."""
        if self.queue is None:
            return None
        items = self.queue._items_key
        if self.lease:
            inflight = self.queue._key("inflight")
            entry = self._store.blpop_lease(items, inflight, self.worker_id,
                                            self.lease_ttl_s, timeout=0.0)
        else:
            got = self._store.blpop(items, timeout=0.0)
            entry = None if got is None else got[1]
        if entry is None:
            return None
        if self.queue._maxsize > 0:  # hand the admission slot token back
            self._store.rpush(self.queue._slots_key, b"s")
        if (isinstance(entry, (tuple, list)) and len(entry) == 3
                and isinstance(entry[0], int)):
            attempt, _rid, payload = entry
            return ServeRequest.from_payload(payload), attempt, self.lease, False
        # lease-unaware producer used Queue.put: payload is the whole blob
        req = ServeRequest.from_payload(entry)
        return req, 0, False, False

    def _finish(self, req: ServeRequest, result: Dict[str, Any],
                slot: _Slot) -> None:
        if slot.leased:
            self._store.lease_release(self.queue._key("inflight"),
                                      req.id, slot.attempt)
        if slot.local or self.queue is None:
            self.results[req.id] = result
        else:
            self._store.rpush(self._resp_key(req.id),
                              serialization.dumps(result))

    # ---------------------------------------------------------- scheduling

    def _admit_one(self) -> bool:
        free_slot = next((i for i, s in enumerate(self._slots) if s is None),
                         None)
        if free_slot is None:
            return False
        if self._pending:
            req, attempt, leased, local = self._pending.popleft()
        else:
            popped = self._poll_queue()
            if popped is None:
                return False
            req, attempt, leased, local = popped
        total = len(req.tokens) + req.max_new_tokens
        if (not req.tokens or total > self.max_len
                or self.alloc.pages_for(total) > self.alloc.num_pages - 1):
            # reject anything that could not run even on an empty slab —
            # otherwise preemption would thrash forever trying to fit it
            self.metrics["rejected"] += 1
            slot = _Slot(req, attempt, leased, local, self._seq)
            self._finish(req, {"id": req.id, "error":
                               f"prompt+output {total} does not fit "
                               f"(max_len {self.max_len})", "tokens": []},
                         slot)
            return True
        need = self.alloc.pages_for(len(req.tokens))
        pages = self.alloc.alloc(need)
        if pages is None:
            # no pages: park it at the front and stop admitting this step
            self._pending.appendleft((req, attempt, leased, local))
            return False
        slot = _Slot(req, attempt, leased, local, self._seq, pages=pages,
                     t_admit=time.time())
        self._seq += 1
        self._slots[free_slot] = slot
        self._tables[free_slot] = 0
        self._tables[free_slot, :need] = pages
        self._lengths[free_slot] = 0
        self._mask[free_slot] = False  # joins decode only after prefill
        self.metrics["admitted"] += 1
        return True

    def _ensure_capacity(self, idx: int, pos: int) -> bool:
        """Grow slot ``idx`` so cache position ``pos`` is backed by a page."""
        slot = self._slots[idx]
        needed = pos // self.page_size + 1
        while len(slot.pages) < needed:
            got = self.alloc.alloc(1)
            if got is None:
                if not self._preempt_youngest():
                    return False
                if self._slots[idx] is not slot:
                    return False  # the victim was us
                continue
            self._tables[idx, len(slot.pages)] = got[0]
            slot.pages.extend(got)
        return True

    def _preempt_youngest(self) -> bool:
        """Preempt-by-recompute the youngest active slot. Returns False
        when there is nothing to preempt."""
        victims = [(s.seq, i) for i, s in enumerate(self._slots)
                   if s is not None]
        if not victims:
            return False
        _, idx = max(victims)
        slot = self._slots[idx]
        self.alloc.free(slot.pages)
        slot.pages = []
        self._release_slot(idx)
        # retry from scratch; lease stays held (still our request)
        self._pending.appendleft((slot.req, slot.attempt, slot.leased,
                                  slot.local))
        self.metrics["preempted"] += 1
        return True

    def _release_slot(self, idx: int) -> None:
        self._slots[idx] = None
        self._tables[idx] = 0
        self._lengths[idx] = 0
        self._mask[idx] = False
        self._tokens[idx] = 0

    def _complete(self, idx: int) -> None:
        slot = self._slots[idx]
        req = slot.req
        now = time.time()
        t0 = req.submitted_at if req.submitted_at is not None else slot.t_admit
        result = {"id": req.id, "tokens": list(slot.out_tokens),
                  "ttft_s": (slot.t_first - t0
                             if slot.t_first is not None else None),
                  "completion_s": now - t0}
        self.alloc.free(slot.pages)
        self._release_slot(idx)
        self._finish(req, result, slot)
        self.metrics["completed"] += 1

    def _emit_token(self, idx: int, tok: int) -> None:
        """Record one generated token for slot ``idx``; completes the
        request on eos or output budget."""
        slot = self._slots[idx]
        if slot.t_first is None:
            slot.t_first = time.time()
        slot.out_tokens.append(tok)
        slot.cur_token = tok
        done = (self.eos_id is not None and tok == self.eos_id) or \
               len(slot.out_tokens) >= slot.req.max_new_tokens
        if done:
            self._complete(idx)
        else:
            self._tokens[idx] = tok

    def _prefill_one(self) -> None:
        """Advance the OLDEST still-prefilling slot by one chunk."""
        cand = [(s.seq, i) for i, s in enumerate(self._slots)
                if s is not None and s.state == "prefill"]
        if not cand:
            return
        _, idx = min(cand)
        slot = self._slots[idx]
        C = self.prefill_chunk
        prompt = slot.req.tokens
        n_valid = min(C, len(prompt) - slot.prompt_pos)
        if not self._ensure_capacity(idx, slot.prompt_pos + n_valid - 1):
            return  # wait for pages (or we were the preemption victim)
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :n_valid] = prompt[slot.prompt_pos:slot.prompt_pos + n_valid]
        tok, self._pages = self._prefill_chunk(
            self.params, self._pages, jnp.asarray(chunk),
            jnp.asarray(self._tables[idx]), jnp.int32(slot.prompt_pos),
            jnp.int32(n_valid))
        self.metrics["prefill_chunks"] += 1
        slot.prompt_pos += n_valid
        slot.length = slot.prompt_pos
        self._lengths[idx] = slot.length
        if slot.prompt_pos == len(prompt):
            slot.state = "decode"
            self._emit_token(idx, int(tok[0]))  # first token: TTFT
            if self._slots[idx] is slot:  # not completed by that token
                self._mask[idx] = True

    def _decode_once(self) -> None:
        decoding = [i for i, s in enumerate(self._slots)
                    if s is not None and s.state == "decode"]
        if not decoding:
            return
        for idx in decoding:
            s = self._slots[idx]
            if s is None or s.state != "decode":
                continue  # preempted by an earlier slot's growth
            # the new token lands at cache position `length`
            self._ensure_capacity(idx, s.length)
        decoding = [i for i, s in enumerate(self._slots)
                    if s is not None and s.state == "decode"]
        if not decoding:
            return
        toks, self._pages = self._decode(
            self.params, self._pages, jnp.asarray(self._tokens),
            jnp.asarray(self._tables), jnp.asarray(self._lengths),
            jnp.asarray(self._mask))
        self.metrics["decode_steps"] += 1
        toks = np.asarray(toks)
        for idx in decoding:
            slot = self._slots[idx]
            slot.length += 1
            self._lengths[idx] = slot.length
            self._emit_token(idx, int(toks[idx]))

    def _renew_leases(self) -> None:
        if not self.lease:
            return
        now = time.monotonic()
        if now - self._last_renew < self.lease_ttl_s / 3:
            return
        self._last_renew = now
        inflight = self.queue._key("inflight")
        for s in self._slots:
            if s is not None and s.leased:
                self._store.lease_renew(inflight, s.req.id, s.attempt,
                                        self.lease_ttl_s)

    # ------------------------------------------------------------- driving

    def step(self) -> bool:
        """One scheduler tick: admit → one prefill chunk → one decode
        step → lease renewal. Returns True if any work was done."""
        admitted = False
        while self._admit_one():
            admitted = True
        had_prefill = any(s is not None and s.state == "prefill"
                          for s in self._slots)
        self._prefill_one()
        had_decode = any(s is not None and s.state == "decode"
                         for s in self._slots)
        self._decode_once()
        self._renew_leases()
        return admitted or had_prefill or had_decode

    def run_until_idle(self) -> None:
        """Drive until no local/pending work remains (queue not polled
        beyond what's already available)."""
        while True:
            worked = self.step()
            if not worked and not self._pending and self.active == 0:
                break

    def serve_forever(self, stop=None, poll_s: float = 0.005) -> None:
        """Drive until ``stop`` (threading.Event) is set; drains active
        requests before returning."""
        while stop is None or not stop.is_set():
            if not self.step():
                time.sleep(poll_s)
        while self.active > 0 or self._pending:
            self.step()
