"""Page allocator for the shared decode KV slab.

The serving cache is one slab of ``num_pages`` fixed-size pages per
layer (``models.Model.init_paged_cache``). Page 0 is reserved as the
**null page**: idle serving slots and prompt-padding positions scatter
their K/V writes there, and no live page table ever references it, so a
masked write can never corrupt a live sequence. The allocator therefore
hands out pages ``1 .. num_pages-1``.

Allocation is all-or-nothing (``alloc`` returns None rather than a
partial set) so the engine's admission / growth decisions stay atomic:
either a request gets every page it asked for or the slab state is
untouched and the scheduler can pick a preemption victim.
"""

from __future__ import annotations

from typing import List, Optional

__all__ = ["PageAllocator"]


class PageAllocator:
    """Free-list allocator over pages ``1 .. num_pages - 1`` (0 = null)."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need at least one allocatable page + the null page")
        self.num_pages = num_pages
        self.page_size = page_size
        # pop() hands out low page ids first
        self._free: List[int] = list(range(num_pages - 1, 0, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache entries (>= 1)."""
        return max(1, -(-n_tokens // self.page_size))

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` pages, or None (and no state change) if unavailable."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if not 0 < p < self.num_pages:
                raise ValueError(f"page {p} out of range")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
        self._free.extend(pages)
