"""Process — one serverless function per process (paper §3.1.1).

"every Process corresponds to a single function": ``start()`` serializes
target+args and invokes one function through the session's
FunctionExecutor. ``join``/``is_alive``/``exitcode`` are driven by the
task future; ``terminate`` sets a cooperative kill flag in the KV store
(FaaS functions cannot be killed externally — the flag is checked by
long-running framework loops such as Pool workers).
"""

from __future__ import annotations

import itertools
import threading
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

from . import session as _session
from .executor import FunctionExecutor, RemoteError, TaskFuture
from .reference import fresh_uid

__all__ = ["Process", "current_process", "active_children", "parent_process"]

_proc_counter = itertools.count(1)
_tls = threading.local()


class _ProcessInfo:
    """What ``multiprocessing.current_process()`` exposes."""

    def __init__(self, name: str, pid: int):
        self.name = name
        self.pid = pid
        self.daemon = False

    def __repr__(self):  # pragma: no cover - cosmetic
        return f"<ProcessInfo {self.name} pid={self.pid}>"


_MAIN = _ProcessInfo("MainProcess", 0)


def current_process() -> _ProcessInfo:
    return getattr(_tls, "info", _MAIN)


def parent_process() -> Optional[_ProcessInfo]:
    return None if current_process() is _MAIN else _MAIN


_active: Dict[str, "Process"] = {}
_active_lock = threading.Lock()


def active_children():
    with _active_lock:
        procs = list(_active.values())
    out = []
    for p in procs:
        if p.is_alive():
            out.append(p)
        else:
            with _active_lock:
                _active.pop(p._uid, None)
    return out


def _default_executor() -> FunctionExecutor:
    sess = _session.get_session()
    ex = getattr(sess, "_process_executor", None)
    if ex is None or ex.session is not sess:
        ex = FunctionExecutor(name="procs", **sess.executor_defaults)
        sess._process_executor = ex
    return ex


def _child_main(info_name: str, pid: int, target: Optional[Callable],
                args: Tuple, kwargs: Dict) -> int:
    _tls.info = _ProcessInfo(info_name, pid)
    try:
        if target is not None:
            target(*args, **kwargs)
        return 0
    finally:
        _tls.info = _MAIN


class Process:
    def __init__(self, group=None, target: Optional[Callable] = None,
                 name: Optional[str] = None, args: Sequence[Any] = (),
                 kwargs: Optional[Dict[str, Any]] = None, *,
                 daemon: Optional[bool] = None):
        if group is not None:
            raise ValueError("process group must be None")
        self._target = target
        self._args = tuple(args)
        self._kwargs = dict(kwargs or {})
        self._uid = fresh_uid("proc")
        self._number = next(_proc_counter)
        self.name = name or f"Process-{self._number}"
        self.daemon = bool(daemon)
        self.pid: Optional[int] = None
        self._future: Optional[TaskFuture] = None
        self._exitcode: Optional[int] = None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        if self._future is not None:
            raise RuntimeError("cannot start a process twice")
        self.pid = 100000 + self._number  # synthetic, stable
        ex = _default_executor()
        self._future = ex.call_async(
            _child_main, (self.name, self.pid, self._target, self._args,
                          self._kwargs))
        with _active_lock:
            _active[self._uid] = self

    def run(self) -> None:
        """Inline execution (matching multiprocessing's overridable run)."""
        if self._target is not None:
            self._target(*self._args, **self._kwargs)

    def join(self, timeout: Optional[float] = None) -> None:
        if self._future is None:
            raise RuntimeError("can only join a started process")
        if not self._future.wait(timeout):
            return  # like multiprocessing: join times out silently
        try:
            self._future.result(0)
            self._exitcode = 0
        except RemoteError:
            self._exitcode = 1
        with _active_lock:
            _active.pop(self._uid, None)

    def is_alive(self) -> bool:
        return self._future is not None and not self._future.done()

    @property
    def exitcode(self) -> Optional[int]:
        if self._exitcode is None and self._future is not None and self._future.done():
            try:
                self._future.result(0)
                self._exitcode = 0
            except RemoteError:
                self._exitcode = 1
        return self._exitcode

    def terminate(self) -> None:
        """Cooperative termination: set the kill flag for this process."""
        sess = _session.get_session()
        sess.store.set(f"{{{self._uid}}}:kill", 1, ex=3600)

    kill = terminate

    def __repr__(self):  # pragma: no cover - cosmetic
        state = ("initial" if self._future is None
                 else "running" if self.is_alive() else "stopped")
        return f"<Process name={self.name} pid={self.pid} state={state}>"
