"""Message-passing abstractions over KV lists (paper §3.2 "Message passing").

Pipe  -> two KV LISTs, one per direction. ``send()`` is an RPUSH to the
         peer's list, ``recv()`` a BLPOP on one's own list, so the list is
         a FIFO and blocking reads come for free — the paper's exact
         construction. ``poll(timeout)`` is a blocking BLLEN (wakeup on
         push), not an llen busy-poll.
Queue -> one LIST shared by any number of producers/consumers; bounded
         queues add a token LIST (capacity tokens), keeping *all*
         blocking inside the store.
JoinableQueue -> adds an outstanding-work counter (INCR/DECR) and a
         completion notification list for ``join()``.

Per-operation KV command (= remote round trip) counts on the hot path:

===========================  =====  =============================
operation                    cmds   wire commands
===========================  =====  =============================
Pipe.send / unbounded put      1    RPUSH items
Pipe.recv / unbounded get      1    BLPOP items
bounded Queue.put              1    BLPOPRPUSH slots->items blob
bounded Queue.get              1    BLPOPRPUSH items->slots token
Connection.poll(timeout)       1    BLLEN (blocking server-side)
===========================  =====  =============================

The bounded operations used to take 2 commands each (BLPOP token + RPUSH
payload); ``blpop_rpush`` fuses them so a put+get round trip costs 2 RTTs
instead of 4 — the difference the paper measures between "comparable to a
large VM" and per-operation latency death (§6).

Over the multiplexed TCP transport (``kvserver`` v3), the blocking
operations above (``recv``/``poll``/bounded ``put``/``get`` with a
nonzero timeout) ride the client's dedicated **blocking lane**
connection, where the server parks them on their own thread and answers
out of order; the non-blocking ones share the main-lane socket, where
concurrent threads' commands group-commit into one frame — so a consumer
parked in ``Queue.get`` never head-of-line blocks the producers' pushes,
even though the whole process multiplexes two sockets per server.

All payloads cross the store as serialized bytes (KV latency/metrics see
true wire sizes); over the TCP transport, large payloads travel as
zero-copy out-of-band frames (see ``kvserver``).

Every queue/pipe command above (``rpush``/``blpop``/``blpop_rpush``/
``bllen``/``llen``/``lpop``/``incr``) sits in the v4 raw wire vocabulary
(``serialization.RAW_COMMANDS``): with payload blobs under 4 KiB the
whole operation — command AND reply — crosses the wire through the
struct-packed codec with zero pickling of the envelope (the payload
bytes themselves were serialized once by ``put``/``send`` and travel
opaquely). Larger blobs automatically switch that one command to the
pickle-5 out-of-band path, keeping the zero-copy transfer.
"""

from __future__ import annotations

import queue as _stdqueue
import time
from typing import Any, Optional, Tuple

from . import serialization
from .pool import TimeoutError  # the multiprocessing-compatible one
from .reference import RemoteResource

__all__ = ["Pipe", "Connection", "Queue", "SimpleQueue", "JoinableQueue",
           "Empty", "Full"]

Empty = _stdqueue.Empty
Full = _stdqueue.Full


class Connection(RemoteResource):
    """One end of a Pipe. End ``i`` reads list ``c{i}``, writes ``c{1-i}``."""

    _RESOURCE_KIND = "pipe"

    def __init__(self, uid: str, end: int, duplex: bool, _adopt: bool = False,
                 **kw):
        super().__init__(uid=uid, _adopt=_adopt, **kw)
        self._rebuild(end, duplex)

    def _rebuild(self, end: int, duplex: bool) -> None:
        self._end = end
        self._duplex = duplex
        # multiprocessing semantics: with duplex=False, conn1 is read-only
        # and conn2 is write-only.
        self.readable = duplex or end == 0
        self.writable = duplex or end == 1

    def _reduce_state(self) -> Tuple[Any, ...]:
        return (self._end, self._duplex)

    def _kv_keys(self):
        return [self._refs_key, self._key("c0"), self._key("c1")]

    @property
    def _read_key(self) -> str:
        return self._key(f"c{self._end}")

    @property
    def _write_key(self) -> str:
        return self._key(f"c{1 - self._end}")

    # -- API ----------------------------------------------------------------

    def send(self, obj: Any) -> None:
        self.send_bytes(serialization.dumps(obj))

    def send_bytes(self, data: bytes) -> None:
        if not self.writable:
            raise OSError("connection is read-only")
        self._store.rpush(self._write_key, data)

    def recv(self) -> Any:
        return serialization.loads(self.recv_bytes())

    def recv_bytes(self, timeout: Optional[float] = None) -> bytes:
        if not self.readable:
            raise OSError("connection is write-only")
        got = self._store.blpop(self._read_key, timeout)
        if got is None:
            raise TimeoutError("recv timed out")
        return got[1]

    def poll(self, timeout: float = 0.0) -> bool:
        if not timeout or timeout <= 0:
            return self._store.llen(self._read_key) > 0
        # Blocking wait in the store: one command, wakeup on push. BLLEN is
        # part of the store interface (KVStore, ShardedKVStore, and any
        # KVServer reached through KVClient all serve it).
        return self._store.bllen(self._read_key, timeout) > 0


def Pipe(duplex: bool = True) -> Tuple[Connection, Connection]:
    c0 = Connection(uid=None, end=0, duplex=duplex)
    c1 = Connection(uid=c0.uid, end=1, duplex=duplex)
    return c0, c1


class Queue(RemoteResource):
    _RESOURCE_KIND = "queue"

    def __init__(self, maxsize: int = 0, _adopt: bool = False, **kw):
        super().__init__(_adopt=_adopt, **kw)
        self._rebuild(maxsize)
        if not _adopt and maxsize > 0:
            # capacity tokens: put() consumes one, get() returns one.
            self._store.rpush(self._slots_key, *([b"s"] * maxsize))

    def _rebuild(self, maxsize: int) -> None:
        self._maxsize = maxsize

    def _reduce_state(self):
        return (self._maxsize,)

    @property
    def _items_key(self) -> str:
        return self._key("items")

    @property
    def _slots_key(self) -> str:
        return self._key("slots")

    def _kv_keys(self):
        return [self._refs_key, self._items_key, self._slots_key]

    # -- API ----------------------------------------------------------------

    def put(self, obj: Any, block: bool = True, timeout: Optional[float] = None) -> None:
        blob = serialization.dumps(obj)
        if self._maxsize > 0:
            # One fused command: pop a capacity token, push the payload.
            tok = self._store.blpop_rpush(self._slots_key, self._items_key,
                                          blob, timeout if block else 0.0)
            if tok is None:
                raise Full
            return
        self._store.rpush(self._items_key, blob)

    def put_nowait(self, obj: Any) -> None:
        self.put(obj, block=False)

    def get(self, block: bool = True, timeout: Optional[float] = None) -> Any:
        if self._maxsize > 0:
            # One fused command: pop the payload, push a token back.
            blob = self._store.blpop_rpush(self._items_key, self._slots_key,
                                           b"s", timeout if block else 0.0)
            if blob is None:
                raise Empty
            return serialization.loads(blob)
        if block:
            got = self._store.blpop(self._items_key, timeout)
            if got is None:
                raise Empty
            blob = got[1]
        else:
            blob = self._store.lpop(self._items_key)
            if blob is None:
                raise Empty
        return serialization.loads(blob)

    def get_nowait(self) -> Any:
        return self.get(block=False)

    def qsize(self) -> int:
        return self._store.llen(self._items_key)

    def empty(self) -> bool:
        return self.qsize() == 0

    def full(self) -> bool:
        return self._maxsize > 0 and self._store.llen(self._slots_key) == 0

    # local-resource lifecycle methods are no-ops remotely
    def join_thread(self) -> None:
        pass

    def cancel_join_thread(self) -> None:
        pass


class SimpleQueue(Queue):
    _RESOURCE_KIND = "squeue"

    def __init__(self, **kw):
        super().__init__(maxsize=0, **kw)


class JoinableQueue(Queue):
    _RESOURCE_KIND = "jqueue"

    @property
    def _unfinished_key(self) -> str:
        return self._key("unfinished")

    @property
    def _joinev_key(self) -> str:
        return self._key("joinev")

    def _kv_keys(self):
        return super()._kv_keys() + [self._unfinished_key, self._joinev_key]

    def put(self, obj: Any, block: bool = True, timeout: Optional[float] = None) -> None:
        self._store.incr(self._unfinished_key)
        super().put(obj, block, timeout)

    def task_done(self) -> None:
        unfinished_key, joinev_key = self._unfinished_key, self._joinev_key

        def txn(s):  # closes over plain strings only (TCP-transaction safe)
            left = s.incrby(unfinished_key, -1)
            if left < 0:
                raise ValueError("task_done() called too many times")
            if left == 0:
                s.rpush(joinev_key, b"done")
            return left
        if hasattr(self._store, "shards"):
            self._store.transaction(txn, key_hint=unfinished_key)
        else:
            self._store.transaction(txn)

    def join(self, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            v = self._store.get(self._unfinished_key)
            if not v or int(v) <= 0:
                return
            wait = 0.05
            if deadline is not None:
                wait = min(wait, max(0.0, deadline - time.monotonic()))
                if wait <= 0:
                    raise TimeoutError("join timed out")
            self._store.blpop(self._joinev_key, wait)
