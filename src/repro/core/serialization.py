"""Closure-capable serialization (paper §3.1.1 step 2).

Lithops "automatically detects, serializes and uploads" the process
function, its arguments and referenced globals. Plain ``pickle`` only
serializes functions *by reference* (module + qualname), which fails for
lambdas, closures, and anything defined in ``__main__`` or interactively.

``dumps``/``loads`` here extend pickle with by-value function support à la
cloudpickle: dynamic functions are reduced to (marshaled code, referenced
globals, defaults, closure cells) and rebuilt on the worker. Only the
globals actually referenced by the code object (transitively, through
nested code constants) are captured — this is the paper's "detects ...
dependencies" step.

``dumps_oob``/``loads_oob`` add pickle protocol-5 *out-of-band* buffers
(PEP 574) for the remote hot path: numpy arrays and large ``bytes`` /
``bytearray`` payloads (the paper's ES / PPO parameter vectors, queue
blobs) are emitted as separate zero-copy buffers instead of being copied
into the pickle stream. The transport (``kvserver``) sends each buffer
as its own scatter-gather frame part, so a 1 MB payload crosses the wire
without a single sender-side copy.

``FRAME_TAG`` is the request-id tag of the v3 multiplexed wire dialect:
a fixed-width unsigned word prepended to a frame's part-length vector.
Many client threads share ONE socket per server; the tag is what lets
the server answer out of order (a parked BLPOP must not head-of-line
block the commands behind it) and lets the client-side I/O mux correlate
each response with the submitting thread's future. It lives here, next
to the payload encoding, because it is the one piece of framing state
that both ends must agree on byte-for-byte.

v4 "raw" command codec (``encode_command``/``decode_command`` +
``encode_reply``/``decode_reply``): a type-tagged, struct-packed binary
encoding of the HOT command vocabulary (:data:`RAW_COMMANDS`) that
removes ``pickle`` from both ends of a small-command round trip — the
client-GIL ceiling left after PRs 1-4 amortized the syscalls. Layout::

    command := cmd_id:u8, nargs:u32, value*, nkw:u8, (klen:u8, key, value)*
    EXEC    := cmd_id:u8, nentries:u32, (len:u32, command)*   # execute_batch
    reply   := ok:u8 (0|1), value
    value   := tag:u8, payload            (self-delimiting, recursive)

    tag  payload
    'N'  none                      None
    'T'  none                      True
    'F'  none                      False
    'i'  i64                       int in [-2^63, 2^63)
    'I'  u32 len + signed bytes    arbitrary-precision int
    'f'  f64                       float (IEEE 754, NaN-safe)
    'B'  u32 len + raw bytes       bytes  (< OOB_THRESHOLD — see below)
    'S'  u32 len + utf-8           str    (surrogatepass, lossless)
    'U'  u32 n + value*            tuple
    'L'  u32 n + value*            list
    'D'  u32 n + (u32 klen, utf-8 key, value)*   dict with str keys

All words network order. Per-command **cost model**: one u8 dispatch id
(the server indexes a precomputed bound-method table — no ``getattr``,
no name check) plus one fixed-width tag+payload per argument; encode and
decode are a handful of ``struct`` ops with no object graph traversal,
no memo table, and no Pickler/Unpickler instantiation per command.
``encode_command``/``encode_reply`` return None for anything outside the
vocabulary — unknown commands, exotic argument types, exceptions in
replies, containers nested deeper than ``_RAW_DEPTH``, or any
bytes-like of ``OOB_THRESHOLD`` bytes or more (large values stay on the
pickle-5 out-of-band zero-copy path, which ships them as scatter-gather
frame parts without a copy) — and the transport falls back to the
pickle dialect for that one command. ``execute_batch`` bodies are
length-prefixed concatenations of independently encoded entries, so the
I/O mux's group commit can merge pre-encoded submissions by byte
concatenation (``encode_batch_entries``) without re-encoding — and
without pickling — under the flush lock.

Every dialect above is **transport-independent** (PR 6): the same v1-v4
byte frames travel unchanged over a TCP socket, a Unix-domain socket, or
a shared-memory SPSC ring (``repro.core.transport``). Nothing in this
module knows which carrier moves the bytes — the framing contract is
"a reliable ordered byte stream", and every carrier provides exactly
that, which is what lets ``KVClient(transport=...)`` A/B carriers
without touching the codec or the server dispatch path.
"""

from __future__ import annotations

import importlib
import io
import marshal
import pickle
import struct
import types
from typing import Any, Dict, List, Optional, Set, Tuple

from .errors import ShardRedirectError

__all__ = ["dumps", "loads", "dumps_oob", "loads_oob", "payload_size",
           "OOB_THRESHOLD", "FRAME_TAG", "MAX_FRAME_TAG",
           "RAW_COMMANDS", "RAW_COMMAND_IDS", "RAW_EXEC_ID", "Prepickled",
           "encode_command", "decode_command", "decode_command_id",
           "encode_reply", "decode_reply", "encode_batch_entries"]

#: v3 frame tag: one network-order u32 request id per tagged frame. Ids
#: are per-connection and wrap at 2**32 — a connection never has 4
#: billion requests in flight, so a wrapped id can't collide with a live
#: one.
FRAME_TAG = struct.Struct("!I")
MAX_FRAME_TAG = 1 << 32

#: Payloads at least this large go out-of-band when a buffer callback is
#: active. Below it, the header/descriptor overhead outweighs the copy.
OOB_THRESHOLD = 4096


def _is_importable(obj: Any) -> bool:
    """True if pickle-by-reference would round-trip this function/class."""
    module = getattr(obj, "__module__", None)
    qualname = getattr(obj, "__qualname__", None)
    if module is None or qualname is None or "<locals>" in qualname or "<lambda>" in qualname:
        return False
    try:
        mod = importlib.import_module(module)
    except Exception:
        return False
    found = mod
    for part in qualname.split("."):
        found = getattr(found, part, None)
        if found is None:
            return False
    return found is obj


def _referenced_globals(code: types.CodeType, globals_: Dict[str, Any],
                        seen: Set[str]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    names = set(code.co_names)
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            out.update(_referenced_globals(const, globals_, seen))
    for name in names:
        if name in seen or name not in globals_:
            continue
        seen.add(name)
        out[name] = globals_[name]
    return out


def _make_cell(value):
    def f():
        return value
    return f.__closure__[0]


def _make_empty_cell():
    def f():
        if False:
            value = None  # noqa: F841 - creates the cell

        def g():
            return value  # noqa: F821
        return g
    return f().__closure__[0]


def _rebuild_function(code_bytes, globals_dict, name, defaults, closure_values,
                      kwdefaults, qualname, module):
    code = marshal.loads(code_bytes)
    globals_dict = dict(globals_dict)
    globals_dict.setdefault("__builtins__", __builtins__)
    cells = tuple(
        _make_empty_cell() if v is _SENTINEL_EMPTY else _make_cell(v)
        for v in closure_values
    )
    fn = types.FunctionType(code, globals_dict, name, defaults, cells or None)
    fn.__kwdefaults__ = kwdefaults
    fn.__qualname__ = qualname
    fn.__module__ = module
    return fn


class _Sentinel:
    def __repr__(self):  # pragma: no cover
        return "<empty-cell>"


_SENTINEL_EMPTY = _Sentinel()


def _apply_function_state(fn, state):
    """Post-rebuild fixup: point self-referential closure cells at fn."""
    for i in state.get("self_cells", ()):
        fn.__closure__[i].cell_contents = fn
    return fn


def _rebuild_class(name, bases, dct, qualname, module):
    cls = type(name, bases, dct)
    cls.__qualname__ = qualname
    cls.__module__ = module
    return cls


class _Pickler(pickle.Pickler):
    def reducer_override(self, obj):
        if isinstance(obj, types.ModuleType):
            # modules captured in closures/globals: pickle by import name
            return (importlib.import_module, (obj.__name__,))
        if isinstance(obj, type) and not _is_importable(obj):
            # dynamic class (defined in a function / __main__): by value
            dct = {k: v for k, v in obj.__dict__.items()
                   if k not in ("__dict__", "__weakref__")}
            return (_rebuild_class, (obj.__name__, obj.__bases__, dct,
                                     obj.__qualname__, obj.__module__))
        if isinstance(obj, types.FunctionType) and not _is_importable(obj):
            return self._reduce_function(obj)
        return NotImplemented

    def _reduce_function(self, fn: types.FunctionType):
        code_bytes = marshal.dumps(fn.__code__)
        globals_dict = _referenced_globals(fn.__code__, fn.__globals__, set())
        # Avoid self-reference loops (recursive top-level functions).
        globals_dict = {k: v for k, v in globals_dict.items() if v is not fn}
        globals_dict.pop("__builtins__", None)
        closure_values = []
        self_cells = []
        if fn.__closure__:
            for i, cell in enumerate(fn.__closure__):
                try:
                    v = cell.cell_contents
                except ValueError:
                    v = _SENTINEL_EMPTY
                if v is fn:  # local recursion: patch after rebuild
                    self_cells.append(i)
                    v = _SENTINEL_EMPTY
                closure_values.append(v)
        return (
            _rebuild_function,
            (code_bytes, globals_dict, fn.__name__, fn.__defaults__,
             tuple(closure_values), fn.__kwdefaults__, fn.__qualname__,
             fn.__module__),
            {"self_cells": self_cells},
            None, None, _apply_function_state,
        )


def dumps(obj: Any, protocol: int = pickle.HIGHEST_PROTOCOL) -> bytes:
    buf = io.BytesIO()
    _Pickler(buf, protocol).dump(obj)
    return buf.getvalue()


def loads(data: bytes) -> Any:
    return pickle.loads(data)


def dumps_oob(obj: Any) -> Tuple[bytes, List[memoryview]]:
    """Serialize with out-of-band buffers (PEP 574).

    Returns ``(payload, buffers)``: the pickle stream holds only
    descriptors for every large buffer (numpy arrays, big bytes), which
    are returned as raw zero-copy memoryviews into the original objects.
    Reverse with :func:`loads_oob`. The caller must keep ``obj`` alive
    until the buffers have been consumed (e.g. written to a socket).
    """
    buffers: List[pickle.PickleBuffer] = []
    buf = io.BytesIO()
    p = _Pickler(buf, pickle.HIGHEST_PROTOCOL, buffer_callback=buffers.append)
    p.dump(_wrap_oob(obj, _WRAP_DEPTH))
    return buf.getvalue(), [_flat(b) for b in buffers]


class _OOBBlob:
    """Stand-in that reduces a large bytes/bytearray to an out-of-band
    PickleBuffer. Needed because CPython's pickler never consults
    ``reducer_override`` for exact ``bytes``/``bytearray`` instances
    (they take the C fast path), so the detour must happen pre-pickle."""

    __slots__ = ("_pb", "_cls")

    def __init__(self, obj):
        self._pb = pickle.PickleBuffer(obj)
        self._cls = type(obj)

    def __reduce__(self):
        return (self._cls, (self._pb,))


#: How deep ``_wrap_oob`` descends. 6 covers the deepest hot-path shape:
#: ("execute_batch", ([(cmd, (key, blob), {}), ...],), {}).
_WRAP_DEPTH = 6


def _wrap_oob(obj: Any, depth: int) -> Any:
    # Pre-scan without allocating: the overwhelmingly common case (all-small
    # command batches) must not pay a deep rebuild of every container.
    if not _has_oob(obj, depth):
        return obj
    return _wrap(obj, depth)


def _has_oob(obj: Any, depth: int) -> bool:
    t = type(obj)
    if t in (bytes, bytearray):
        return len(obj) >= OOB_THRESHOLD
    if depth > 0:
        if t is tuple or t is list:
            return any(_has_oob(x, depth - 1) for x in obj)
        if t is dict:
            return any(_has_oob(v, depth - 1) for v in obj.values())
    return False


def _wrap(obj: Any, depth: int) -> Any:
    t = type(obj)
    if t in (bytes, bytearray) and len(obj) >= OOB_THRESHOLD:
        return _OOBBlob(obj)
    if depth > 0:
        if t is tuple:
            return tuple(_wrap(x, depth - 1) for x in obj)
        if t is list:
            return [_wrap(x, depth - 1) for x in obj]
        if t is dict:
            return {k: _wrap(v, depth - 1) for k, v in obj.items()}
    return obj


def _flat(b: pickle.PickleBuffer) -> memoryview:
    try:
        return b.raw()
    except BufferError:
        # Non-C-contiguous (e.g. Fortran-order arrays): flatten preserving
        # physical layout — one copy, still out-of-band on the wire.
        return memoryview(memoryview(b).tobytes(order="A"))


def loads_oob(payload: Any, buffers: Optional[List[Any]] = None) -> Any:
    """Inverse of :func:`dumps_oob`; accepts any buffer-likes (bytearray,
    memoryview) so the transport can hand over receive buffers directly."""
    return pickle.loads(payload, buffers=buffers or ())


def payload_size(obj: Any) -> int:
    """Serialized size — used by the latency model and benchmarks."""
    return len(dumps(obj))


class Prepickled:
    """An already-serialized object embeddable in an outer ``dumps``.

    Pickling the wrapper emits the stored payload plus a ``loads`` call,
    so the inner object's graph is never re-traversed: the executor's
    ``map`` serializes the task function ONCE and reuses the bytes
    across every per-item payload (the per-item cost drops to the args).
    """

    __slots__ = ("payload",)

    def __init__(self, payload: bytes):
        self.payload = payload

    def __reduce__(self):
        return (loads, (self.payload,))


# ---------------------------------------------------------------------------
# v4 raw command codec (see module docstring for the frame layout)
# ---------------------------------------------------------------------------

#: The hot command vocabulary, in dispatch-id order. Index = the u8 wire
#: id AND the server's dispatch-table slot — append only, never reorder
#: (the id is a wire contract between mixed-version peers).
RAW_COMMANDS: Tuple[str, ...] = (
    "get", "set", "mget", "mset", "incr", "incrby", "decr",
    "rpush", "lpush", "lpop", "rpop", "blpop", "brpop",
    "blpop_rpush", "bllen", "llen",
    "getrange", "setrange", "msetrange", "strlen",
    "expire", "persist", "ttl", "exists", "delete",
    "execute_batch",
    # PR 7: replication plane. A primary streams its command log to
    # replicas as repl_apply(first_seq, [(cmd, args, kwargs), ...])
    # batches, riding the same v4 dialect as client traffic (entries
    # with OOB-sized or exotic args fall back to the pickle dialect,
    # exactly like any other command).
    "repl_apply",
    # PR 8: task-plane lease protocol. blpop_lease is the fused
    # hand-off (pop + in-flight lease record, one RTT, same shape as
    # blpop_rpush); renew/release are the per-heartbeat/per-settle hot
    # commands, fenced by attempt; lease_reap is the (cold) reclaim
    # sweep. Entries whose payload reaches OOB size fall back to the
    # pickle dialect per command, like everything else.
    "blpop_lease", "lease_renew", "lease_release", "lease_reap",
)
RAW_COMMAND_IDS: Dict[str, int] = {c: i for i, c in enumerate(RAW_COMMANDS)}
#: Dispatch id of ``execute_batch`` — its body nests whole sub-commands.
RAW_EXEC_ID = RAW_COMMAND_IDS["execute_batch"]

_U8 = struct.Struct("!B")
_U32 = struct.Struct("!I")
_I64 = struct.Struct("!q")
_F64 = struct.Struct("!d")
_I64_MIN, _I64_MAX = -(1 << 63), (1 << 63) - 1

#: Max container nesting in raw values. 4 covers every hot shape
#: (msetrange entry lists of tuples, the cluster descriptor's dict of
#: lists of address pairs); anything deeper falls back to pickle.
_RAW_DEPTH = 4

_TAG_NONE, _TAG_TRUE, _TAG_FALSE = ord("N"), ord("T"), ord("F")
_TAG_I64, _TAG_BIG, _TAG_F64 = ord("i"), ord("I"), ord("f")
_TAG_BYTES, _TAG_STR = ord("B"), ord("S")
_TAG_TUPLE, _TAG_LIST, _TAG_DICT = ord("U"), ord("L"), ord("D")
#: PR 7 redirect frame: a replica answering a mutating command encodes a
#: ShardRedirectError (message, epoch, shard) so the refusal stays in the
#: raw dialect instead of forcing a pickle fallback on the redirect path.
_TAG_REDIR = ord("R")


class _NotRaw(Exception):
    """Internal: the value/command is outside the raw vocabulary."""


# Hot-path note: these run once per command per direction — the whole
# point of the codec is beating a C pickler on SMALL payloads, so the
# scalar cases are ordered by frequency (str keys, bytes values, ints),
# struct methods are bound into locals, and the exec path decodes
# entries in place without slicing sub-buffers.

def _enc_value(out: bytearray, v: Any, depth: int = _RAW_DEPTH,
               _u32: Any = _U32.pack, _i64: Any = _I64.pack,
               _f64: Any = _F64.pack) -> None:
    t = type(v)  # exact types only: subclasses keep pickle's fidelity
    if t is str:
        b = v.encode("utf-8", "surrogatepass")
        out.append(_TAG_STR)
        out += _u32(len(b))
        out += b
    elif t is bytes:
        if len(v) >= OOB_THRESHOLD:
            raise _NotRaw  # large values keep the zero-copy OOB path
        out.append(_TAG_BYTES)
        out += _u32(len(v))
        out += v
    elif t is int:
        if _I64_MIN <= v <= _I64_MAX:
            out.append(_TAG_I64)
            out += _i64(v)
        else:
            big = v.to_bytes((v.bit_length() + 8) // 8, "big", signed=True)
            out.append(_TAG_BIG)
            out += _u32(len(big))
            out += big
    elif v is None:
        out.append(_TAG_NONE)
    elif t is bool:
        out.append(_TAG_TRUE if v else _TAG_FALSE)
    elif t is float:
        out.append(_TAG_F64)
        out += _f64(v)
    elif t is tuple or t is list:
        if depth <= 0:
            raise _NotRaw
        out.append(_TAG_TUPLE if t is tuple else _TAG_LIST)
        out += _u32(len(v))
        for x in v:
            _enc_value(out, x, depth - 1)
    elif t is dict:
        if depth <= 0:
            raise _NotRaw
        out.append(_TAG_DICT)
        out += _u32(len(v))
        for k, x in v.items():
            if type(k) is not str:
                raise _NotRaw
            kb = k.encode("utf-8", "surrogatepass")
            out += _u32(len(kb))
            out += kb
            _enc_value(out, x, depth - 1)
    elif t is ShardRedirectError:
        # cold branch: only replica-mode servers emit redirects
        msg = str(v.args[0]) if v.args else ""
        mb = msg.encode("utf-8", "surrogatepass")
        out.append(_TAG_REDIR)
        out += _u32(len(mb))
        out += mb
        out += _i64(int(v.epoch))
        out += _i64(int(v.shard))
    else:
        # bytearray/memoryview included: decoding would narrow them to
        # bytes, so mutable buffers keep pickle's round-trip fidelity
        raise _NotRaw


def _dec_value(buf: bytes, off: int, depth: int = _RAW_DEPTH,
               _u32: Any = _U32.unpack_from, _i64: Any = _I64.unpack_from,
               _f64: Any = _F64.unpack_from) -> Tuple[Any, int]:
    tag = buf[off]
    off += 1
    if tag == _TAG_STR:
        (n,) = _u32(buf, off)
        off += 4
        end = off + n
        return buf[off:end].decode("utf-8", "surrogatepass"), end
    if tag == _TAG_BYTES:
        (n,) = _u32(buf, off)
        off += 4
        end = off + n
        return buf[off:end], end
    if tag == _TAG_I64:
        return _i64(buf, off)[0], off + 8
    if tag == _TAG_NONE:
        return None, off
    if tag == _TAG_TRUE:
        return True, off
    if tag == _TAG_FALSE:
        return False, off
    if tag == _TAG_F64:
        return _f64(buf, off)[0], off + 8
    if tag == _TAG_TUPLE or tag == _TAG_LIST:
        if depth <= 0:
            raise ValueError("raw value nested too deep")
        (n,) = _u32(buf, off)
        off += 4
        items = []
        append = items.append
        for _ in range(n):
            v, off = _dec_value(buf, off, depth - 1)
            append(v)
        return (tuple(items) if tag == _TAG_TUPLE else items), off
    if tag == _TAG_DICT:
        if depth <= 0:
            raise ValueError("raw value nested too deep")
        (n,) = _u32(buf, off)
        off += 4
        d: Dict[str, Any] = {}
        for _ in range(n):
            (klen,) = _u32(buf, off)
            off += 4
            k = buf[off:off + klen].decode("utf-8", "surrogatepass")
            off += klen
            d[k], off = _dec_value(buf, off, depth - 1)
        return d, off
    if tag == _TAG_BIG:
        (n,) = _u32(buf, off)
        off += 4
        end = off + n
        return int.from_bytes(buf[off:end], "big", signed=True), end
    if tag == _TAG_REDIR:
        (n,) = _u32(buf, off)
        off += 4
        end = off + n
        msg = buf[off:end].decode("utf-8", "surrogatepass")
        epoch = _i64(buf, end)[0]
        shard = _i64(buf, end + 8)[0]
        return ShardRedirectError(msg, epoch, shard), end + 16
    raise ValueError(f"unknown raw value tag {tag:#x}")


#: Memo caches for the per-command hot path. Real workloads re-touch a
#: small working set of keys (queue item/slot keys, counters, shared
#: array segments), so the same tiny command bodies encode and decode
#: over and over. Keys are exact: (cmd, args) with ALL-STRING args on
#: the encode side (numbers are excluded — ``hash(1) == hash(1.0) ==
#: hash(True)`` would alias distinct encodings), the exact body bytes
#: on the decode side. Cleared when full (simple, adapts to phase
#: changes); GIL-safe, and racing fills are idempotent.
_ENC_CACHE: Dict[tuple, bytes] = {}
_DEC_CACHE: Dict[bytes, tuple] = {}
_CACHE_MAX = 4096
_CACHEABLE_BODY = 96  # bytes; only tiny bodies are worth remembering


def encode_command(cmd: str, args: tuple, kwargs: Optional[dict] = None
                   ) -> Optional[bytes]:
    """Encode ``(cmd, args, kwargs)`` as a raw v4 body, or None when the
    command/arguments are outside the raw vocabulary (the caller falls
    back to the pickle dialect for this one command)."""
    if not kwargs and len(args) <= 4:
        for a in args:
            if type(a) is not str:
                break
        else:
            key = (cmd, args)
            body = _ENC_CACHE.get(key)
            if body is None:
                body = _encode_command_uncached(cmd, args, {})
                if body is not None and len(body) <= _CACHEABLE_BODY:
                    if len(_ENC_CACHE) >= _CACHE_MAX:
                        _ENC_CACHE.clear()
                    _ENC_CACHE[key] = body
            return body
    return _encode_command_uncached(cmd, args, kwargs)


def _encode_command_uncached(cmd: str, args: tuple,
                             kwargs: Optional[dict]) -> Optional[bytes]:
    cid = RAW_COMMAND_IDS.get(cmd)
    if cid is None:
        return None
    kwargs = kwargs or {}
    if cid == RAW_EXEC_ID:
        if kwargs or len(args) != 1 or type(args[0]) not in (list, tuple):
            return None
        subs: List[bytes] = []
        for entry in args[0]:
            if type(entry) not in (list, tuple) or len(entry) != 3:
                return None
            c, a, k = entry
            if c == "execute_batch":  # no EXEC-in-EXEC on the raw wire
                return None
            sub = encode_command(c, tuple(a), dict(k or {}))
            if sub is None:
                return None
            subs.append(sub)
        return encode_batch_entries(subs)
    if len(kwargs) > 255:
        return None
    out = bytearray()
    out.append(cid)
    out += _U32.pack(len(args))
    enc = _enc_value
    try:
        for a in args:
            # inlined scalar fast path (str keys and bytes values are
            # the overwhelming majority of hot-command arguments)
            t = type(a)
            if t is str:
                b = a.encode("utf-8", "surrogatepass")
                out.append(_TAG_STR)
                out += _U32.pack(len(b))
                out += b
            elif t is bytes:
                if len(a) >= OOB_THRESHOLD:
                    return None
                out.append(_TAG_BYTES)
                out += _U32.pack(len(a))
                out += a
            elif t is int and _I64_MIN <= a <= _I64_MAX:
                out.append(_TAG_I64)
                out += _I64.pack(a)
            else:
                enc(out, a)
        if kwargs:
            out.append(len(kwargs))
            for k, v in kwargs.items():
                if type(k) is not str:
                    return None
                kb = k.encode("utf-8")
                if len(kb) > 255:
                    return None
                out.append(len(kb))
                out += kb
                enc(out, v)
        else:
            out.append(0)
    except (_NotRaw, OverflowError, struct.error):
        return None
    return bytes(out)


def encode_batch_entries(subs: List[bytes]) -> bytes:
    """An ``execute_batch`` body from already-encoded entry bodies: pure
    length-prefixed concatenation, so the I/O mux's group commit merges
    pre-encoded submissions without re-encoding under its flush lock."""
    out = bytearray()
    out.append(RAW_EXEC_ID)
    out += _U32.pack(len(subs))
    for s in subs:
        out += _U32.pack(len(s))
        out += s
    return bytes(out)


def _dec_command_at(buf: bytes, off: int,
                    _u32: Any = _U32.unpack_from,
                    _i64: Any = _I64.unpack_from
                    ) -> Tuple[int, tuple, dict, int]:
    """Decode one non-EXEC command in place; returns (cid, args, kwargs,
    next_offset). Shared by the single-command and batch-entry paths so
    batch entries never pay a per-entry sub-buffer slice."""
    cid = buf[off]
    if cid >= len(RAW_COMMANDS) or cid == RAW_EXEC_ID:
        if cid == RAW_EXEC_ID:
            raise ValueError("nested execute_batch on the raw wire")
        raise ValueError(f"unknown raw command id {cid}")
    (na,) = _u32(buf, off + 1)
    off += 5
    args = []
    append = args.append
    dec = _dec_value
    for _ in range(na):
        # inlined scalar fast path, mirroring encode_command's
        tag = buf[off]
        if tag == _TAG_STR:
            (n,) = _u32(buf, off + 1)
            off += 5
            end = off + n
            append(buf[off:end].decode("utf-8", "surrogatepass"))
            off = end
        elif tag == _TAG_BYTES:
            (n,) = _u32(buf, off + 1)
            off += 5
            end = off + n
            append(buf[off:end])
            off = end
        elif tag == _TAG_I64:
            append(_i64(buf, off + 1)[0])
            off += 9
        else:
            v, off = dec(buf, off)
            append(v)
    nk = buf[off]
    off += 1
    kwargs: Dict[str, Any] = {}
    for _ in range(nk):
        klen = buf[off]
        off += 1
        k = buf[off:off + klen].decode("utf-8")
        off += klen
        kwargs[k], off = dec(buf, off)
    return cid, tuple(args), kwargs, off


def decode_command_id(buf: Any) -> Tuple[int, tuple, dict]:
    """Decode a raw body to ``(cmd_id, args, kwargs)`` — the server fast
    path: the id indexes a precomputed bound-method dispatch table, so
    execution skips ``getattr`` and the name check entirely.
    ``execute_batch`` entries come back as nested id-triples."""
    buf = bytes(buf)  # one copy: decoded values never alias the transport
    try:
        cid = buf[0]
        if cid == RAW_EXEC_ID:
            (n,) = _U32.unpack_from(buf, 1)
            off = 5
            entries = []
            append = entries.append
            cache = _DEC_CACHE
            u32 = _U32.unpack_from
            for _ in range(n):
                (ln,) = u32(buf, off)
                off += 4
                end = off + ln
                if ln <= _CACHEABLE_BODY:
                    body = buf[off:end]
                    entry = cache.get(body)
                    if entry is None:
                        ecid, ea, ek, stop = _dec_command_at(buf, off)
                        if stop != end:
                            raise ValueError("misframed raw batch entry")
                        entry = (ecid, ea, ek)
                        _dec_cache_put(body, entry)
                else:
                    # big entry: guaranteed cache miss AND uncacheable —
                    # skip the memo slice copy entirely
                    ecid, ea, ek, stop = _dec_command_at(buf, off)
                    if stop != end:
                        raise ValueError("misframed raw batch entry")
                    entry = (ecid, ea, ek)
                append(entry)
                off = end
            if off != len(buf):
                raise ValueError("trailing bytes after raw batch")
            return cid, (entries,), {}
        entry = _DEC_CACHE.get(buf)
        if entry is None:
            cid, args, kwargs, off = _dec_command_at(buf, 0)
            if off != len(buf):
                raise ValueError("trailing bytes after raw command")
            entry = (cid, args, kwargs)
            _dec_cache_put(buf, entry)
        return entry
    except (IndexError, struct.error) as exc:
        raise ValueError(f"malformed raw command: {exc!r}") from None


def _dec_cache_put(body: bytes, entry: tuple) -> None:
    """Remember a decoded body iff sharing it is provably safe: tiny, no
    kwargs, and all-immutable-scalar args (a cached list/dict arg could
    be mutated by one executing command and observed by the next)."""
    if len(body) > _CACHEABLE_BODY or entry[2]:
        return
    for a in entry[1]:
        t = type(a)
        if not (t is str or t is bytes or t is int or t is float):
            return
    if len(_DEC_CACHE) >= _CACHE_MAX:
        _DEC_CACHE.clear()
    _DEC_CACHE[body] = entry


def decode_command(buf: Any) -> Tuple[str, tuple, dict]:
    """Name-based inverse of :func:`encode_command` (``execute_batch``
    entries are name-triples, mirroring the pickle request shape)."""
    cid, args, kwargs = decode_command_id(buf)
    if cid == RAW_EXEC_ID:
        entries = [(RAW_COMMANDS[ecid], ea, ek)
                   for ecid, ea, ek in args[0]]
        return "execute_batch", (entries,), {}
    return RAW_COMMANDS[cid], args, kwargs


#: Replies whose top-level container holds more than this many items
#: fall back to pickle even when raw-codable. Deliberate: a C
#: Unpickler decodes a big homogeneous result list (a 100-command batch
#: reply, a wide MGET) faster than any per-item Python loop, and that
#: decode runs on the CLIENT GIL — the exact bottleneck this codec
#: exists to relieve. Small replies (the per-command hot path) stay
#: raw, where the codec beats the Pickler's fixed per-call costs.
_RAW_REPLY_MAX_ITEMS = 8


def encode_reply(ok: bool, value: Any) -> Optional[bytes]:
    """Encode an ``(ok, value)`` response as a raw v4 body, or None when
    the value is outside the raw vocabulary (exceptions, large/OOB
    values, exotic types) or is a wide container (see
    ``_RAW_REPLY_MAX_ITEMS``) — the server then answers in pickle,
    flagged per frame, and the client decodes by flag."""
    t = type(value)
    if ((t is list or t is tuple or t is dict)
            and len(value) > _RAW_REPLY_MAX_ITEMS):
        return None
    out = bytearray()
    out.append(1 if ok else 0)
    try:
        _enc_value(out, value)
    except (_NotRaw, OverflowError, struct.error):
        return None
    return bytes(out)


def decode_reply(buf: Any) -> Tuple[bool, Any]:
    """Inverse of :func:`encode_reply`."""
    buf = bytes(buf)
    try:
        v, off = _dec_value(buf, 1)
        if off != len(buf):
            raise ValueError("trailing bytes after raw reply")
        return buf[0] == 1, v
    except (IndexError, struct.error) as exc:
        raise ValueError(f"malformed raw reply: {exc!r}") from None
