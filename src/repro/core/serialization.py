"""Closure-capable serialization (paper §3.1.1 step 2).

Lithops "automatically detects, serializes and uploads" the process
function, its arguments and referenced globals. Plain ``pickle`` only
serializes functions *by reference* (module + qualname), which fails for
lambdas, closures, and anything defined in ``__main__`` or interactively.

``dumps``/``loads`` here extend pickle with by-value function support à la
cloudpickle: dynamic functions are reduced to (marshaled code, referenced
globals, defaults, closure cells) and rebuilt on the worker. Only the
globals actually referenced by the code object (transitively, through
nested code constants) are captured — this is the paper's "detects ...
dependencies" step.
"""

from __future__ import annotations

import importlib
import io
import marshal
import pickle
import types
from typing import Any, Dict, Set

__all__ = ["dumps", "loads", "payload_size"]


def _is_importable(obj: Any) -> bool:
    """True if pickle-by-reference would round-trip this function/class."""
    module = getattr(obj, "__module__", None)
    qualname = getattr(obj, "__qualname__", None)
    if module is None or qualname is None or "<locals>" in qualname or "<lambda>" in qualname:
        return False
    try:
        mod = importlib.import_module(module)
    except Exception:
        return False
    found = mod
    for part in qualname.split("."):
        found = getattr(found, part, None)
        if found is None:
            return False
    return found is obj


def _referenced_globals(code: types.CodeType, globals_: Dict[str, Any],
                        seen: Set[str]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    names = set(code.co_names)
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            out.update(_referenced_globals(const, globals_, seen))
    for name in names:
        if name in seen or name not in globals_:
            continue
        seen.add(name)
        out[name] = globals_[name]
    return out


def _make_cell(value):
    def f():
        return value
    return f.__closure__[0]


def _make_empty_cell():
    def f():
        if False:
            value = None  # noqa: F841 - creates the cell

        def g():
            return value  # noqa: F821
        return g
    return f().__closure__[0]


def _rebuild_function(code_bytes, globals_dict, name, defaults, closure_values,
                      kwdefaults, qualname, module):
    code = marshal.loads(code_bytes)
    globals_dict = dict(globals_dict)
    globals_dict.setdefault("__builtins__", __builtins__)
    cells = tuple(
        _make_empty_cell() if v is _SENTINEL_EMPTY else _make_cell(v)
        for v in closure_values
    )
    fn = types.FunctionType(code, globals_dict, name, defaults, cells or None)
    fn.__kwdefaults__ = kwdefaults
    fn.__qualname__ = qualname
    fn.__module__ = module
    return fn


class _Sentinel:
    def __repr__(self):  # pragma: no cover
        return "<empty-cell>"


_SENTINEL_EMPTY = _Sentinel()


def _apply_function_state(fn, state):
    """Post-rebuild fixup: point self-referential closure cells at fn."""
    for i in state.get("self_cells", ()):
        fn.__closure__[i].cell_contents = fn
    return fn


def _rebuild_class(name, bases, dct, qualname, module):
    cls = type(name, bases, dct)
    cls.__qualname__ = qualname
    cls.__module__ = module
    return cls


class _Pickler(pickle.Pickler):
    def reducer_override(self, obj):
        if isinstance(obj, types.ModuleType):
            # modules captured in closures/globals: pickle by import name
            return (importlib.import_module, (obj.__name__,))
        if isinstance(obj, type) and not _is_importable(obj):
            # dynamic class (defined in a function / __main__): by value
            dct = {k: v for k, v in obj.__dict__.items()
                   if k not in ("__dict__", "__weakref__")}
            return (_rebuild_class, (obj.__name__, obj.__bases__, dct,
                                     obj.__qualname__, obj.__module__))
        if isinstance(obj, types.FunctionType) and not _is_importable(obj):
            return self._reduce_function(obj)
        return NotImplemented

    def _reduce_function(self, fn: types.FunctionType):
        code_bytes = marshal.dumps(fn.__code__)
        globals_dict = _referenced_globals(fn.__code__, fn.__globals__, set())
        # Avoid self-reference loops (recursive top-level functions).
        globals_dict = {k: v for k, v in globals_dict.items() if v is not fn}
        globals_dict.pop("__builtins__", None)
        closure_values = []
        self_cells = []
        if fn.__closure__:
            for i, cell in enumerate(fn.__closure__):
                try:
                    v = cell.cell_contents
                except ValueError:
                    v = _SENTINEL_EMPTY
                if v is fn:  # local recursion: patch after rebuild
                    self_cells.append(i)
                    v = _SENTINEL_EMPTY
                closure_values.append(v)
        return (
            _rebuild_function,
            (code_bytes, globals_dict, fn.__name__, fn.__defaults__,
             tuple(closure_values), fn.__kwdefaults__, fn.__qualname__,
             fn.__module__),
            {"self_cells": self_cells},
            None, None, _apply_function_state,
        )


def dumps(obj: Any, protocol: int = pickle.DEFAULT_PROTOCOL) -> bytes:
    buf = io.BytesIO()
    _Pickler(buf, protocol).dump(obj)
    return buf.getvalue()


def loads(data: bytes) -> Any:
    return pickle.loads(data)


def payload_size(obj: Any) -> int:
    """Serialized size — used by the latency model and benchmarks."""
    return len(dumps(obj))
