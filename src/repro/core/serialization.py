"""Closure-capable serialization (paper §3.1.1 step 2).

Lithops "automatically detects, serializes and uploads" the process
function, its arguments and referenced globals. Plain ``pickle`` only
serializes functions *by reference* (module + qualname), which fails for
lambdas, closures, and anything defined in ``__main__`` or interactively.

``dumps``/``loads`` here extend pickle with by-value function support à la
cloudpickle: dynamic functions are reduced to (marshaled code, referenced
globals, defaults, closure cells) and rebuilt on the worker. Only the
globals actually referenced by the code object (transitively, through
nested code constants) are captured — this is the paper's "detects ...
dependencies" step.

``dumps_oob``/``loads_oob`` add pickle protocol-5 *out-of-band* buffers
(PEP 574) for the remote hot path: numpy arrays and large ``bytes`` /
``bytearray`` payloads (the paper's ES / PPO parameter vectors, queue
blobs) are emitted as separate zero-copy buffers instead of being copied
into the pickle stream. The transport (``kvserver``) sends each buffer
as its own scatter-gather frame part, so a 1 MB payload crosses the wire
without a single sender-side copy.

``FRAME_TAG`` is the request-id tag of the v3 multiplexed wire dialect:
a fixed-width unsigned word prepended to a frame's part-length vector.
Many client threads share ONE socket per server; the tag is what lets
the server answer out of order (a parked BLPOP must not head-of-line
block the commands behind it) and lets the client-side I/O mux correlate
each response with the submitting thread's future. It lives here, next
to the payload encoding, because it is the one piece of framing state
that both ends must agree on byte-for-byte.
"""

from __future__ import annotations

import importlib
import io
import marshal
import pickle
import struct
import types
from typing import Any, Dict, List, Optional, Set, Tuple

__all__ = ["dumps", "loads", "dumps_oob", "loads_oob", "payload_size",
           "OOB_THRESHOLD", "FRAME_TAG", "MAX_FRAME_TAG"]

#: v3 frame tag: one network-order u32 request id per tagged frame. Ids
#: are per-connection and wrap at 2**32 — a connection never has 4
#: billion requests in flight, so a wrapped id can't collide with a live
#: one.
FRAME_TAG = struct.Struct("!I")
MAX_FRAME_TAG = 1 << 32

#: Payloads at least this large go out-of-band when a buffer callback is
#: active. Below it, the header/descriptor overhead outweighs the copy.
OOB_THRESHOLD = 4096


def _is_importable(obj: Any) -> bool:
    """True if pickle-by-reference would round-trip this function/class."""
    module = getattr(obj, "__module__", None)
    qualname = getattr(obj, "__qualname__", None)
    if module is None or qualname is None or "<locals>" in qualname or "<lambda>" in qualname:
        return False
    try:
        mod = importlib.import_module(module)
    except Exception:
        return False
    found = mod
    for part in qualname.split("."):
        found = getattr(found, part, None)
        if found is None:
            return False
    return found is obj


def _referenced_globals(code: types.CodeType, globals_: Dict[str, Any],
                        seen: Set[str]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    names = set(code.co_names)
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            out.update(_referenced_globals(const, globals_, seen))
    for name in names:
        if name in seen or name not in globals_:
            continue
        seen.add(name)
        out[name] = globals_[name]
    return out


def _make_cell(value):
    def f():
        return value
    return f.__closure__[0]


def _make_empty_cell():
    def f():
        if False:
            value = None  # noqa: F841 - creates the cell

        def g():
            return value  # noqa: F821
        return g
    return f().__closure__[0]


def _rebuild_function(code_bytes, globals_dict, name, defaults, closure_values,
                      kwdefaults, qualname, module):
    code = marshal.loads(code_bytes)
    globals_dict = dict(globals_dict)
    globals_dict.setdefault("__builtins__", __builtins__)
    cells = tuple(
        _make_empty_cell() if v is _SENTINEL_EMPTY else _make_cell(v)
        for v in closure_values
    )
    fn = types.FunctionType(code, globals_dict, name, defaults, cells or None)
    fn.__kwdefaults__ = kwdefaults
    fn.__qualname__ = qualname
    fn.__module__ = module
    return fn


class _Sentinel:
    def __repr__(self):  # pragma: no cover
        return "<empty-cell>"


_SENTINEL_EMPTY = _Sentinel()


def _apply_function_state(fn, state):
    """Post-rebuild fixup: point self-referential closure cells at fn."""
    for i in state.get("self_cells", ()):
        fn.__closure__[i].cell_contents = fn
    return fn


def _rebuild_class(name, bases, dct, qualname, module):
    cls = type(name, bases, dct)
    cls.__qualname__ = qualname
    cls.__module__ = module
    return cls


class _Pickler(pickle.Pickler):
    def reducer_override(self, obj):
        if isinstance(obj, types.ModuleType):
            # modules captured in closures/globals: pickle by import name
            return (importlib.import_module, (obj.__name__,))
        if isinstance(obj, type) and not _is_importable(obj):
            # dynamic class (defined in a function / __main__): by value
            dct = {k: v for k, v in obj.__dict__.items()
                   if k not in ("__dict__", "__weakref__")}
            return (_rebuild_class, (obj.__name__, obj.__bases__, dct,
                                     obj.__qualname__, obj.__module__))
        if isinstance(obj, types.FunctionType) and not _is_importable(obj):
            return self._reduce_function(obj)
        return NotImplemented

    def _reduce_function(self, fn: types.FunctionType):
        code_bytes = marshal.dumps(fn.__code__)
        globals_dict = _referenced_globals(fn.__code__, fn.__globals__, set())
        # Avoid self-reference loops (recursive top-level functions).
        globals_dict = {k: v for k, v in globals_dict.items() if v is not fn}
        globals_dict.pop("__builtins__", None)
        closure_values = []
        self_cells = []
        if fn.__closure__:
            for i, cell in enumerate(fn.__closure__):
                try:
                    v = cell.cell_contents
                except ValueError:
                    v = _SENTINEL_EMPTY
                if v is fn:  # local recursion: patch after rebuild
                    self_cells.append(i)
                    v = _SENTINEL_EMPTY
                closure_values.append(v)
        return (
            _rebuild_function,
            (code_bytes, globals_dict, fn.__name__, fn.__defaults__,
             tuple(closure_values), fn.__kwdefaults__, fn.__qualname__,
             fn.__module__),
            {"self_cells": self_cells},
            None, None, _apply_function_state,
        )


def dumps(obj: Any, protocol: int = pickle.HIGHEST_PROTOCOL) -> bytes:
    buf = io.BytesIO()
    _Pickler(buf, protocol).dump(obj)
    return buf.getvalue()


def loads(data: bytes) -> Any:
    return pickle.loads(data)


def dumps_oob(obj: Any) -> Tuple[bytes, List[memoryview]]:
    """Serialize with out-of-band buffers (PEP 574).

    Returns ``(payload, buffers)``: the pickle stream holds only
    descriptors for every large buffer (numpy arrays, big bytes), which
    are returned as raw zero-copy memoryviews into the original objects.
    Reverse with :func:`loads_oob`. The caller must keep ``obj`` alive
    until the buffers have been consumed (e.g. written to a socket).
    """
    buffers: List[pickle.PickleBuffer] = []
    buf = io.BytesIO()
    p = _Pickler(buf, pickle.HIGHEST_PROTOCOL, buffer_callback=buffers.append)
    p.dump(_wrap_oob(obj, _WRAP_DEPTH))
    return buf.getvalue(), [_flat(b) for b in buffers]


class _OOBBlob:
    """Stand-in that reduces a large bytes/bytearray to an out-of-band
    PickleBuffer. Needed because CPython's pickler never consults
    ``reducer_override`` for exact ``bytes``/``bytearray`` instances
    (they take the C fast path), so the detour must happen pre-pickle."""

    __slots__ = ("_pb", "_cls")

    def __init__(self, obj):
        self._pb = pickle.PickleBuffer(obj)
        self._cls = type(obj)

    def __reduce__(self):
        return (self._cls, (self._pb,))


#: How deep ``_wrap_oob`` descends. 6 covers the deepest hot-path shape:
#: ("execute_batch", ([(cmd, (key, blob), {}), ...],), {}).
_WRAP_DEPTH = 6


def _wrap_oob(obj: Any, depth: int) -> Any:
    # Pre-scan without allocating: the overwhelmingly common case (all-small
    # command batches) must not pay a deep rebuild of every container.
    if not _has_oob(obj, depth):
        return obj
    return _wrap(obj, depth)


def _has_oob(obj: Any, depth: int) -> bool:
    t = type(obj)
    if t in (bytes, bytearray):
        return len(obj) >= OOB_THRESHOLD
    if depth > 0:
        if t is tuple or t is list:
            return any(_has_oob(x, depth - 1) for x in obj)
        if t is dict:
            return any(_has_oob(v, depth - 1) for v in obj.values())
    return False


def _wrap(obj: Any, depth: int) -> Any:
    t = type(obj)
    if t in (bytes, bytearray) and len(obj) >= OOB_THRESHOLD:
        return _OOBBlob(obj)
    if depth > 0:
        if t is tuple:
            return tuple(_wrap(x, depth - 1) for x in obj)
        if t is list:
            return [_wrap(x, depth - 1) for x in obj]
        if t is dict:
            return {k: _wrap(v, depth - 1) for k, v in obj.items()}
    return obj


def _flat(b: pickle.PickleBuffer) -> memoryview:
    try:
        return b.raw()
    except BufferError:
        # Non-C-contiguous (e.g. Fortran-order arrays): flatten preserving
        # physical layout — one copy, still out-of-band on the wire.
        return memoryview(memoryview(b).tobytes(order="A"))


def loads_oob(payload: Any, buffers: Optional[List[Any]] = None) -> Any:
    """Inverse of :func:`dumps_oob`; accepts any buffer-likes (bytearray,
    memoryview) so the transport can hand over receive buffers directly."""
    return pickle.loads(payload, buffers=buffers or ())


def payload_size(obj: Any) -> int:
    """Serialized size — used by the latency model and benchmarks."""
    return len(dumps(obj))
