"""Unified client construction surface (PR 9 api_redesign satellite).

Every client entry point — :class:`~repro.core.kvserver.KVClient`,
:class:`~repro.core.kvcluster.ClusterClient`, and
:func:`~repro.core.kvcluster.connect` — historically grew its own
keyword spellings for the same five knobs (transport preference, raw
dialect, mux engine, legacy wire protocol, failover budget). They now
all consume ONE :class:`ClientOptions` value:

    opts = ClientOptions(transport="uds", raw=False)
    KVClient(addr, options=opts)
    ClusterClient(address=ctrl, options=opts)
    connect(addr, options=opts)

**Back-compat contract (deprecation policy).** The historical kwargs
(``legacy_protocol=``, ``mux=``, ``raw=``, ``transport=``,
``failover_timeout_s=``) remain supported indefinitely as *aliases*
that map onto the same resolved ``ClientOptions``: old spellings are
not deprecated-and-removed, they are redefined as sugar. Passing an
alias together with ``options`` is allowed when they agree (the alias
restates the option) and raises a clear ``ValueError`` when they
conflict — silent precedence between two explicit spellings is how
configuration bugs hide.

Resolution order for each knob:

1. an explicitly passed legacy kwarg (must agree with ``options`` if
   both are given);
2. the ``options`` value;
3. the ``ClientOptions`` field default.
"""

from __future__ import annotations

from dataclasses import dataclass, fields
from typing import Any, Dict, Optional

__all__ = ["ClientOptions", "UNSET", "resolve_client_options"]


class _Unset:
    """Sentinel distinguishing 'kwarg not passed' from any real value
    (``transport=None`` means auto-selection and must stay expressible)."""

    _instance: Optional["_Unset"] = None

    def __new__(cls) -> "_Unset":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "<UNSET>"

    def __bool__(self) -> bool:
        return False


UNSET = _Unset()


@dataclass(frozen=True)
class ClientOptions:
    """One value object naming every client construction knob.

    ``transport``
        ``None`` auto-selects the cheapest advertised carrier per
        connection (shm > uds > tcp); ``"tcp"``/``"uds"``/``"shm"``
        pins one for A/B runs (raises if the server does not advertise
        it).
    ``raw``
        Speak the v4 struct-packed binary dialect for the hot command
        vocabulary (default). ``False`` keeps pickle v3 for A/B.
    ``mux``
        Use the per-process multiplexed I/O engine (default). ``False``
        keeps the one-socket-per-thread PR 3 transport.
    ``legacy_protocol``
        Speak the seed's v1 wire dialect; implies ``mux=False`` and
        ``raw=False`` (the resolved options keep the user's values, the
        clients apply the implication exactly as they always did).
    ``failover_timeout_s``
        Retry budget for idempotent commands across a shard failover
        (``ClusterClient`` only; carried but unused by plain
        ``KVClient``).
    """

    transport: Optional[str] = None
    raw: bool = True
    mux: bool = True
    legacy_protocol: bool = False
    failover_timeout_s: float = 10.0

    def replace(self, **changes: Any) -> "ClientOptions":
        import dataclasses
        return dataclasses.replace(self, **changes)


#: Field names resolvable from legacy kwarg aliases.
_ALIAS_FIELDS = tuple(f.name for f in fields(ClientOptions))


def resolve_client_options(options: Optional[ClientOptions] = None,
                           **aliases: Any) -> ClientOptions:
    """Merge legacy kwarg ``aliases`` (value or :data:`UNSET`) with an
    explicit ``options`` object into one resolved :class:`ClientOptions`.

    A kwarg that was actually passed must agree with ``options`` when
    both are given — two explicit, conflicting spellings raise
    ``ValueError`` naming the knob and both values instead of silently
    picking one.
    """
    if options is not None and not isinstance(options, ClientOptions):
        raise TypeError(
            f"options must be a ClientOptions, got {type(options).__name__}")
    base = options if options is not None else ClientOptions()
    resolved: Dict[str, Any] = {}
    for name in _ALIAS_FIELDS:
        alias_val = aliases.pop(name, UNSET)
        if alias_val is UNSET:
            resolved[name] = getattr(base, name)
        elif options is not None and alias_val != getattr(options, name):
            raise ValueError(
                f"conflicting client options: {name}={alias_val!r} was "
                f"passed as a keyword but options.{name}="
                f"{getattr(options, name)!r}; pass one spelling (or make "
                f"them agree)")
        else:
            resolved[name] = alias_val
    if aliases:
        raise TypeError(
            f"unknown client option(s): {', '.join(sorted(aliases))}")
    return ClientOptions(**resolved)
