"""Pool with the serverless job-queue pattern (paper §3.1.2).

Workers are *long-lived functions* invoked once at pool creation. Pool
operations do not invoke new functions; they serialize the task function
once to storage, then submit every chunk with a single LPUSH to the
pool's KV job list. Workers BLPOP chunks, execute, and RPUSH results to
the pool's result list, which a collector thread in the parent drains.

Benefits reproduced from the paper: submit cost is one KV command for a
whole map (vs one FaaS invocation per task), warm function reuse kills
cold-start stragglers, and worker-scope state (``initializer``) is set up
once per worker. Drawback reproduced too: the FaaS execution time limit
bounds worker lifetime (see ``FunctionExecutor(time_limit_s=...)``).

Beyond-paper: ``resize()`` grows/shrinks the worker fleet at runtime —
the elasticity hook used by ``repro.runtime.elastic``.

Fault tolerance (PR 8)
----------------------

Serverless workers are *expected* to die mid-task. With the knobs below
the task plane is **at-least-once execution, exactly-once-visible
results**:

``max_retries`` (default 0 = off)
    Tasks hand off via the fused ``blpop_lease`` KV command instead of a
    bare ``blpop``: the chunk moves atomically from the job queue into a
    per-pool in-flight hash under a TTL lease. A worker that dies (or
    stalls past the TTL) has its lease reclaimed — by the pool
    supervisor immediately on detected death, by its periodic TTL sweep,
    or by a ``KVCluster(lease_sweep_s=...)`` server-side reaper if the
    pool's owner died too — and the chunk re-enqueues with a bumped
    attempt counter, up to ``max_retries`` re-runs. Beyond that the
    chunk dead-letters and its items settle as a typed
    :class:`~repro.core.errors.WorkerLostError` (task id, attempts,
    last worker) instead of hanging forever. Every settle is fenced by
    ``(field, attempt)``: a zombie worker's late result for a reclaimed
    task is discarded by the collector's settled-set, never
    double-delivered to ``AsyncResult``/``imap``.

``lease_ttl_s`` / ``heartbeat_s``
    Lease TTL and the worker renewal cadence (default ``ttl / 3``).
    Each worker also refreshes a per-worker heartbeat key carrying its
    PID; a missing heartbeat is how the supervisor detects dead
    subprocess workers (thread-backend deaths surface through the
    executor future as well).

``speculation_factor`` (default 0.0 = off)
    Straggler speculation: the supervisor tracks completed-chunk
    runtimes and re-enqueues a *speculative duplicate* of any chunk
    outstanding longer than ``speculation_factor x median``. Fencing
    makes the duplicate safe — first settle wins, the loser is
    discarded.

``respawn_budget``
    How many replacement workers the supervisor may spawn for dead ones
    (default ``2 x processes`` when fault tolerance is on, else 0).
    When no live worker remains, tasks are outstanding and the budget
    is spent, pending results fail with ``WorkerLostError`` rather than
    blocking forever — this detection also runs with fault tolerance
    OFF, closing the bare "all workers died -> ``get()`` hangs" hole.

**Cost when off** is zero: with ``max_retries=0`` and
``speculation_factor=0.0`` the worker loop, the submit path and the
result messages are byte-identical to the lease-less protocol — same KV
command count per task — and the supervisor thread performs no KV
operation.

**Side-effect caveat**: at-least-once execution means a non-idempotent
user function can run its side effects more than once even though its
*result* is delivered exactly once. Keep side-effecting tasks
idempotent, or leave fault tolerance off for them.
"""

from __future__ import annotations

import hashlib
import itertools
import math
import os
import statistics
import threading
import time
import traceback
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from . import serialization
from . import session as _session
from .errors import ProcessError, WorkerLostError
from .executor import FunctionExecutor
from .kvstore import LEASE_REGISTRY_KEY
from .reference import fresh_uid

__all__ = ["Pool", "AsyncResult", "MapResult", "ProcessError",
           "TimeoutError", "WorkerLostError"]


class TimeoutError(ProcessError):  # noqa: A001 - mirrors multiprocessing
    """Deliberately distinct from the builtin TimeoutError, exactly like
    ``multiprocessing.TimeoutError``: callers port ``except
    multiprocessing.TimeoutError`` unchanged, and a builtin-catching
    handler does not accidentally swallow pool timeouts."""

_POISON = b"__poison__"
_SUBMIT_RPUSH_ARITY = 64  # max chunks per RPUSH inside a submit pipeline

#: Speculative re-enqueues fence with attempts from this base so they can
#: never collide with (or be mistaken for) real retry attempts — and so a
#: speculative lease that itself expires dead-letters invisibly instead of
#: failing a task whose original attempt is still running.
_SPEC_ATTEMPT_BASE = 10 ** 6

#: Grace between an executor future settling and declaring the worker
#: dead: a clean exit's "worker_exit" message needs a beat to drain.
_DEAD_GRACE_S = 0.5

#: Grace after spawn before a missing heartbeat key means death — covers
#: subprocess startup (interpreter boot + store connect + first beat).
_HB_SPAWN_GRACE_S = 5.0


def default_parallelism() -> int:
    sess = _session.get_session()
    return int(sess.executor_defaults.get("default_parallelism", 0)) or 4


def _kill_flag_matches(value: Any, pool_uid: str) -> bool:
    """Generation-fenced kill flag: ``terminate`` writes the pool's uid,
    so a stale flag from a previous pool generation reusing the tag can
    never kill this generation's workers. Non-string truthy values keep
    the legacy kill-all meaning."""
    if value is None:
        return False
    if isinstance(value, (str, bytes)):
        val = value.decode() if isinstance(value, bytes) else value
        return val == pool_uid
    return bool(value)


def _chaos_actions(worker_id: int) -> set:
    """Parse ``REPRO_POOL_CHAOS`` (e.g. ``"die:1,3;zombie:2"``) into the
    set of fault actions scripted for this worker id. Used only by the
    chaos harness; the env var is unset in normal operation."""
    spec = os.environ.get("REPRO_POOL_CHAOS", "")
    acts = set()
    for part in spec.split(";"):
        if ":" not in part:
            continue
        name, ids = part.split(":", 1)
        try:
            if worker_id in {int(x) for x in ids.split(",") if x}:
                acts.add(name.strip())
        except ValueError:
            continue
    return acts


# ---------------------------------------------------------------------------
# The generic long-lived pool worker (runs inside a serverless function)
# ---------------------------------------------------------------------------


def _pool_worker(pool_tag: str, worker_id: int, init_key: Optional[str],
                 maxtasksperchild: Optional[int],
                 lease_cfg: Optional[Tuple[float, float]] = None,
                 drain_enabled: bool = False) -> None:
    sess = _session.get_session()
    store, storage = sess.store, sess.get_storage()
    job_key = f"{pool_tag}:jobs"
    result_key = f"{pool_tag}:results"
    kill_key = f"{pool_tag}:kill"
    inflight_key = f"{pool_tag}:inflight"
    drain_key = f"{pool_tag}:drain:{worker_id}"
    pool_uid = pool_tag[1:-1] if pool_tag.startswith("{") else pool_tag

    if init_key is not None:
        initializer, initargs = serialization.loads(storage.get(init_key))
        initializer(*initargs)

    # -- lease mode plumbing (no-ops when lease_cfg is None) ----------------
    ttl = hb_s = 0.0
    chaos: set = set()
    cur_lock = threading.Lock()
    cur_lease: List[Optional[Tuple[str, int]]] = [None]
    hb_stop = threading.Event()
    if lease_cfg is not None:
        ttl, hb_s = float(lease_cfg[0]), float(lease_cfg[1])
        chaos = _chaos_actions(worker_id)
        hb_key = f"{pool_tag}:hb:{worker_id}"
        hb_ex = max(2.5 * hb_s, 0.5)

        def _beat() -> None:
            try:
                store.set(hb_key, os.getpid(), ex=hb_ex)
                with cur_lock:
                    lease = cur_lease[0]
                if lease is not None:
                    store.lease_renew(inflight_key, lease[0], lease[1], ttl)
            except Exception:
                pass  # transient store failure: the next beat retries

        def _hb_loop() -> None:
            while not hb_stop.wait(hb_s):
                _beat()

        _beat()  # first heartbeat before any task, so spawn-grace is short
        threading.Thread(target=_hb_loop, daemon=True,
                         name=f"pool-hb-{worker_id}").start()

    func_cache: Dict[str, Callable] = {}
    chunks_done = 0
    exit_reason = "poison"
    try:
        while True:
            attempt, field_ = 0, None
            if drain_enabled and _kill_flag_matches(store.get(drain_key),
                                                    pool_uid):
                # graceful drain (PR 9): the flag is only ever checked
                # BETWEEN tasks — a drained worker finishes its current
                # lease, stops issuing blpop_lease, and exits cleanly.
                # The flag's value is the pool uid (generation fence),
                # so a stale flag from a prior pool generation is inert.
                exit_reason = "drained"
                break
            if lease_cfg is not None:
                got = store.blpop_lease(job_key, inflight_key, worker_id,
                                        ttl, timeout=0.25)
                if got is None:
                    if _kill_flag_matches(store.get(kill_key), pool_uid):
                        exit_reason = "killed"
                        break
                    continue
                if isinstance(got, (bytes, bytearray)) \
                        and bytes(got) == _POISON:
                    break
                blob = got
                if (isinstance(got, (tuple, list)) and len(got) == 3
                        and isinstance(got[0], int)):
                    attempt, field_, blob = got
                if field_ is not None and "die" in chaos:
                    # chaos: SIGKILL between lease-acquire and the first
                    # renewal — the task must come back via the reaper
                    import signal
                    chaos.discard("die")
                    os.kill(os.getpid(), signal.SIGKILL)
                if field_ is not None:
                    with cur_lock:
                        cur_lease[0] = (field_, attempt)
            else:
                got = store.blpop(job_key, timeout=0.25)
                if got is None:
                    if _kill_flag_matches(store.get(kill_key), pool_uid):
                        exit_reason = "killed"
                        break
                    continue
                if got[1] == _POISON:
                    break
                blob = got[1]
            job_id, chunk_idx, func_key, items = serialization.loads(blob)
            func = func_cache.get(func_key)
            if func is None:
                func = serialization.loads(storage.get(func_key))
                func_cache[func_key] = func
            results: List[Tuple[int, str, Any]] = []
            t0 = time.perf_counter()
            for item_idx, args, kwargs in items:
                try:
                    results.append((item_idx, "ok", func(*args, **kwargs)))
                except Exception as exc:
                    results.append((item_idx, "error",
                                    (f"{type(exc).__name__}: {exc}",
                                     traceback.format_exc())))
            run_s = time.perf_counter() - t0
            if field_ is not None and "zombie" in chaos:
                # chaos: model a worker suspended past its lease TTL that
                # resumes and tries a stale settle — renewals stop (a
                # suspended process beats nothing), the reaper reclaims,
                # and the late push below must be fenced/deduplicated
                chaos.discard("zombie")
                with cur_lock:
                    cur_lease[0] = None
                time.sleep(2.0 * ttl)
            if lease_cfg is not None:
                store.rpush(result_key, serialization.dumps(
                    ("chunk", job_id, chunk_idx, results, worker_id,
                     attempt, run_s)))
                if field_ is not None:
                    with cur_lock:
                        cur_lease[0] = None
                    store.lease_release(inflight_key, field_, attempt)
            else:
                store.rpush(result_key, serialization.dumps(
                    ("chunk", job_id, chunk_idx, results, worker_id)))
            chunks_done += 1
            if maxtasksperchild and chunks_done >= maxtasksperchild:
                exit_reason = "recycle"
                break
        store.rpush(result_key, serialization.dumps(
            ("worker_exit", worker_id, exit_reason)))
        if exit_reason == "drained":
            # release our marker keys AFTER the exit message is on the
            # wire: the supervisor skips draining workers in its
            # heartbeat sweep, so the early key deletion cannot be
            # mistaken for a death (and never burns respawn budget).
            try:
                store.delete(drain_key)
                if lease_cfg is not None:
                    store.delete(f"{pool_tag}:hb:{worker_id}")
            except Exception:
                pass
    finally:
        hb_stop.set()


# ---------------------------------------------------------------------------
# Async results
# ---------------------------------------------------------------------------


class AsyncResult:
    def __init__(self, n_items: int, callback=None, error_callback=None):
        self._n = n_items
        self._values: List[Any] = [None] * n_items
        self._got = 0
        self._first_error: Optional[Exception] = None
        self._event = threading.Event()
        self._callback = callback
        self._error_callback = error_callback
        self._lock = threading.Lock()

    def _deliver(self, item_idx: int, status: str, value: Any) -> None:
        from .executor import RemoteError
        with self._lock:
            if status == "ok":
                self._values[item_idx] = value
            elif status == "exc":  # value IS the exception (WorkerLostError)
                if self._first_error is None:
                    self._first_error = value
            elif self._first_error is None:
                self._first_error = RemoteError(value[0], value[1])
            self._got += 1
            done = self._got >= self._n
        if done:
            if self._first_error is not None and self._error_callback:
                try:
                    self._error_callback(self._first_error)
                except Exception:
                    pass
            elif self._first_error is None and self._callback:
                try:
                    self._callback(self._result_value())
                except Exception:
                    pass
            self._event.set()

    def _fail(self, exc: Exception) -> None:
        """Settle the whole result with ``exc`` (supervisor verdicts:
        all workers dead, pool torn down under a pending job)."""
        with self._lock:
            if self._event.is_set():
                return
            if self._first_error is None:
                self._first_error = exc
            self._got = self._n
        if self._error_callback:
            try:
                self._error_callback(self._first_error)
            except Exception:
                pass
        self._event.set()

    def _result_value(self):
        return self._values[0]

    def ready(self) -> bool:
        return self._event.is_set()

    def successful(self) -> bool:
        if not self.ready():
            raise ValueError("result is not ready")
        return self._first_error is None

    def wait(self, timeout: Optional[float] = None) -> None:
        self._event.wait(timeout)

    def get(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"pool result not ready after {timeout}s")
        if self._first_error is not None:
            raise self._first_error
        return self._result_value()


class MapResult(AsyncResult):
    def _result_value(self):
        return list(self._values)


# ---------------------------------------------------------------------------
# Pool
# ---------------------------------------------------------------------------


class _Chunk:
    """Client-side record of one submitted chunk (lease mode only): the
    item indices it covers (for dead-letter delivery), the serialized
    payload (for speculation) and the submit time (for straggler
    detection)."""

    __slots__ = ("item_idxs", "payload", "submit_t", "speculated")

    def __init__(self, item_idxs: List[int], payload: bytes):
        self.item_idxs = item_idxs
        self.payload = payload
        self.submit_t = time.monotonic()
        self.speculated = False


class _Job:
    __slots__ = ("result", "imap_buf", "settled", "chunks")

    def __init__(self, result: "AsyncResult",
                 imap_buf: Optional["_IMapBuffer"],
                 chunks: Optional[Dict[int, _Chunk]] = None):
        self.result = result
        self.imap_buf = imap_buf
        #: chunk indices already settled (lease mode): the exactly-once-
        #: visible gate — late zombie results and speculation losers for
        #: a settled chunk are discarded here. None when leases are off.
        self.settled: Optional[set] = set() if chunks is not None else None
        self.chunks = chunks


#: Sentinel distinguishing "caller did not pass this knob" from an
#: explicit value — the hinge of the pool_defaults merge: explicit
#: ``Pool(...)`` kwargs > ``session.pool_defaults`` > builtin default.
_UNSET = object()


class Pool:
    """``multiprocessing.Pool`` over serverless workers.

    Configuration layering (PR 9): every fault-tolerance/elasticity knob
    below resolves as **explicit kwarg > session.pool_defaults >
    builtin default**. Set fleet-wide policy once::

        configure(pool_defaults={"max_retries": 3, "elastic": True})

    and every subsequent ``Pool()`` picks it up; an explicit kwarg at
    any call site still wins. Legacy keyword spellings remain stable —
    no deprecation planned; new knobs are only ever added with inert
    defaults so that an un-configured ``Pool()`` stays byte-identical
    on the wire (see ``TestZeroCostWhenOff``).

    ``elastic`` selects the scaling mode:

    * ``None``/``False`` (default) — fixed fleet; ``resize()`` shrinks
      via poison pills; zero added KV traffic.
    * ``True`` — graceful-drain resize enabled: scale-down flags
      individual workers, which finish their current task, stop
      pulling work and exit cleanly (never killing a leased task,
      never burning ``respawn_budget``). No controller is started.
    * an :class:`~repro.runtime.elastic.ElasticPolicy` (or a dict of
      its fields) — drain-enabled resize **plus** an auto-started
      :class:`~repro.runtime.elastic.ElasticController` driving
      ``resize()`` from ``backlog()``; stopped by ``close()`` /
      ``terminate()``.
    """

    def __init__(self, processes: Optional[int] = None,
                 initializer: Optional[Callable] = None,
                 initargs: Sequence[Any] = (),
                 maxtasksperchild: Any = _UNSET,
                 context=None,  # accepted for API fidelity
                 session: Optional[_session.Session] = None,
                 max_retries: Any = _UNSET,
                 lease_ttl_s: Any = _UNSET,
                 heartbeat_s: Any = _UNSET,
                 speculation_factor: Any = _UNSET,
                 respawn_budget: Any = _UNSET,
                 elastic: Any = _UNSET):
        self.session = session or _session.get_session()
        _defaults = dict(getattr(self.session, "pool_defaults", None) or {})

        def _knob(name: str, explicit: Any, builtin: Any) -> Any:
            if explicit is not _UNSET:
                return explicit
            return _defaults.get(name, builtin)

        processes = processes or _defaults.get("processes") \
            or default_parallelism()
        maxtasksperchild = _knob("maxtasksperchild", maxtasksperchild, None)
        max_retries = _knob("max_retries", max_retries, 0)
        lease_ttl_s = _knob("lease_ttl_s", lease_ttl_s, 5.0)
        heartbeat_s = _knob("heartbeat_s", heartbeat_s, None)
        speculation_factor = _knob("speculation_factor",
                                   speculation_factor, 0.0)
        respawn_budget = _knob("respawn_budget", respawn_budget, None)
        elastic = _knob("elastic", elastic, None)
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if lease_ttl_s <= 0:
            raise ValueError("lease_ttl_s must be > 0")
        self._store = self.session.store
        self._storage = self.session.get_storage()
        self.uid = fresh_uid("pool")
        self._tag = "{" + self.uid + "}"
        self._n_workers_target = processes
        self._maxtasks = maxtasksperchild
        self._max_retries = int(max_retries)
        self._spec_factor = float(speculation_factor)
        self._ft = self._max_retries > 0 or self._spec_factor > 0
        self._hb_s = float(heartbeat_s) if heartbeat_s else lease_ttl_s / 3.0
        self._lease_cfg: Optional[Tuple[float, float]] = (
            (float(lease_ttl_s), self._hb_s) if self._ft else None)
        self._respawn_left = (respawn_budget if respawn_budget is not None
                              else (2 * self._n_workers_target
                                    if self._ft else 0))
        self._drain_enabled = bool(elastic)
        self._draining: set = set()  # wids flagged for graceful drain
        #: set by _submit_job: the ElasticController parks on this event
        #: instead of polling the KV plane when the pool is idle.
        self._activity = threading.Event()
        self._elastic_controller = None
        self._executor = FunctionExecutor(
            name=f"pool-{self.uid}", session=self.session,
            **{k: v for k, v in self.session.executor_defaults.items()
               if k in ("backend", "monitoring", "time_limit_s")})
        self._init_key: Optional[str] = None
        if initializer is not None:
            self._init_key = f"pool/{self.uid}/init"
            self._storage.put(self._init_key,
                              serialization.dumps((initializer, tuple(initargs))))
        self._job_seq = itertools.count()
        self._uploaded_funcs: set = set()  # payload hashes already stored
        self._jobs: Dict[int, _Job] = {}
        self._jobs_lock = threading.Lock()
        self._live_workers = 0
        self._worker_seq = itertools.count()
        self._workers: Dict[int, Any] = {}  # wid -> executor TaskFuture
        self._worker_spawn_t: Dict[int, float] = {}
        self._exited: set = set()        # clean worker_exit seen
        self._dead_handled: set = set()  # deaths already acted on
        self._dead_candidates: Dict[int, float] = {}
        self._runtimes: deque = deque(maxlen=256)
        self._spec_seq = itertools.count()
        self._all_dead_since: Optional[float] = None
        self._stats: Dict[str, int] = {
            "workers_lost": 0, "workers_respawned": 0,
            "leases_requeued": 0, "tasks_dead_lettered": 0,
            "duplicate_results_discarded": 0, "speculative_tasks": 0,
            "all_dead_failures": 0, "workers_drained": 0,
        }
        self._closed = False
        self._all_exited = threading.Event()
        self._all_exited.set()
        if self._ft:
            # register with any cluster-side reaper: if THIS process dies,
            # the sweep still reclaims our workers' orphaned leases
            try:
                self._store.hset(
                    LEASE_REGISTRY_KEY, self._inflight_key,
                    (self._job_key, self._max_retries, self._dead_key))
            except Exception:
                pass
        self._collector = threading.Thread(
            target=self._collect, daemon=True, name=f"pool-collector-{self.uid}")
        self._collector_stop = False
        self._collector.start()
        self._supervisor_stop = threading.Event()
        self._supervisor = threading.Thread(
            target=self._supervise, daemon=True,
            name=f"pool-supervisor-{self.uid}")
        self._supervisor.start()
        self._spawn_workers(self._n_workers_target)
        if elastic not in (None, False, True):
            # lazy import: repro.core must not import repro.runtime at
            # module load (layering), and plain pools must not pay for it
            from ..runtime.elastic import ElasticController, ElasticPolicy
            policy = (ElasticPolicy(**elastic) if isinstance(elastic, dict)
                      else elastic)
            if not isinstance(policy, ElasticPolicy):
                raise TypeError(
                    "elastic must be None/bool, an ElasticPolicy, or a "
                    f"dict of ElasticPolicy fields, not {type(elastic).__name__}")
            self._elastic_controller = ElasticController(self, policy)
            self._elastic_controller.start()

    # -- keys ---------------------------------------------------------------

    @property
    def _job_key(self) -> str:
        return f"{self._tag}:jobs"

    @property
    def _result_key(self) -> str:
        return f"{self._tag}:results"

    @property
    def _kill_key(self) -> str:
        return f"{self._tag}:kill"

    @property
    def _inflight_key(self) -> str:
        return f"{self._tag}:inflight"

    @property
    def _dead_key(self) -> str:
        return f"{self._tag}:dead"

    def _hb_key(self, wid: int) -> str:
        return f"{self._tag}:hb:{wid}"

    def _drain_key(self, wid: int) -> str:
        return f"{self._tag}:drain:{wid}"

    # -- workers --------------------------------------------------------------

    def _spawn_workers(self, n: int) -> None:
        for _ in range(n):
            wid = next(self._worker_seq)
            fut = self._executor.call_async(
                _pool_worker, (self._tag, wid, self._init_key, self._maxtasks,
                               self._lease_cfg, self._drain_enabled))
            with self._jobs_lock:
                self._workers[wid] = fut
                self._worker_spawn_t[wid] = time.monotonic()
                self._live_workers += 1
                self._all_exited.clear()

    def resize(self, n_workers: int) -> None:
        """Grow or shrink the worker fleet at runtime (beyond-paper; the
        actuator behind :class:`~repro.runtime.elastic.ElasticController`).

        Scale-up first cancels any not-yet-honored drain flags, then
        cold-spawns the remainder (with the warm-capable subprocess
        backend, the spawn re-attaches parked warm handlers first).
        Scale-down is **graceful** when the pool was built with
        ``elastic`` truthy: the highest-numbered live workers get a
        per-worker drain flag, finish their current task, stop pulling
        work and exit — a leased task is never killed and a drained
        exit never burns ``respawn_budget``. Without ``elastic`` the
        legacy poison-pill shrink is used (workers exit after their
        next queue pop), keeping the default wire profile unchanged.
        """
        if n_workers < 0:
            raise ValueError("n_workers must be >= 0")
        if self._closed:
            return  # teardown already poisoned the fleet
        cancel: List[int] = []
        victims: List[int] = []
        with self._jobs_lock:
            cur = self._live_workers - len(self._draining)
            if n_workers > cur and self._draining:
                # un-drain the newest flagged workers before spawning:
                # cheaper than a cold spawn, and the worker keeps its
                # warm caches. The collector covers the race where the
                # worker honored the flag before the delete landed.
                cancel = sorted(self._draining)[:n_workers - cur]
                for wid in cancel:
                    self._draining.discard(wid)
                cur += len(cancel)
            elif n_workers < cur and self._drain_enabled:
                victims = sorted(
                    (w for w in self._workers
                     if w not in self._draining and w not in self._exited
                     and w not in self._dead_handled),
                    reverse=True)[:cur - n_workers]
                self._draining.update(victims)
        if cancel:
            try:
                self._store.delete(*[self._drain_key(w) for w in cancel])
            except Exception:
                pass
        if n_workers > cur:
            self._spawn_workers(n_workers - cur)
        elif victims:
            pipe_factory = getattr(self._store, "pipeline", None)
            if pipe_factory is not None and len(victims) > 1:
                with pipe_factory() as pipe:
                    for wid in victims:
                        pipe.set(self._drain_key(wid), self.uid, ex=3600)
            else:
                for wid in victims:
                    self._store.set(self._drain_key(wid), self.uid, ex=3600)
        elif n_workers < cur:
            self._store.rpush(self._job_key, *([_POISON] * (cur - n_workers)))
        self._n_workers_target = n_workers

    @property
    def n_workers(self) -> int:
        """Number of currently live workers (public contract, PR 9).

        Counts every worker that has been spawned and has not yet
        exited or been declared dead — including workers currently
        draining. This is the value
        :class:`~repro.runtime.elastic.ElasticController` scales
        against; ``resize()`` targets ``n_workers - draining``."""
        with self._jobs_lock:
            return self._live_workers

    def backlog(self) -> int:
        """Outstanding work the fleet has not finished: queue depth
        plus in-flight tasks (public contract, PR 9 — the load signal
        :class:`~repro.runtime.elastic.ElasticController` consumes).

        Costs **zero KV commands when the pool is idle** (no registered
        jobs short-circuits to 0) and exactly one pipelined round trip
        otherwise: ``LLEN jobs`` + ``HLEN inflight`` in one flush (the
        pool's keys share a hash tag, so this holds on a cluster too).
        Without fault tolerance there is no in-flight hash; the queue
        depth alone is returned, so tasks currently executing are not
        counted — an acceptable undercount for scaling decisions."""
        with self._jobs_lock:
            if not self._jobs:
                return 0
        try:
            if self._lease_cfg is None:
                return int(self._store.llen(self._job_key))
            pipe_factory = getattr(self._store, "pipeline", None)
            if pipe_factory is None:
                return (int(self._store.llen(self._job_key))
                        + int(self._store.hlen(self._inflight_key)))
            try:
                pipe = pipe_factory(transactional=False)
            except TypeError:  # in-process stores: batch mode only
                pipe = pipe_factory()
            with pipe:
                q = pipe.llen(self._job_key)
                inflight = pipe.hlen(self._inflight_key)
            return int(q.get()) + int(inflight.get())
        except (ConnectionError, OSError):
            return 0  # store gone: report idle rather than explode

    def worker_pids(self) -> Dict[int, int]:
        """PIDs of live workers as advertised by their heartbeat keys
        (lease mode only — empty otherwise). With the subprocess backend
        these are real OS pids; the chaos harness SIGKILLs them."""
        if self._lease_cfg is None:
            return {}
        with self._jobs_lock:
            wids = [w for w in self._workers
                    if w not in self._exited and w not in self._dead_handled]
        if not wids:
            return {}
        try:
            vals = self._store.mget([self._hb_key(w) for w in wids])
        except Exception:
            return {}
        return {w: int(v) for w, v in zip(wids, vals) if v is not None}

    def fault_stats(self) -> Dict[str, int]:
        """Snapshot of the fault-tolerance/elasticity counters (all
        zero with FT off): workers lost/respawned/drained, leases
        requeued, tasks dead-lettered, duplicate results discarded by
        fencing, speculative re-enqueues, all-dead failures — plus the
        executor's cold-spawn vs warm-attach counts (PR 9: the
        invoker/handler backend re-attaches parked warm handlers on
        scale-up instead of cold-starting)."""
        with self._jobs_lock:
            out = dict(self._stats)
            out["live_workers"] = self._live_workers
            out["draining_workers"] = len(self._draining)
            out["respawn_budget_left"] = self._respawn_left
        try:
            exs = self._executor.stats_summary() or {}
        except Exception:
            exs = {}
        out["cold_starts"] = int(exs.get("cold_starts",
                                         exs.get("containers_created", 0)))
        out["warm_attaches"] = int(exs.get("warm_attaches", 0))
        return out

    # -- submission ------------------------------------------------------------

    def _upload_func(self, func: Callable) -> str:
        """Content-addressed function upload: the key is the hash of the
        serialized function, so repeated ``map()``/``map_async()`` of the
        same function (grid search's loop) upload it ONCE — later submits
        skip the ``storage.put`` entirely (local memo; cross-client
        reuse via ``storage.exists`` when the memo is cold). Workers
        already cache by ``func_key``, so the same key also means one
        download + deserialize per worker, ever — which, like a warm
        FaaS container (paper §3.1.2), makes by-value state the function
        captured persist across same-function jobs within a worker,
        exactly as it already persisted across chunks within one job."""
        blob = serialization.dumps(func)
        digest = hashlib.sha256(blob).hexdigest()[:24]
        key = f"pool/funcs/{digest}"
        if digest in self._uploaded_funcs:
            return key
        if not self._storage.exists(key):
            self._storage.put(key, blob)
        self._uploaded_funcs.add(digest)
        return key

    def _submit_job(self, func: Callable, items: List[Tuple[Tuple, Dict]],
                    chunksize: Optional[int], result: MapResult,
                    imap_buf: Optional["_IMapBuffer"] = None) -> None:
        if self._closed:
            raise ValueError("Pool not running")
        n = len(items)
        if n == 0:
            # Nothing to run: resolve immediately WITHOUT uploading the
            # function or registering the job — a registered job with no
            # chunks would sit in self._jobs forever (the collector only
            # prunes a job once its last result arrives).
            result._event.set()
            return
        job_id = next(self._job_seq)
        func_key = self._upload_func(func)
        if chunksize is None:
            chunksize = max(1, math.ceil(n / (self._n_workers_target * 4)))
        chunks: List[Any] = []
        chunk_meta: Optional[Dict[int, _Chunk]] = {} if self._ft else None
        for c_idx, start in enumerate(range(0, n, chunksize)):
            chunk_items = [(start + j, args, kwargs)
                           for j, (args, kwargs) in
                           enumerate(items[start:start + chunksize])]
            blob = serialization.dumps((job_id, c_idx, func_key, chunk_items))
            if chunk_meta is None:
                chunks.append(blob)
            else:
                # lease-mode queue entry: (attempt, field, payload), the
                # shape blpop_lease indexes the in-flight hash by
                chunks.append((0, f"j{job_id}.{c_idx}", blob))
                chunk_meta[c_idx] = _Chunk([ci[0] for ci in chunk_items],
                                           blob)
        with self._jobs_lock:
            self._jobs[job_id] = _Job(result, imap_buf, chunk_meta)
        self._activity.set()  # wake a parked ElasticController, if any
        # One flush submits the whole job (the paper's key optimization).
        # Large jobs split into capped-arity RPUSHes inside one pipeline
        # flush: over TCP the multi-frame mode bounds how much of the job
        # a single wire frame materializes (responses drain between
        # buffer-bounded chunks); on in-process stores the batch still
        # runs under a single lock acquisition.
        pipe_factory = getattr(self._store, "pipeline", None)
        if pipe_factory is not None and len(chunks) > _SUBMIT_RPUSH_ARITY:
            try:
                pipe = pipe_factory(transactional=False)
            except TypeError:  # in-process stores: batch mode only
                pipe = pipe_factory()
            with pipe:
                for i in range(0, len(chunks), _SUBMIT_RPUSH_ARITY):
                    pipe.rpush(self._job_key,
                               *chunks[i:i + _SUBMIT_RPUSH_ARITY])
        else:
            self._store.rpush(self._job_key, *chunks)

    # -- public API -------------------------------------------------------------

    def apply_async(self, func: Callable, args: Sequence[Any] = (),
                    kwds: Optional[Dict] = None, callback=None,
                    error_callback=None) -> AsyncResult:
        res = AsyncResult(1, callback, error_callback)
        self._submit_job(func, [(tuple(args), dict(kwds or {}))], 1, res)
        return res

    def apply(self, func: Callable, args: Sequence[Any] = (),
              kwds: Optional[Dict] = None):
        return self.apply_async(func, args, kwds).get()

    def map_async(self, func: Callable, iterable: Iterable[Any],
                  chunksize: Optional[int] = None, callback=None,
                  error_callback=None) -> MapResult:
        items = [((x,), {}) for x in iterable]
        res = MapResult(len(items), callback, error_callback)
        self._submit_job(func, items, chunksize, res)
        return res

    def map(self, func: Callable, iterable: Iterable[Any],
            chunksize: Optional[int] = None) -> List[Any]:
        return self.map_async(func, iterable, chunksize).get()

    def starmap_async(self, func: Callable, iterable: Iterable[Sequence[Any]],
                      chunksize: Optional[int] = None, callback=None,
                      error_callback=None) -> MapResult:
        items = [(tuple(x), {}) for x in iterable]
        res = MapResult(len(items), callback, error_callback)
        self._submit_job(func, items, chunksize, res)
        return res

    def starmap(self, func: Callable, iterable: Iterable[Sequence[Any]],
                chunksize: Optional[int] = None) -> List[Any]:
        return self.starmap_async(func, iterable, chunksize).get()

    def imap(self, func: Callable, iterable: Iterable[Any],
             chunksize: int = 1):
        return self._imap(func, iterable, chunksize, ordered=True)

    def imap_unordered(self, func: Callable, iterable: Iterable[Any],
                       chunksize: int = 1):
        return self._imap(func, iterable, chunksize, ordered=False)

    def _imap(self, func, iterable, chunksize, ordered: bool):
        items = [((x,), {}) for x in iterable]
        res = MapResult(len(items))
        buf = _IMapBuffer(len(items), ordered)
        self._submit_job(func, items, chunksize, res, imap_buf=buf)
        return buf.__iter__()

    # -- lifecycle -----------------------------------------------------------------

    def _stop_elastic(self) -> None:
        ctl = self._elastic_controller
        if ctl is not None:
            self._elastic_controller = None
            try:
                ctl.stop()
            except Exception:
                pass

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._stop_elastic()
        with self._jobs_lock:
            # draining workers exit via their flag (checked before every
            # queue pop) and never consume a pill — poison only the rest
            n = self._live_workers - len(self._draining)
        if n > 0:
            self._store.rpush(self._job_key, *([_POISON] * n))

    def terminate(self) -> None:
        self._closed = True
        self._stop_elastic()
        with self._jobs_lock:
            n = self._live_workers - len(self._draining)
        pipe_factory = getattr(self._store, "pipeline", None)
        if pipe_factory is not None:
            # kill flag + queue flush + poison pills: one round trip.
            # The flag's VALUE is this pool's uid (generation fence):
            # workers only honor their own generation's flag, so the
            # ex=3600 window can never kill a later pool's workers.
            with pipe_factory() as pipe:
                pipe.set(self._kill_key, self.uid, ex=3600)
                pipe.delete(self._job_key)
                if n:
                    pipe.rpush(self._job_key, *([_POISON] * n))
            return
        self._store.set(self._kill_key, self.uid, ex=3600)
        self._store.delete(self._job_key)
        if n:
            self._store.rpush(self._job_key, *([_POISON] * n))

    def join(self, timeout: Optional[float] = None) -> None:
        if not self._closed:
            raise ValueError("Pool is still running; call close() first")
        self._all_exited.wait(timeout)
        self._collector_stop = True
        self._supervisor_stop.set()
        if self._ft:
            try:
                self._store.hdel(LEASE_REGISTRY_KEY, self._inflight_key)
                self._store.delete(self._inflight_key, self._dead_key)
            except Exception:
                pass
        self._store.rpush(self._result_key, serialization.dumps(("stop",)))
        self._executor.shutdown(wait=False)
        # reap the collector so teardown (e.g. the session's store being
        # closed right after join) can't race its parked blpop
        self._collector.join(timeout=5)

    def __enter__(self) -> "Pool":
        return self

    def __exit__(self, *exc) -> None:
        self.terminate()
        self.join(timeout=10)

    def __del__(self):
        try:
            if not self._closed:
                self.terminate()
        except Exception:
            pass

    # -- result collection ------------------------------------------------------

    def _collect(self) -> None:
        while True:
            try:
                got = self._store.blpop(self._result_key, timeout=0.5)
            except (ConnectionError, OSError) as exc:
                # store connection closed under us (session teardown /
                # server gone): no result can arrive anymore. Fail what
                # is still pending so waiters unblock with the cause.
                with self._jobs_lock:
                    jobs = list(self._jobs.values())
                    self._jobs.clear()
                err = ProcessError(
                    f"kv store connection lost while collecting pool "
                    f"results: {type(exc).__name__}: {exc}")
                for job in jobs:
                    job.result._fail(err)
                    if job.imap_buf is not None:
                        job.imap_buf.fail(err)
                return
            if got is None:
                if self._collector_stop:
                    return
                continue
            msg = serialization.loads(got[1])
            kind = msg[0]
            if kind == "stop":
                return
            if kind == "worker_exit":
                _, wid, reason = msg
                with self._jobs_lock:
                    self._exited.add(wid)
                    self._workers.pop(wid, None)
                    self._dead_candidates.pop(wid, None)
                    self._live_workers -= 1
                    if self._live_workers <= 0:
                        self._all_exited.set()
                    deficit = False
                    if reason == "drained":
                        # clean scale-down exit: NOT a death — no lost
                        # counter, no respawn budget spent. If a resize
                        # cancelled this drain after the worker already
                        # honored the flag, live has dipped below target:
                        # spawn one replacement to converge.
                        self._draining.discard(wid)
                        self._stats["workers_drained"] += 1
                        deficit = (not self._closed
                                   and self._live_workers
                                   < self._n_workers_target)
                if reason == "recycle" and not self._closed:
                    self._spawn_workers(1)  # maxtasksperchild replacement
                elif deficit:
                    self._spawn_workers(1)
                continue
            if len(msg) >= 7:  # lease-mode chunk: + (attempt, run_s)
                _, job_id, c_idx, results, _wid, _attempt, run_s = msg[:7]
            else:
                _, job_id, c_idx, results, _wid = msg
                run_s = None
            with self._jobs_lock:
                job = self._jobs.get(job_id)
                if job is not None and job.settled is not None:
                    if c_idx in job.settled:
                        # fenced duplicate: a zombie's late settle or a
                        # speculation loser — already delivered once
                        self._stats["duplicate_results_discarded"] += 1
                        continue
                    job.settled.add(c_idx)
                elif job is None and run_s is not None:
                    # lease-mode settle for a job already pruned (fully
                    # delivered): a zombie that outslept the whole job
                    self._stats["duplicate_results_discarded"] += 1
                if run_s is not None:
                    self._runtimes.append(run_s)
            if job is None:
                continue
            for item_idx, status, value in results:
                job.result._deliver(item_idx, status, value)
                if job.imap_buf is not None:
                    job.imap_buf.deliver(item_idx, status, value)
            if job.result.ready():
                with self._jobs_lock:
                    self._jobs.pop(job_id, None)

    # -- supervision ------------------------------------------------------------

    def _supervise(self) -> None:
        """Supervisor loop: dead-worker detection + respawn (all modes,
        in-process signals only when FT is off), lease reaping,
        dead-letter delivery, straggler speculation, and the all-dead
        failsafe. Interval tracks the heartbeat cadence in lease mode."""
        interval = (max(0.05, min(0.25, self._hb_s)) if self._ft else 0.25)
        while not self._supervisor_stop.wait(interval):
            try:
                self._supervise_once()
            except Exception:
                pass  # a supervision pass must never kill the thread

    def _supervise_once(self) -> None:
        now = time.monotonic()
        with self._jobs_lock:
            snapshot = [(wid, fut) for wid, fut in self._workers.items()
                        if wid not in self._exited
                        and wid not in self._dead_handled]
            draining = set(self._draining)
        # 1. executor-future deaths (thread backend: worker body raised)
        for wid, fut in snapshot:
            if fut is not None and fut.done():
                t0 = self._dead_candidates.setdefault(wid, now)
                if now - t0 >= _DEAD_GRACE_S:
                    self._on_worker_death(wid)
            else:
                self._dead_candidates.pop(wid, None)
        # 2. missing heartbeats (lease mode: catches SIGKILLed subprocesses).
        #    Draining workers are exempt: they delete their own heartbeat
        #    key on a clean drained exit, which must never read as death
        #    (real deaths of draining workers still surface via check 1
        #    and their leases via the periodic reap below).
        if self._lease_cfg is not None and snapshot:
            wids = [wid for wid, _ in snapshot
                    if wid not in self._dead_handled and wid not in draining
                    and now - self._worker_spawn_t.get(wid, now)
                    > _HB_SPAWN_GRACE_S]
            if wids:
                try:
                    vals = self._store.mget([self._hb_key(w) for w in wids])
                except Exception:
                    vals = None
                if vals is not None:
                    for wid, val in zip(wids, vals):
                        if val is None:
                            self._on_worker_death(wid)
        # 3. periodic TTL reap + dead-letter delivery (lease mode)
        if self._lease_cfg is not None:
            try:
                requeued, _dead = self._store.lease_reap(
                    self._inflight_key, self._job_key, self._max_retries,
                    None, self._dead_key)
                if requeued:
                    with self._jobs_lock:
                        self._stats["leases_requeued"] += len(requeued)
            except Exception:
                pass
            self._drain_dead_letters()
            if self._spec_factor > 0:
                self._speculate(now)
        # 4. all-dead failsafe (runs in every mode)
        self._check_all_dead(now)

    def _on_worker_death(self, wid: int) -> None:
        with self._jobs_lock:
            if wid in self._exited or wid in self._dead_handled:
                return
            self._dead_handled.add(wid)
            was_draining = wid in self._draining
            self._draining.discard(wid)
            self._workers.pop(wid, None)
            self._dead_candidates.pop(wid, None)
            self._live_workers -= 1
            if self._live_workers <= 0:
                self._all_exited.set()
            self._stats["workers_lost"] += 1
            # a worker that died while draining was leaving anyway:
            # reclaim its lease below, but don't respawn past the
            # already-reduced target (and don't spend budget on it)
            respawn = (not self._closed and self._respawn_left > 0
                       and not was_draining)
            if respawn:
                self._respawn_left -= 1
        if self._lease_cfg is not None:
            # reclaim the corpse's leases NOW instead of waiting for TTL
            try:
                requeued, _dead = self._store.lease_reap(
                    self._inflight_key, self._job_key, self._max_retries,
                    wid, self._dead_key)
                self._store.delete(self._hb_key(wid))
                if requeued:
                    with self._jobs_lock:
                        self._stats["leases_requeued"] += len(requeued)
            except Exception:
                pass
        if respawn:
            self._spawn_workers(1)
            with self._jobs_lock:
                self._stats["workers_respawned"] += 1

    def _drain_dead_letters(self) -> None:
        while True:
            try:
                got = self._store.blpop(self._dead_key, timeout=0)
            except Exception:
                return
            if got is None:
                return
            try:
                field_, attempt, holder, _payload = got[1]
            except (TypeError, ValueError):
                continue
            if attempt >= _SPEC_ATTEMPT_BASE:
                continue  # an expired speculative duplicate is not a failure
            self._deliver_dead(str(field_), int(attempt), holder)

    def _deliver_dead(self, field_: str, attempt: int, holder: Any) -> None:
        try:
            job_part, c_part = field_.split(".", 1)
            job_id, c_idx = int(job_part[1:]), int(c_part)
        except (ValueError, IndexError):
            return
        with self._jobs_lock:
            job = self._jobs.get(job_id)
            if job is None or job.settled is None or c_idx in job.settled:
                return
            job.settled.add(c_idx)
            chunk = (job.chunks or {}).get(c_idx)
            self._stats["tasks_dead_lettered"] += (
                len(chunk.item_idxs) if chunk else 1)
        exc = WorkerLostError(
            f"task {field_} lost its worker on every attempt "
            f"({attempt + 1} attempts, max_retries={self._max_retries})",
            task_id=field_, attempts=attempt + 1, last_worker=holder)
        if chunk is not None:
            for item_idx in chunk.item_idxs:
                job.result._deliver(item_idx, "exc", exc)
                if job.imap_buf is not None:
                    job.imap_buf.deliver(item_idx, "exc", exc)
        else:
            job.result._fail(exc)
            if job.imap_buf is not None:
                job.imap_buf.fail(exc)
        if job.result.ready():
            with self._jobs_lock:
                self._jobs.pop(job_id, None)

    def _speculate(self, now: float) -> None:
        """Re-enqueue a speculative duplicate of chunks outstanding
        longer than ``speculation_factor x median`` completed-chunk
        runtime (client-observed: queue wait counts as straggling too).
        At most one speculation per chunk; fencing + the settled-set
        make whichever copy finishes second invisible."""
        if len(self._runtimes) < 3:
            return
        med = statistics.median(self._runtimes)
        if med <= 0:
            return
        # floor: with microsecond medians every queued chunk would look
        # like a straggler and the whole backlog would double-submit
        threshold = max(self._spec_factor * med, 0.05)
        cands: List[Tuple[int, int, bytes]] = []
        with self._jobs_lock:
            for job_id, job in self._jobs.items():
                if job.chunks is None:
                    continue
                for c_idx, ch in job.chunks.items():
                    if (ch.speculated or c_idx in job.settled
                            or now - ch.submit_t <= threshold):
                        continue
                    ch.speculated = True
                    cands.append((job_id, c_idx, ch.payload))
        for job_id, c_idx, payload in cands:
            attempt = _SPEC_ATTEMPT_BASE + next(self._spec_seq)
            try:
                self._store.rpush(self._job_key,
                                  (attempt, f"j{job_id}.{c_idx}", payload))
                with self._jobs_lock:
                    self._stats["speculative_tasks"] += 1
            except Exception:
                pass

    def _check_all_dead(self, now: float) -> None:
        """No live worker + outstanding tasks + no respawn left: fail
        pending results with ``WorkerLostError`` instead of letting
        ``get(timeout=None)``/``join`` park forever. Requires the
        condition to hold for two passes with an EMPTY result list so
        results still in flight are never spuriously failed."""
        with self._jobs_lock:
            live = self._live_workers
            can_respawn = not self._closed and self._respawn_left > 0
            pending = [j for j in self._jobs.values() if not j.result.ready()]
        if live > 0 or not pending or can_respawn:
            self._all_dead_since = None
            return
        try:
            backlog = self._store.llen(self._result_key)
        except Exception:
            backlog = 1  # can't tell -> don't fail anything yet
        if backlog:
            self._all_dead_since = None
            return
        if self._all_dead_since is None:
            self._all_dead_since = now
            return
        if now - self._all_dead_since < 2 * _DEAD_GRACE_S:
            return
        exc = WorkerLostError(
            "all pool workers died with tasks outstanding "
            "(respawn budget exhausted)", attempts=0)
        with self._jobs_lock:
            jobs = list(self._jobs.values())
            self._jobs.clear()
            self._stats["all_dead_failures"] += 1
        for job in jobs:
            job.result._fail(exc)
            if job.imap_buf is not None:
                job.imap_buf.fail(exc)
        self._all_dead_since = None


class _IMapBuffer:
    """Feeds imap/imap_unordered generators as chunks arrive."""

    def __init__(self, n: int, ordered: bool):
        self._n = n
        self._ordered = ordered
        self._ready: Dict[int, Tuple[str, Any]] = {}
        self._arrival: List[Tuple[int, str, Any]] = []
        self._error: Optional[Exception] = None
        self._cond = threading.Condition()

    def deliver(self, idx: int, status: str, value: Any) -> None:
        with self._cond:
            self._ready[idx] = (status, value)
            self._arrival.append((idx, status, value))
            self._cond.notify_all()

    def fail(self, exc: Exception) -> None:
        """Abort the iteration: consumers raise ``exc`` instead of
        waiting forever on items that can no longer arrive."""
        with self._cond:
            self._error = exc
            self._cond.notify_all()

    def __iter__(self):
        from .executor import RemoteError
        if self._ordered:
            for i in range(self._n):
                with self._cond:
                    while i not in self._ready:
                        if self._error is not None:
                            raise self._error
                        self._cond.wait()
                    status, value = self._ready[i]
                if status == "exc":
                    raise value
                if status != "ok":
                    raise RemoteError(value[0], value[1])
                yield value
        else:
            for i in range(self._n):
                with self._cond:
                    while len(self._arrival) <= i:
                        if self._error is not None:
                            raise self._error
                        self._cond.wait()
                    _, status, value = self._arrival[i]
                if status == "exc":
                    raise value
                if status != "ok":
                    raise RemoteError(value[0], value[1])
                yield value
