"""Pool with the serverless job-queue pattern (paper §3.1.2).

Workers are *long-lived functions* invoked once at pool creation. Pool
operations do not invoke new functions; they serialize the task function
once to storage, then submit every chunk with a single LPUSH to the
pool's KV job list. Workers BLPOP chunks, execute, and RPUSH results to
the pool's result list, which a collector thread in the parent drains.

Benefits reproduced from the paper: submit cost is one KV command for a
whole map (vs one FaaS invocation per task), warm function reuse kills
cold-start stragglers, and worker-scope state (``initializer``) is set up
once per worker. Drawback reproduced too: the FaaS execution time limit
bounds worker lifetime (see ``FunctionExecutor(time_limit_s=...)``).

Beyond-paper: ``resize()`` grows/shrinks the worker fleet at runtime —
the elasticity hook used by ``repro.runtime.elastic``.
"""

from __future__ import annotations

import hashlib
import itertools
import math
import threading
import time
import traceback
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from . import serialization
from . import session as _session
from .executor import FunctionExecutor
from .reference import fresh_uid

__all__ = ["Pool", "AsyncResult", "MapResult", "ProcessError", "TimeoutError"]


class ProcessError(Exception):
    """Base of repro.core.mp exceptions (multiprocessing.ProcessError)."""


class TimeoutError(ProcessError):  # noqa: A001 - mirrors multiprocessing
    """Deliberately distinct from the builtin TimeoutError, exactly like
    ``multiprocessing.TimeoutError``: callers port ``except
    multiprocessing.TimeoutError`` unchanged, and a builtin-catching
    handler does not accidentally swallow pool timeouts."""

_POISON = b"__poison__"
_SUBMIT_RPUSH_ARITY = 64  # max chunks per RPUSH inside a submit pipeline


def default_parallelism() -> int:
    sess = _session.get_session()
    return int(sess.executor_defaults.get("default_parallelism", 0)) or 4


# ---------------------------------------------------------------------------
# The generic long-lived pool worker (runs inside a serverless function)
# ---------------------------------------------------------------------------


def _pool_worker(pool_tag: str, worker_id: int, init_key: Optional[str],
                 maxtasksperchild: Optional[int]) -> None:
    sess = _session.get_session()
    store, storage = sess.store, sess.get_storage()
    job_key = f"{pool_tag}:jobs"
    result_key = f"{pool_tag}:results"
    kill_key = f"{pool_tag}:kill"

    if init_key is not None:
        initializer, initargs = serialization.loads(storage.get(init_key))
        initializer(*initargs)

    func_cache: Dict[str, Callable] = {}
    chunks_done = 0
    exit_reason = "poison"
    while True:
        got = store.blpop(job_key, timeout=0.25)
        if got is None:
            if store.get(kill_key):
                exit_reason = "killed"
                break
            continue
        if got[1] == _POISON:
            break
        job_id, chunk_idx, func_key, items = serialization.loads(got[1])
        func = func_cache.get(func_key)
        if func is None:
            func = serialization.loads(storage.get(func_key))
            func_cache[func_key] = func
        results: List[Tuple[int, str, Any]] = []
        for item_idx, args, kwargs in items:
            try:
                results.append((item_idx, "ok", func(*args, **kwargs)))
            except Exception as exc:
                results.append((item_idx, "error",
                                (f"{type(exc).__name__}: {exc}",
                                 traceback.format_exc())))
        store.rpush(result_key, serialization.dumps(
            ("chunk", job_id, chunk_idx, results, worker_id)))
        chunks_done += 1
        if maxtasksperchild and chunks_done >= maxtasksperchild:
            exit_reason = "recycle"
            break
    store.rpush(result_key, serialization.dumps(
        ("worker_exit", worker_id, exit_reason)))


# ---------------------------------------------------------------------------
# Async results
# ---------------------------------------------------------------------------


class AsyncResult:
    def __init__(self, n_items: int, callback=None, error_callback=None):
        self._n = n_items
        self._values: List[Any] = [None] * n_items
        self._got = 0
        self._first_error: Optional[Exception] = None
        self._event = threading.Event()
        self._callback = callback
        self._error_callback = error_callback
        self._lock = threading.Lock()

    def _deliver(self, item_idx: int, status: str, value: Any) -> None:
        from .executor import RemoteError
        with self._lock:
            if status == "ok":
                self._values[item_idx] = value
            elif self._first_error is None:
                self._first_error = RemoteError(value[0], value[1])
            self._got += 1
            done = self._got >= self._n
        if done:
            if self._first_error is not None and self._error_callback:
                try:
                    self._error_callback(self._first_error)
                except Exception:
                    pass
            elif self._first_error is None and self._callback:
                try:
                    self._callback(self._result_value())
                except Exception:
                    pass
            self._event.set()

    def _result_value(self):
        return self._values[0]

    def ready(self) -> bool:
        return self._event.is_set()

    def successful(self) -> bool:
        if not self.ready():
            raise ValueError("result is not ready")
        return self._first_error is None

    def wait(self, timeout: Optional[float] = None) -> None:
        self._event.wait(timeout)

    def get(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"pool result not ready after {timeout}s")
        if self._first_error is not None:
            raise self._first_error
        return self._result_value()


class MapResult(AsyncResult):
    def _result_value(self):
        return list(self._values)


# ---------------------------------------------------------------------------
# Pool
# ---------------------------------------------------------------------------


class Pool:
    def __init__(self, processes: Optional[int] = None,
                 initializer: Optional[Callable] = None,
                 initargs: Sequence[Any] = (),
                 maxtasksperchild: Optional[int] = None,
                 context=None,  # accepted for API fidelity
                 session: Optional[_session.Session] = None):
        self.session = session or _session.get_session()
        self._store = self.session.store
        self._storage = self.session.get_storage()
        self.uid = fresh_uid("pool")
        self._tag = "{" + self.uid + "}"
        self._n_workers_target = processes or default_parallelism()
        self._maxtasks = maxtasksperchild
        self._executor = FunctionExecutor(
            name=f"pool-{self.uid}", session=self.session,
            **{k: v for k, v in self.session.executor_defaults.items()
               if k in ("backend", "monitoring", "time_limit_s")})
        self._init_key: Optional[str] = None
        if initializer is not None:
            self._init_key = f"pool/{self.uid}/init"
            self._storage.put(self._init_key,
                              serialization.dumps((initializer, tuple(initargs))))
        self._job_seq = itertools.count()
        self._uploaded_funcs: set = set()  # payload hashes already stored
        self._jobs: Dict[int, Tuple[MapResult, Optional["_IMapBuffer"]]] = {}
        self._jobs_lock = threading.Lock()
        self._live_workers = 0
        self._worker_seq = itertools.count()
        self._closed = False
        self._all_exited = threading.Event()
        self._all_exited.set()
        self._collector = threading.Thread(
            target=self._collect, daemon=True, name=f"pool-collector-{self.uid}")
        self._collector_stop = False
        self._collector.start()
        self._spawn_workers(self._n_workers_target)

    # -- keys ---------------------------------------------------------------

    @property
    def _job_key(self) -> str:
        return f"{self._tag}:jobs"

    @property
    def _result_key(self) -> str:
        return f"{self._tag}:results"

    @property
    def _kill_key(self) -> str:
        return f"{self._tag}:kill"

    # -- workers --------------------------------------------------------------

    def _spawn_workers(self, n: int) -> None:
        for _ in range(n):
            wid = next(self._worker_seq)
            self._executor.call_async(
                _pool_worker, (self._tag, wid, self._init_key, self._maxtasks))
            with self._jobs_lock:
                self._live_workers += 1
                self._all_exited.clear()

    def resize(self, n_workers: int) -> None:
        """Elastically grow or shrink the worker fleet (beyond-paper)."""
        with self._jobs_lock:
            cur = self._live_workers
        if n_workers > cur:
            self._spawn_workers(n_workers - cur)
        elif n_workers < cur:
            self._store.rpush(self._job_key, *([_POISON] * (cur - n_workers)))
        self._n_workers_target = n_workers

    @property
    def n_workers(self) -> int:
        with self._jobs_lock:
            return self._live_workers

    # -- submission ------------------------------------------------------------

    def _upload_func(self, func: Callable) -> str:
        """Content-addressed function upload: the key is the hash of the
        serialized function, so repeated ``map()``/``map_async()`` of the
        same function (grid search's loop) upload it ONCE — later submits
        skip the ``storage.put`` entirely (local memo; cross-client
        reuse via ``storage.exists`` when the memo is cold). Workers
        already cache by ``func_key``, so the same key also means one
        download + deserialize per worker, ever — which, like a warm
        FaaS container (paper §3.1.2), makes by-value state the function
        captured persist across same-function jobs within a worker,
        exactly as it already persisted across chunks within one job."""
        blob = serialization.dumps(func)
        digest = hashlib.sha256(blob).hexdigest()[:24]
        key = f"pool/funcs/{digest}"
        if digest in self._uploaded_funcs:
            return key
        if not self._storage.exists(key):
            self._storage.put(key, blob)
        self._uploaded_funcs.add(digest)
        return key

    def _submit_job(self, func: Callable, items: List[Tuple[Tuple, Dict]],
                    chunksize: Optional[int], result: MapResult,
                    imap_buf: Optional["_IMapBuffer"] = None) -> None:
        if self._closed:
            raise ValueError("Pool not running")
        n = len(items)
        if n == 0:
            # Nothing to run: resolve immediately WITHOUT uploading the
            # function or registering the job — a registered job with no
            # chunks would sit in self._jobs forever (the collector only
            # prunes a job once its last result arrives).
            result._event.set()
            return
        job_id = next(self._job_seq)
        with self._jobs_lock:
            self._jobs[job_id] = (result, imap_buf)
        func_key = self._upload_func(func)
        if chunksize is None:
            chunksize = max(1, math.ceil(n / (self._n_workers_target * 4)))
        chunks = []
        for c_idx, start in enumerate(range(0, n, chunksize)):
            chunk_items = [(start + j, args, kwargs)
                           for j, (args, kwargs) in
                           enumerate(items[start:start + chunksize])]
            chunks.append(serialization.dumps(
                (job_id, c_idx, func_key, chunk_items)))
        # One flush submits the whole job (the paper's key optimization).
        # Large jobs split into capped-arity RPUSHes inside one pipeline
        # flush: over TCP the multi-frame mode bounds how much of the job
        # a single wire frame materializes (responses drain between
        # buffer-bounded chunks); on in-process stores the batch still
        # runs under a single lock acquisition.
        pipe_factory = getattr(self._store, "pipeline", None)
        if pipe_factory is not None and len(chunks) > _SUBMIT_RPUSH_ARITY:
            try:
                pipe = pipe_factory(transactional=False)
            except TypeError:  # in-process stores: batch mode only
                pipe = pipe_factory()
            with pipe:
                for i in range(0, len(chunks), _SUBMIT_RPUSH_ARITY):
                    pipe.rpush(self._job_key,
                               *chunks[i:i + _SUBMIT_RPUSH_ARITY])
        else:
            self._store.rpush(self._job_key, *chunks)

    # -- public API -------------------------------------------------------------

    def apply_async(self, func: Callable, args: Sequence[Any] = (),
                    kwds: Optional[Dict] = None, callback=None,
                    error_callback=None) -> AsyncResult:
        res = AsyncResult(1, callback, error_callback)
        self._submit_job(func, [(tuple(args), dict(kwds or {}))], 1, res)
        return res

    def apply(self, func: Callable, args: Sequence[Any] = (),
              kwds: Optional[Dict] = None):
        return self.apply_async(func, args, kwds).get()

    def map_async(self, func: Callable, iterable: Iterable[Any],
                  chunksize: Optional[int] = None, callback=None,
                  error_callback=None) -> MapResult:
        items = [((x,), {}) for x in iterable]
        res = MapResult(len(items), callback, error_callback)
        self._submit_job(func, items, chunksize, res)
        return res

    def map(self, func: Callable, iterable: Iterable[Any],
            chunksize: Optional[int] = None) -> List[Any]:
        return self.map_async(func, iterable, chunksize).get()

    def starmap_async(self, func: Callable, iterable: Iterable[Sequence[Any]],
                      chunksize: Optional[int] = None, callback=None,
                      error_callback=None) -> MapResult:
        items = [(tuple(x), {}) for x in iterable]
        res = MapResult(len(items), callback, error_callback)
        self._submit_job(func, items, chunksize, res)
        return res

    def starmap(self, func: Callable, iterable: Iterable[Sequence[Any]],
                chunksize: Optional[int] = None) -> List[Any]:
        return self.starmap_async(func, iterable, chunksize).get()

    def imap(self, func: Callable, iterable: Iterable[Any],
             chunksize: int = 1):
        return self._imap(func, iterable, chunksize, ordered=True)

    def imap_unordered(self, func: Callable, iterable: Iterable[Any],
                       chunksize: int = 1):
        return self._imap(func, iterable, chunksize, ordered=False)

    def _imap(self, func, iterable, chunksize, ordered: bool):
        items = [((x,), {}) for x in iterable]
        res = MapResult(len(items))
        buf = _IMapBuffer(len(items), ordered)
        self._submit_job(func, items, chunksize, res, imap_buf=buf)
        return buf.__iter__()

    # -- lifecycle -----------------------------------------------------------------

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        with self._jobs_lock:
            n = self._live_workers
        if n:
            self._store.rpush(self._job_key, *([_POISON] * n))

    def terminate(self) -> None:
        self._closed = True
        with self._jobs_lock:
            n = self._live_workers
        pipe_factory = getattr(self._store, "pipeline", None)
        if pipe_factory is not None:
            # kill flag + queue flush + poison pills: one round trip.
            with pipe_factory() as pipe:
                pipe.set(self._kill_key, 1, ex=3600)
                pipe.delete(self._job_key)
                if n:
                    pipe.rpush(self._job_key, *([_POISON] * n))
            return
        self._store.set(self._kill_key, 1, ex=3600)
        self._store.delete(self._job_key)
        if n:
            self._store.rpush(self._job_key, *([_POISON] * n))

    def join(self, timeout: Optional[float] = None) -> None:
        if not self._closed:
            raise ValueError("Pool is still running; call close() first")
        self._all_exited.wait(timeout)
        self._collector_stop = True
        self._store.rpush(self._result_key, serialization.dumps(("stop",)))
        self._executor.shutdown(wait=False)

    def __enter__(self) -> "Pool":
        return self

    def __exit__(self, *exc) -> None:
        self.terminate()
        self.join(timeout=10)

    def __del__(self):
        try:
            if not self._closed:
                self.terminate()
        except Exception:
            pass

    # -- result collection ------------------------------------------------------

    def _collect(self) -> None:
        while True:
            got = self._store.blpop(self._result_key, timeout=0.5)
            if got is None:
                if self._collector_stop:
                    return
                continue
            msg = serialization.loads(got[1])
            kind = msg[0]
            if kind == "stop":
                return
            if kind == "worker_exit":
                _, wid, reason = msg
                with self._jobs_lock:
                    self._live_workers -= 1
                    if self._live_workers <= 0:
                        self._all_exited.set()
                if reason == "recycle" and not self._closed:
                    self._spawn_workers(1)  # maxtasksperchild replacement
                continue
            _, job_id, _c_idx, results, _wid = msg
            with self._jobs_lock:
                entry = self._jobs.get(job_id)
            if entry is None:
                continue
            result, imap_buf = entry
            for item_idx, status, value in results:
                result._deliver(item_idx, status, value)
                if imap_buf is not None:
                    imap_buf.deliver(item_idx, status, value)
            if result.ready():
                with self._jobs_lock:
                    self._jobs.pop(job_id, None)


class _IMapBuffer:
    """Feeds imap/imap_unordered generators as chunks arrive."""

    def __init__(self, n: int, ordered: bool):
        self._n = n
        self._ordered = ordered
        self._ready: Dict[int, Tuple[str, Any]] = {}
        self._arrival: List[Tuple[int, str, Any]] = []
        self._cond = threading.Condition()

    def deliver(self, idx: int, status: str, value: Any) -> None:
        with self._cond:
            self._ready[idx] = (status, value)
            self._arrival.append((idx, status, value))
            self._cond.notify_all()

    def __iter__(self):
        from .executor import RemoteError
        if self._ordered:
            for i in range(self._n):
                with self._cond:
                    while i not in self._ready:
                        self._cond.wait()
                    status, value = self._ready[i]
                if status != "ok":
                    raise RemoteError(value[0], value[1])
                yield value
        else:
            for i in range(self._n):
                with self._cond:
                    while len(self._arrival) <= i:
                        self._cond.wait()
                    _, status, value = self._arrival[i]
                if status != "ok":
                    raise RemoteError(value[0], value[1])
                yield value
