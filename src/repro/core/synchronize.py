"""Synchronization primitives over KV lists (paper §3.2 "Synchronization").

Semaphore -> a LIST holding N tokens; ``acquire`` = BLPOP (blocks when no
             token, i.e. N holders inside), ``release`` = LPUSH. A Lock is
             the N=1 case. Exactly the paper's construction.
Condition -> each waiter registers a fresh *notification list* in the
             condition's waiter registry and BLPOPs it; ``notify`` pops
             waiter ids and pushes a token to each notification list.
Event / Barrier -> specific cases of Condition (paper), implemented on the
             same notification-list machinery plus a flag / arrival
             counter + generation number.
RLock     -> Lock + owner key + recursion counter (owner identity =
             process uid + thread id), checked transactionally.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Optional, Tuple

from .reference import RemoteResource, fresh_uid

__all__ = ["Lock", "RLock", "Semaphore", "BoundedSemaphore", "Condition",
           "Event", "Barrier", "BrokenBarrierError"]


class BrokenBarrierError(RuntimeError):
    pass


def _caller_identity() -> str:
    from .process import current_process
    return f"{current_process().pid}:{threading.get_ident()}"


class Semaphore(RemoteResource):
    _RESOURCE_KIND = "sem"

    def __init__(self, value: int = 1, _adopt: bool = False, **kw):
        if value < 0:
            raise ValueError("semaphore initial value must be >= 0")
        super().__init__(_adopt=_adopt, **kw)
        self._rebuild(value)
        if not _adopt and value > 0:
            self._store.rpush(self._tokens_key, *([b"t"] * value))

    def _rebuild(self, value: int) -> None:
        self._initial = value

    def _reduce_state(self):
        return (self._initial,)

    @property
    def _tokens_key(self) -> str:
        return self._key("tokens")

    def _kv_keys(self):
        return [self._refs_key, self._tokens_key]

    def acquire(self, block: bool = True, timeout: Optional[float] = None) -> bool:
        got = self._store.blpop(self._tokens_key, timeout if block else 0.0)
        return got is not None

    def release(self) -> None:
        self._store.lpush(self._tokens_key, b"t")

    def get_value(self) -> int:
        return self._store.llen(self._tokens_key)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()


class BoundedSemaphore(Semaphore):
    _RESOURCE_KIND = "bsem"

    def release(self) -> None:
        tokens_key, initial = self._tokens_key, self._initial

        def txn(s):
            if s.llen(tokens_key) >= initial:
                raise ValueError("semaphore released too many times")
            s.lpush(tokens_key, b"t")
        self._txn(txn, tokens_key)

    def _txn(self, fn, key_hint):
        if hasattr(self._store, "shards"):
            return self._store.transaction(fn, key_hint=key_hint)
        return self._store.transaction(fn)


class Lock(Semaphore):
    _RESOURCE_KIND = "lock"

    def __init__(self, _adopt: bool = False, **kw):
        super().__init__(value=1, _adopt=_adopt, **kw)

    def _rebuild(self, value: int = 1) -> None:
        super()._rebuild(1)

    def locked(self) -> bool:
        return self.get_value() == 0


class RLock(Lock):
    """Lock + owner + recursion count, with *lock-scope hooks*.

    Local (never serialized) callbacks fire at the edges of the outermost
    acquire/release: ``on_acquire`` right after ownership is taken,
    ``on_release`` right before the token is returned — i.e. still inside
    mutual exclusion. Block-backed shared arrays register their cache
    here: acquire invalidates stale local segments, release flushes
    write-combined dirty segments in one command. That is release
    consistency, and it is exactly the contract ``with arr.get_lock():``
    already promises callers.
    """

    _RESOURCE_KIND = "rlock"

    @property
    def _owner_key(self) -> str:
        return self._key("owner")

    @property
    def _count_key(self) -> str:
        return self._key("count")

    def _kv_keys(self):
        return super()._kv_keys() + [self._owner_key, self._count_key]

    def _register_scope_hooks(self, on_acquire, on_release) -> None:
        """Attach local outermost-scope callbacks (see class docstring).
        Hooks live only on this proxy object: a pickled copy in another
        process re-registers its own against its own cache."""
        self.__dict__.setdefault("_scope_hooks", []).append(
            (on_acquire, on_release))

    def acquire(self, block: bool = True, timeout: Optional[float] = None) -> bool:
        me = _caller_identity()
        if self._store.get(self._owner_key) == me:
            self._store.incr(self._count_key)
            return True  # reentrant: scope already open, hooks stay quiet
        if not super().acquire(block, timeout):
            return False
        self._store.set(self._owner_key, me)
        self._store.set(self._count_key, 1)
        for on_acquire, _ in getattr(self, "_scope_hooks", ()):
            on_acquire()
        return True

    def release(self) -> None:
        me = _caller_identity()
        if self._store.get(self._owner_key) != me:
            raise RuntimeError("cannot release un-acquired RLock")
        left = self._store.decr(self._count_key)
        if left <= 0:
            # Flush hooks run while we still hold the lock: write-combined
            # state must be visible before the next holder can acquire.
            # The lock is returned even if a flush fails (finally): the
            # exception propagates to the caller — whose writes ARE lost,
            # like any failed store write — but other processes must not
            # deadlock on a permanently-held lock.
            try:
                for _, on_release in getattr(self, "_scope_hooks", ()):
                    on_release()
            finally:
                self._store.delete(self._owner_key, self._count_key)
                super().release()


class Condition(RemoteResource):
    _RESOURCE_KIND = "cond"

    def __init__(self, lock: Optional[Lock] = None, _adopt: bool = False, **kw):
        super().__init__(_adopt=_adopt, **kw)
        self._rebuild(lock if lock is not None else Lock(store=kw.get("store")))

    def _rebuild(self, lock: Lock) -> None:
        self._lock = lock

    def _reduce_state(self):
        return (self._lock,)

    @property
    def _waiters_key(self) -> str:
        return self._key("waiters")

    def _kv_keys(self):
        return [self._refs_key, self._waiters_key]

    # lock delegation
    def acquire(self, *a, **kw):
        return self._lock.acquire(*a, **kw)

    def release(self):
        return self._lock.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()

    def wait(self, timeout: Optional[float] = None) -> bool:
        # Register a fresh notification list, drop the lock, block on it
        # (paper: "the process registers a new list to the notification
        # list set and blocks to it with a BLPOP command").
        notify_key = self._key("n-" + fresh_uid("w"))
        self._store.rpush(self._waiters_key, notify_key.encode())
        self.release()
        try:
            got = self._store.blpop(notify_key, timeout)
            return got is not None
        finally:
            self._store.delete(notify_key)
            self.acquire()

    def wait_for(self, predicate, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        result = predicate()
        while not result:
            remaining = None
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return predicate()
            self.wait(remaining)
            result = predicate()
        return result

    def notify(self, n: int = 1) -> None:
        for _ in range(n):
            got = self._store.lpop(self._waiters_key)
            if got is None:
                return
            self._store.rpush(got.decode(), b"n")

    def notify_all(self) -> None:
        self.notify(1 << 30)


class Event(RemoteResource):
    _RESOURCE_KIND = "event"

    @property
    def _flag_key(self) -> str:
        return self._key("flag")

    @property
    def _waiters_key(self) -> str:
        return self._key("waiters")

    def _kv_keys(self):
        return [self._refs_key, self._flag_key, self._waiters_key]

    def is_set(self) -> bool:
        return bool(self._store.get(self._flag_key))

    def set(self) -> None:
        flag_key, waiters_key = self._flag_key, self._waiters_key

        def txn(s):  # closes over plain strings only (TCP-transaction safe)
            s.set(flag_key, 1)
            while True:
                w = s.lpop(waiters_key)
                if w is None:
                    return
                s.rpush(w.decode(), b"n")
        self._txn(txn)

    def clear(self) -> None:
        self._store.delete(self._flag_key)

    def wait(self, timeout: Optional[float] = None) -> bool:
        if self.is_set():
            return True
        notify_key = self._key("n-" + fresh_uid("w"))
        # Register, then re-check the flag to close the set() race.
        self._store.rpush(self._waiters_key, notify_key.encode())
        if self.is_set():
            self._store.delete(notify_key)
            return True
        try:
            got = self._store.blpop(notify_key, timeout)
            return got is not None or self.is_set()
        finally:
            self._store.delete(notify_key)

    def _txn(self, fn):
        if hasattr(self._store, "shards"):
            return self._store.transaction(fn, key_hint=self._flag_key)
        return self._store.transaction(fn)


class Barrier(RemoteResource):
    _RESOURCE_KIND = "barrier"

    def __init__(self, parties: int, action=None, timeout: Optional[float] = None,
                 _adopt: bool = False, **kw):
        super().__init__(_adopt=_adopt, **kw)
        self._rebuild(parties, timeout)
        self._action = action  # runs in the releasing process only

    def _rebuild(self, parties: int, timeout: Optional[float]) -> None:
        self.parties = parties
        self._timeout = timeout
        self._action = None

    def _reduce_state(self):
        return (self.parties, self._timeout)

    @property
    def _count_key(self):
        return self._key("count")

    @property
    def _broken_key(self):
        return self._key("broken")

    @property
    def _waiters_key(self):
        return self._key("waiters")

    def _kv_keys(self):
        return [self._refs_key, self._count_key, self._broken_key,
                self._waiters_key]

    @property
    def n_waiting(self) -> int:
        v = self._store.get(self._count_key)
        return int(v) if v else 0

    @property
    def broken(self) -> bool:
        return bool(self._store.get(self._broken_key))

    def abort(self) -> None:
        broken_key, waiters_key = self._broken_key, self._waiters_key

        def txn(s):
            s.set(broken_key, 1)
            while True:
                w = s.lpop(waiters_key)
                if w is None:
                    return
                s.rpush(w.decode(), b"abort")
        self._txn(txn)

    def reset(self) -> None:
        self.abort()
        self._store.delete(self._broken_key, self._count_key)

    def wait(self, timeout: Optional[float] = None) -> int:
        if self.broken:
            raise BrokenBarrierError
        timeout = timeout if timeout is not None else self._timeout
        notify_key = self._key("n-" + fresh_uid("w"))
        count_key, waiters_key, parties = self._count_key, self._waiters_key, self.parties

        def txn(s):  # closes over plain strings/ints only
            arrived = s.incr(count_key)
            if arrived >= parties:
                # Releasing party: wake everyone, reset the generation.
                s.delete(count_key)
                while True:
                    w = s.lpop(waiters_key)
                    if w is None:
                        break
                    s.rpush(w.decode(), b"go")
            else:
                s.rpush(waiters_key, notify_key.encode())
            return arrived

        arrived = self._txn(txn)
        if arrived >= parties:
            if self._action is not None:
                self._action()
            return self.parties - 1
        got = self._store.blpop(notify_key, timeout)
        self._store.delete(notify_key)
        if got is None:
            self.abort()
            raise BrokenBarrierError("barrier wait timed out")
        if got[1] == b"abort" or self.broken:
            raise BrokenBarrierError
        return arrived - 1

    def _txn(self, fn):
        if hasattr(self._store, "shards"):
            return self._store.transaction(fn, key_hint=self._count_key)
        return self._store.transaction(fn)
