"""Multi-process sharded KV serving plane (beyond-paper scaling tier).

The remote mode of the seed serves every client from ONE Python process:
client threads and server threads share a GIL, and a single store
serializes all connections. "Serverless End Game" (arXiv:2006.01251)
argues disaggregation only becomes transparent when the shared-state
tier scales *independently* of compute; Faabric (arXiv:2302.11358) makes
the same observation for fine-grained distributed state. This module is
that tier:

``KVCluster``
    Launches N ``KVServer`` shard **processes** — each with its own
    interpreter, GIL, and striped ``KVStore`` — and supervises them
    (spawn handshake, stderr capture, liveness poll, explicit restart,
    teardown). The parent also serves a tiny *control* ``KVServer``
    whose store holds the cluster descriptor (shard count, addresses,
    hash seed) under the well-known key :data:`DESCRIPTOR_KEY`, so
    clients bootstrap from one address with a single GET.

``ClusterClient``
    The ``KVClient`` surface over the whole cluster. Keys hash-route
    with the exact consistent-hash + hash-tag rules of
    ``ShardedKVStore`` (the shared ``_ShardRouter`` mixin), so
    hash-tagged resource keys — every IPC primitive's keys, including
    block-array segment keys — stay co-located on one shard.
    ``pipeline()`` batches split into one ``execute_batch`` submission
    per involved shard and flush as a **scatter/gather** over each
    shard's I/O mux: every shard's batch is enqueued before any mux is
    flushed, then the per-shard futures are gathered — N shards still
    cost ~one wall-clock round trip. Cross-shard blocking pops fall back
    to the ``ShardedKVStore`` exponential-backoff sweep.

    v3 cost model (syscalls per N-thread scatter burst against S
    shards): with the per-thread-socket transport (``mux=False``) every
    thread writes its own ``execute_batch`` frame per involved shard and
    reads its own responses — ~4 x N x S syscalls per burst (send + recv
    on both ends), the per-frame tax that lost 0.6x on small commands in
    the PR 3 matrix. With the mux, each shard's connection carries every
    thread's frame: concurrent frames ship in one flat-combined gather
    write, the server reads them from one buffered recv and CORKS their
    responses into one write, and one baton-holding waiter drains the
    whole response burst — ~4 x S syscalls per burst, independent of N.
    Each thread's batch stays its OWN frame (responses stream back per
    thread; semantically merging batches across threads was measured and
    rejected — it couples the threads' latencies into a convoy), while
    bursts of plain single commands DO group-commit into one merged
    ``execute_batch`` frame. Shard batches that share one connection
    (co-resident shards, e.g. duplicate addresses in the descriptor) are
    merged client-side into a single frame.

    v4 raw dialect (PR 5): scatter sub-batches whose commands sit in the
    hot vocabulary are struct-packed per entry AT SUBMIT
    (``serialization.encode_command``) — the per-shard frame is a byte
    concatenation of pre-encoded entries, the shard decodes it into a
    dispatch-table indexed batch without unpickling, and small replies
    come back through the same codec — so after PR 4 collapsed the
    frame/syscall count, the remaining per-command pickle CPU on the
    client GIL collapses too. Commands or replies outside the
    vocabulary (large OOB values, the long command tail) fall back to
    pickle per command on the same connection; ``raw=False`` keeps the
    pure pickle dialect for A/B.

``connect(address)``
    One-address bootstrap: returns a ``ClusterClient`` when the address
    answers the descriptor GET (it is a cluster control endpoint), else
    the plain ``KVClient`` it already opened. ``worker_main`` uses this,
    so subprocess workers join a cluster transparently.

Everything above ``KVClient`` (queues, sharedctypes, pool, managers)
runs unchanged against a ``ClusterClient`` — that is the transparency
claim, proven by ``tests/test_transparency.py``.

Child processes are spawned as ``python -m repro.core.kvcluster
--serve-shard``; each binds its server, reports ``KVSHARD <host>
<port> [<endpoint-url> ...]`` on stdout, and serves until its stdin
reaches EOF — the parent holds the write end, so shards can never
outlive their supervisor, even if it is SIGKILLed.

Transports (PR 6): each shard serves every carrier its ``KVServer``
supports (TCP + Unix-domain + shm rings, see ``repro.core.transport``)
and advertises the full endpoint list in the spawn handshake; the
descriptor is version 2 with an ``"endpoints"`` key (one url list per
shard) alongside the legacy ``"shards"`` host/port pairs, so old
clients keep bootstrapping. ``ClusterClient(transport=...)`` pins one
carrier for A/B runs; the default auto-selects per shard (shm > uds >
tcp same-host, falling back down the list on connect failure). The
parent removes a dead shard's stale uds rendezvous path on terminate,
so ``restart_shard`` never trips over the corpse's socket file.
"""

from __future__ import annotations

import os
import subprocess
import sys
import threading
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from . import transport as _transport
from .kvserver import KVClient, KVServer, _sendv
from .kvstore import KVStore, Metrics, _ShardRouter, _debatch

__all__ = ["KVCluster", "ClusterClient", "connect", "DESCRIPTOR_KEY"]

#: Well-known control-store key holding the cluster descriptor.
DESCRIPTOR_KEY = "__cluster__"

#: Seconds to wait for a shard child to report its bound address.
_SPAWN_TIMEOUT_S = 30.0


# ---------------------------------------------------------------------------
# Shard child supervision
# ---------------------------------------------------------------------------


class _ShardProc:
    """One supervised shard process: handshake, stderr tail, liveness."""

    def __init__(self, index: int, host: str, port: int):
        self.index = index
        self.proc: Optional[subprocess.Popen] = None
        self.address: Optional[Tuple[str, int]] = None
        #: every carrier the shard serves, as endpoint urls (PR 6); a
        #: pre-endpoint child that reports only host/port degrades to
        #: its tcp url, so mixed-version supervision keeps working
        self.endpoints: List[str] = []
        self._stderr_tail: deque = deque(maxlen=200)
        self._spawn(host, port)

    def _spawn(self, host: str, port: int) -> None:
        env = os.environ.copy()
        # children must import repro even when the parent runs from an
        # uninstalled checkout
        src_root = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
        self.proc = subprocess.Popen(
            [sys.executable, "-m", "repro.core.kvcluster", "--serve-shard",
             "--host", host, "--port", str(port),
             "--name", f"shard{self.index}"],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, env=env, text=True)
        threading.Thread(target=self._drain_stderr, daemon=True,
                         name=f"kvshard{self.index}-stderr").start()
        line: List[str] = []

        def read_handshake() -> None:
            line.append(self.proc.stdout.readline())

        t = threading.Thread(target=read_handshake, daemon=True,
                             name=f"kvshard{self.index}-handshake")
        t.start()
        t.join(_SPAWN_TIMEOUT_S)
        words = line[0].split() if line and line[0] else []
        if len(words) < 3 or words[0] != "KVSHARD":
            self.terminate()
            raise RuntimeError(
                f"kv shard {self.index} failed to start "
                f"(got {line[0]!r} on stdout)\n{self.stderr_tail()}"
                if line else
                f"kv shard {self.index} did not report an address within "
                f"{_SPAWN_TIMEOUT_S}s\n{self.stderr_tail()}")
        self.address = (words[1], int(words[2]))
        self.endpoints = words[3:] or [f"tcp://{words[1]}:{words[2]}"]

    def _drain_stderr(self) -> None:
        # keep the pipe drained (a crashing child must not wedge writing
        # its traceback) and keep the tail for diagnostics
        proc = self.proc
        try:
            for ln in proc.stderr:
                self._stderr_tail.append(ln)
        except ValueError:
            pass  # pipe closed during teardown

    def stderr_tail(self) -> str:
        return "".join(self._stderr_tail)

    def alive(self) -> bool:
        return self.proc is not None and self.proc.poll() is None

    def terminate(self, grace_s: float = 5.0) -> None:
        proc = self.proc
        if proc is None:
            return
        try:
            if proc.stdin:
                proc.stdin.close()  # EOF = orderly shutdown request
        except OSError:
            pass
        try:
            proc.wait(timeout=grace_s)
        except subprocess.TimeoutExpired:
            proc.terminate()
            try:
                proc.wait(timeout=grace_s)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait()
        self._remove_stale_paths()

    def _remove_stale_paths(self) -> None:
        """Unlink the dead child's uds rendezvous socket (and its temp
        dir). An orderly child removes them itself in ``KVServer.stop``;
        this covers SIGKILL/crash so a respawned shard — or a client
        walking the old descriptor — never trips over a stale path
        (connecting to one fails with ECONNREFUSED, which the endpoint
        fallback turns into a silent downgrade to tcp; removing the
        corpse keeps the preference order honest)."""
        for url in self.endpoints:
            try:
                ep = _transport.parse_endpoint(url)
            except ValueError:
                continue
            if ep.scheme != "uds" or not ep.path:
                continue
            for path in (ep.path, os.path.dirname(ep.path)):
                try:
                    (os.rmdir if os.path.isdir(path) else os.unlink)(path)
                except OSError:
                    pass


class KVCluster:
    """N ``KVServer`` shard processes + a control endpoint, supervised.

    Use as a context manager (or ``start()``/``stop()``)::

        with KVCluster(shards=4) as cluster:
            client = cluster.client()          # a ClusterClient
            ...                                # or ClusterClient(cluster.address)

    ``address`` is the control endpoint; clients bootstrap from it alone
    (see module docstring for the handshake). Shard stores are empty on
    (re)start — a restarted shard loses its partition's data, exactly
    like a crashed cache node, so ``restart_shard`` is explicit rather
    than automatic.
    """

    def __init__(self, shards: int = 2, host: str = "127.0.0.1",
                 control_port: int = 0, hash_seed: int = 0):
        if shards < 1:
            raise ValueError("need at least one shard")
        self.n_shards = int(shards)
        self.host = host
        self.hash_seed = hash_seed
        self._control_port = control_port
        self._procs: List[_ShardProc] = []
        self._control: Optional[KVServer] = None
        self._started = False

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "KVCluster":
        if self._started:
            return self
        try:
            for i in range(self.n_shards):
                # append as we go: if a later spawn fails, _teardown must
                # reach the shards already running
                self._procs.append(_ShardProc(i, self.host, 0))
            store = KVStore(name="cluster-control")
            store.set(DESCRIPTOR_KEY, self.describe())
            self._control = KVServer(store, host=self.host,
                                     port=self._control_port).start()
        except BaseException:
            self._teardown()
            raise
        self._started = True
        return self

    def stop(self) -> None:
        self._started = False
        self._teardown()

    def _teardown(self) -> None:
        if self._control is not None:
            self._control.stop()
            self._control = None
        for p in self._procs:
            p.terminate()
        self._procs = []

    def __enter__(self) -> "KVCluster":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- topology ------------------------------------------------------------

    @property
    def address(self) -> Tuple[str, int]:
        """Control endpoint: the ONE address clients bootstrap from."""
        if self._control is None:
            raise RuntimeError("cluster is not started")
        return self._control.address

    @property
    def shard_addresses(self) -> List[Tuple[str, int]]:
        return [p.address for p in self._procs]

    @property
    def shard_endpoints(self) -> List[List[str]]:
        """Per-shard endpoint urls, every carrier the shard serves."""
        return [list(p.endpoints) for p in self._procs]

    def describe(self) -> Dict[str, Any]:
        """The cluster descriptor served under :data:`DESCRIPTOR_KEY`.

        Version 2 (PR 6): ``"endpoints"`` carries one url list per shard
        (tcp/uds/shm); ``"shards"`` keeps the bare host/port pairs so
        pre-endpoint clients bootstrap unchanged."""
        return {
            "version": 2,
            "shards": [list(p.address) for p in self._procs],
            "endpoints": self.shard_endpoints,
            "n_shards": len(self._procs),
            "hash": "fnv1a-hashtag",
            "hash_seed": self.hash_seed,
        }

    def client(self, **kwargs: Any) -> "ClusterClient":
        if not self._started:
            raise RuntimeError("cluster is not started")
        return ClusterClient(shard_addresses=self.shard_endpoints,
                             hash_seed=self.hash_seed, **kwargs)

    # -- supervision ---------------------------------------------------------

    def poll(self) -> List[bool]:
        """Per-shard liveness, in shard order."""
        return [p.alive() for p in self._procs]

    def ensure_alive(self) -> None:
        """Raise RuntimeError naming any dead shard, with its stderr tail."""
        dead = [p for p in self._procs if not p.alive()]
        if dead:
            detail = "; ".join(
                f"shard {p.index} exited with code {p.proc.returncode}"
                for p in dead)
            tails = "\n".join(t for t in (p.stderr_tail() for p in dead) if t)
            raise RuntimeError(f"kv cluster degraded: {detail}"
                               + (f"\n{tails}" if tails else ""))

    def restart_shard(self, index: int) -> Tuple[str, int]:
        """Respawn shard ``index`` on a FRESH ephemeral OS-assigned port
        and republish the descriptor. Rebinding the previous fixed port
        was a race — the dead child's socket can linger (TIME_WAIT, or
        the OS hands the port to someone else between death and respawn),
        which made the CI cluster smoke flaky with retry-on-EADDRINUSE
        noise. Ephemeral binding cannot collide; the cost is that
        already-bootstrapped clients must re-bootstrap from the control
        endpoint (which always serves the current descriptor). The
        shard's partition restarts EMPTY — callers own the data-loss
        consequences, which is why restart is explicit. Returns the
        shard's new address."""
        old = self._procs[index]
        host = old.address[0] if old.address else self.host
        old.terminate()
        self._procs[index] = _ShardProc(index, host, 0)
        if self._control is not None:
            self._control.store.set(DESCRIPTOR_KEY, self.describe())
        return self._procs[index].address


# ---------------------------------------------------------------------------
# Cluster client
# ---------------------------------------------------------------------------


class ClusterClient(_ShardRouter):
    """The ``KVClient`` method surface, hash-routed over cluster shards.

    Bootstraps from a single control ``address`` (one descriptor GET) or
    from explicit ``shard_addresses``. Single-key commands are one
    command on one shard; multi-key commands split per shard; pipeline
    batches flush as concurrent per-shard ``execute_batch`` frames
    (scatter/gather — see ``execute_batch``). The ``shards`` attribute
    holds one ``KVClient`` per shard, which is also what the IPC layer's
    ``hasattr(store, "shards")`` probes key on to pass transaction key
    hints.
    """

    def __init__(self, address: Any = None,
                 shard_addresses: Optional[Sequence[Any]] = None,
                 legacy_protocol: bool = False, hash_seed: int = 0,
                 mux: bool = True, raw: bool = True,
                 transport: Optional[str] = None):
        if shard_addresses is None:
            if address is None:
                raise ValueError("need a control address or shard addresses")
            boot = KVClient(address)
            try:
                desc = boot.get(DESCRIPTOR_KEY)
            finally:
                boot.close()
            if not isinstance(desc, dict) or "shards" not in desc:
                raise ConnectionError(
                    f"{address!r} is not a cluster control endpoint (no "
                    "descriptor; use KVClient for a plain KVServer)")
            # v2 descriptors advertise per-shard endpoint url lists;
            # v1 only has host/port pairs (tcp)
            shard_addresses = (desc.get("endpoints")
                               or [tuple(a) for a in desc["shards"]])
            hash_seed = desc.get("hash_seed", hash_seed)
        if not shard_addresses:
            raise ValueError("need at least one shard address")
        self.hash_seed = hash_seed
        self.transport = transport
        # shards at the same address share ONE KVClient (hence one mux
        # connection): their scatter sub-batches coalesce into one
        # frame. Co-residency is keyed on the NORMALIZED endpoint set,
        # so two entries naming the same server through any address
        # shape still share a client.
        by_addr: Dict[Tuple[str, ...], KVClient] = {}
        self.shards = []
        for a in shard_addresses:
            eps = _transport.normalize_endpoints(a)
            key = tuple(sorted(e.url for e in eps))
            if key not in by_addr:
                by_addr[key] = KVClient(eps, legacy_protocol=legacy_protocol,
                                        mux=mux, raw=raw, transport=transport)
            self.shards.append(by_addr[key])
        # client-side counters only (server-side metrics live per shard and
        # are readable via info()): fanout records scatter widths, which no
        # single shard can observe
        self.metrics = Metrics()
        self.name = f"cluster[{len(self.shards)}]"

    def execute_batch(self, commands: List[Tuple[str, tuple, dict]]
                      ) -> List[Tuple[bool, Any]]:
        """Scatter/gather batch: route commands per shard
        (``_route_batch``, which preserves submission order around
        multi-key commands), ENQUEUE every shard's ``execute_batch`` on
        its mux, flush each involved connection once, then gather the
        per-shard futures. The flushes overlap on the wire and in the
        shard processes, so N involved shards cost ~one wall-clock round
        trip instead of N; concurrent threads' scatters group-commit into
        the same per-shard frames, and co-resident shard batches (one
        connection) coalesce into one frame.

        Framing safety under errors matches the single-connection
        pipeline contract: every scattered batch's future is awaited even
        when another shard fails, so no connection is left holding an
        uncorrelated response; a connection that dies is torn down by its
        mux (every pending future resolves with the error) and is
        re-established on next use."""
        return self._route_batch([_debatch(c) for c in commands],
                                 self._scatter_groups)

    def _scatter_groups(self, groups, out) -> None:
        self.metrics.record_fanout(len(groups))
        if not all(self.shards[idx].mux_enabled for idx in groups):
            return self._scatter_groups_sockets(groups, out)
        first_err: Optional[BaseException] = None
        pending = []
        flushes = []
        # Phase 1: merge shard groups per CONNECTION (co-resident shards
        # share a client/mux — their sub-batches become one frame) and
        # enqueue each connection's batch without flushing yet.
        by_mux: Dict[int, List[int]] = {}
        for idx in sorted(groups):
            by_mux.setdefault(id(self.shards[idx]), []).append(idx)
        for idxs in by_mux.values():
            client = self.shards[idxs[0]]
            numbered = [nc for idx in idxs for nc in groups[idx]]
            cmds = [c for _, c in numbered]
            try:
                fut = client._mux().submit(
                    "batch", ("execute_batch", (cmds,), {}),
                    ncmds=len(cmds), flush=False, coalesce=False)
            except Exception as exc:
                if first_err is None:
                    first_err = exc
                continue
            flushes.append(fut)
            pending.append((fut, numbered))
        # Phase 2: one flush per involved connection (the scatter). The
        # flush is keyed on that connection's pending: if another
        # thread's flat-combining leader already shipped our frame, this
        # returns without ever contending the write lock.
        for fut in flushes:
            try:
                fut.mux.flush(fut)
            except Exception as exc:  # pragma: no cover - submit raised first
                if first_err is None:
                    first_err = exc
        # Phase 3: gather. Every future is awaited — a shard error never
        # leaves another shard's response unconsumed.
        for fut, numbered in pending:
            ok, value = fut.result()
            if not ok:
                if first_err is None:
                    first_err = value
                continue
            for (i, _), res in zip(numbered, value):
                out[i] = res
        if first_err is not None:
            raise first_err

    def _scatter_groups_sockets(self, groups, out) -> None:
        """PR 3 transport (``mux=False``/legacy): write every shard's
        frame on this thread's per-shard socket before reading any
        response, then drain — kept for A/B benchmarking."""
        first_err: Optional[BaseException] = None
        pending = []
        for idx in sorted(groups):
            client = self.shards[idx]
            try:
                sock = client._sock()
                _sendv(sock, client._request_frames(
                    ("execute_batch", ([c for _, c in groups[idx]],), {})))
            except Exception as exc:
                if first_err is None:
                    first_err = exc
                # a partial frame would desync this thread's connection;
                # other threads' sockets to the shard are untouched
                client.close_connection()
                continue
            pending.append((client, sock, groups[idx]))
        for client, sock, numbered in pending:
            try:
                ok, value = client._read_response(sock)
            except Exception as exc:
                if first_err is None:
                    first_err = exc
                client.close_connection()  # mid-frame state is unrecoverable
                continue
            if not ok:
                if first_err is None:
                    first_err = value
                continue
            for (i, _), res in zip(numbered, value):
                out[i] = res
        if first_err is not None:
            raise first_err

    def close(self) -> None:
        seen = set()
        for c in self.shards:
            if id(c) not in seen:  # co-resident shards share one client
                seen.add(id(c))
                c.close()


def connect(address: Any, legacy_protocol: bool = False,
            transport: Optional[str] = None
            ) -> Union[KVClient, "ClusterClient"]:
    """Bootstrap from one address: a cluster control endpoint answers the
    descriptor GET and yields a ``ClusterClient``; a plain ``KVServer``
    answers None and the already-open ``KVClient`` is returned as-is.
    ``address`` takes any shape ``KVClient`` does — a ``(host, port)``
    tuple, an endpoint url, or a url list. ``transport`` pins the SHARD
    carriers; the bootstrap GET itself uses whatever ``address``
    advertises (a bare control tuple is tcp-only, and pinning one
    round trip buys nothing)."""
    client = KVClient(address, legacy_protocol=legacy_protocol)
    try:
        desc = client.get(DESCRIPTOR_KEY)
    except Exception:
        client.close()
        raise
    if isinstance(desc, dict) and "shards" in desc:
        client.close()
        return ClusterClient(
            shard_addresses=(desc.get("endpoints")
                             or [tuple(a) for a in desc["shards"]]),
            legacy_protocol=legacy_protocol,
            hash_seed=desc.get("hash_seed", 0),
            transport=transport)
    if transport is not None:
        # plain server: re-open with the pin (raises if unadvertised)
        client.close()
        return KVClient(address, legacy_protocol=legacy_protocol,
                        transport=transport)
    return client


# ---------------------------------------------------------------------------
# Shard child entry point
# ---------------------------------------------------------------------------


def _serve_shard(host: str, port: int, name: str) -> int:
    server = KVServer(KVStore(name=name), host=host, port=port)
    server.start()
    # host/port first (pre-endpoint parents read exactly those), then
    # every endpoint url the server actually serves
    sys.stdout.write(f"KVSHARD {server.address[0]} {server.address[1]} "
                     + " ".join(server.endpoints) + "\n")
    sys.stdout.flush()
    try:
        sys.stdin.read()  # parent holds our stdin; EOF means shut down
    except (KeyboardInterrupt, OSError):
        pass
    server.stop()
    return 0


def _main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="KV cluster shard process (spawned by KVCluster)")
    ap.add_argument("--serve-shard", action="store_true", required=True)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--name", default="shard")
    args = ap.parse_args(argv)
    return _serve_shard(args.host, args.port, args.name)


if __name__ == "__main__":
    sys.exit(_main())
