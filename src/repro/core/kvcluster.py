"""Multi-process sharded KV serving plane (beyond-paper scaling tier).

The remote mode of the seed serves every client from ONE Python process:
client threads and server threads share a GIL, and a single store
serializes all connections. "Serverless End Game" (arXiv:2006.01251)
argues disaggregation only becomes transparent when the shared-state
tier scales *independently* of compute; Faabric (arXiv:2302.11358) makes
the same observation for fine-grained distributed state. This module is
that tier:

``KVCluster``
    Launches N ``KVServer`` shard **processes** — each with its own
    interpreter, GIL, and striped ``KVStore`` — and supervises them
    (spawn handshake, stderr capture, liveness poll, explicit restart,
    teardown). The parent also serves a tiny *control* ``KVServer``
    whose store holds the cluster descriptor (shard count, addresses,
    hash seed) under the well-known key :data:`DESCRIPTOR_KEY`, so
    clients bootstrap from one address with a single GET.

``ClusterClient``
    The ``KVClient`` surface over the whole cluster. Keys hash-route
    with the exact consistent-hash + hash-tag rules of
    ``ShardedKVStore`` (the shared ``_ShardRouter`` mixin), so
    hash-tagged resource keys — every IPC primitive's keys, including
    block-array segment keys — stay co-located on one shard.
    ``pipeline()`` batches split into one ``execute_batch`` submission
    per involved shard and flush as a **scatter/gather** over each
    shard's I/O mux: every shard's batch is enqueued before any mux is
    flushed, then the per-shard futures are gathered — N shards still
    cost ~one wall-clock round trip. Cross-shard blocking pops fall back
    to the ``ShardedKVStore`` exponential-backoff sweep.

    v3 cost model (syscalls per N-thread scatter burst against S
    shards): with the per-thread-socket transport (``mux=False``) every
    thread writes its own ``execute_batch`` frame per involved shard and
    reads its own responses — ~4 x N x S syscalls per burst (send + recv
    on both ends), the per-frame tax that lost 0.6x on small commands in
    the PR 3 matrix. With the mux, each shard's connection carries every
    thread's frame: concurrent frames ship in one flat-combined gather
    write, the server reads them from one buffered recv and CORKS their
    responses into one write, and one baton-holding waiter drains the
    whole response burst — ~4 x S syscalls per burst, independent of N.
    Each thread's batch stays its OWN frame (responses stream back per
    thread; semantically merging batches across threads was measured and
    rejected — it couples the threads' latencies into a convoy), while
    bursts of plain single commands DO group-commit into one merged
    ``execute_batch`` frame. Shard batches that share one connection
    (co-resident shards, e.g. duplicate addresses in the descriptor) are
    merged client-side into a single frame.

    v4 raw dialect (PR 5): scatter sub-batches whose commands sit in the
    hot vocabulary are struct-packed per entry AT SUBMIT
    (``serialization.encode_command``) — the per-shard frame is a byte
    concatenation of pre-encoded entries, the shard decodes it into a
    dispatch-table indexed batch without unpickling, and small replies
    come back through the same codec — so after PR 4 collapsed the
    frame/syscall count, the remaining per-command pickle CPU on the
    client GIL collapses too. Commands or replies outside the
    vocabulary (large OOB values, the long command tail) fall back to
    pickle per command on the same connection; ``raw=False`` keeps the
    pure pickle dialect for A/B.

``connect(address)``
    One-address bootstrap: returns a ``ClusterClient`` when the address
    answers the descriptor GET (it is a cluster control endpoint), else
    the plain ``KVClient`` it already opened. ``worker_main`` uses this,
    so subprocess workers join a cluster transparently.

Everything above ``KVClient`` (queues, sharedctypes, pool, managers)
runs unchanged against a ``ClusterClient`` — that is the transparency
claim, proven by ``tests/test_transparency.py``.

Child processes are spawned as ``python -m repro.core.kvcluster
--serve-shard``; each binds its server, reports ``KVSHARD <host>
<port> [<endpoint-url> ...]`` on stdout, and serves until its stdin
reaches EOF — the parent holds the write end, so shards can never
outlive their supervisor, even if it is SIGKILLed.

Transports (PR 6): each shard serves every carrier its ``KVServer``
supports (TCP + Unix-domain + shm rings, see ``repro.core.transport``)
and advertises the full endpoint list in the spawn handshake; the
descriptor carries an ``"endpoints"`` key (one url list per shard)
alongside the legacy ``"shards"`` host/port pairs, so old
clients keep bootstrapping. ``ClusterClient(transport=...)`` pins one
carrier for A/B runs; the default auto-selects per shard (shm > uds >
tcp same-host, falling back down the list on connect failure). The
parent removes a dead shard's stale uds rendezvous path on terminate,
so ``restart_shard`` never trips over the corpse's socket file.

Replication & the consistency model (PR 7)
------------------------------------------

``KVCluster(replicas=N)`` gives every shard N replica processes. The
primary executes mutating commands under one replication lock (so log
order == execution order), appends each realized effect to a command
log, and a streamer thread per replica ships the log as
``repl_apply(first_seq, entries)`` batches over a plain ``KVClient`` —
replication rides the same wire dialects (v4 raw for small scalar
entries, pickle + out-of-band zero-copy for everything else) and the
same pluggable transports as client traffic. Blocking pops are logged
as their realized non-blocking effect (a ``blpop`` that popped key ``k``
replays as ``lpop(k)``), so replicas never park. Replicas deduplicate by
sequence number, which makes duplicate deliveries (retries, chaos
injection) harmless, and answer any mutating client command with a typed
``ShardRedirectError`` instead of executing it.

What "acknowledged" guarantees, per ack policy:

``ack="primary"`` (default)
    A write is acknowledged once the PRIMARY applied it; replication is
    asynchronous. Latency is within noise of an unreplicated shard, but
    a primary failure may lose the tail of acknowledged writes that had
    not yet streamed (the replication lag, typically well under a
    millisecond on one host). This is Redis-style async replication.

``ack="quorum"``
    A write is acknowledged only after a MAJORITY of the shard's node
    set (primary + replicas) holds it — e.g. primary + 1 of 1, or
    primary + 1 of 2 replicas. An acknowledged write then survives any
    minority of node failures: whichever freshest replica the
    supervisor promotes is guaranteed to hold every acknowledged write.
    The cost is one replication round trip inside every mutating
    command (reads stay un-acked and fast). A double failure that
    removes a majority (e.g. primary + the acking replica of 3 nodes)
    may lose acknowledged writes — quorum tolerates minority failure
    only. If the quorum cannot be reached within ``quorum_timeout``,
    the client gets ``ShardUnavailableError`` for a write that IS
    applied locally but unacknowledged (at-least-once semantics; the
    supervisor's watchdog detaches dead replicas so later writes
    degrade to the surviving majority instead of wedging).

Failover window semantics: when a primary dies, the watchdog (or an
explicit ``promote_shard``) picks the replica with the highest applied
sequence, flips it to primary via ``repl_promote`` (it adopts its apply
history as the new command log and streams to the surviving peers),
bumps the descriptor ``epoch``, and republishes. Clients that hit the
dead primary refetch the descriptor (``ClusterClient.refresh()``) and
retry idempotent commands with bounded exponential backoff; in-flight
non-idempotent commands surface ``ShardUnavailableError`` (ambiguous:
the dead primary may or may not have applied them — exactly the
at-least-once window every primary-failover system has). During the
window between death and promotion, affected commands retry or fail
typed; commands on other shards proceed untouched.
"""

from __future__ import annotations

import os
import subprocess
import sys
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from . import clientopts as _copts
from . import transport as _transport
from .errors import (EndpointConnectError, ShardRedirectError,
                     ShardUnavailableError)
from .kvserver import KVClient, KVServer, _sendv
from .kvstore import (LEASE_REGISTRY_KEY, KVStore, Metrics, _ShardRouter,
                      _debatch)

__all__ = ["KVCluster", "ClusterClient", "connect", "DESCRIPTOR_KEY",
           "ShardRedirectError", "ShardUnavailableError"]

#: Well-known control-store key holding the cluster descriptor.
DESCRIPTOR_KEY = "__cluster__"

#: Seconds to wait for a shard child to report its bound address.
_SPAWN_TIMEOUT_S = 30.0

#: Client-side failover retry tuning (see ``ClusterClient._shard_call``).
_RETRY_MIN_BACKOFF_S = 0.05
_RETRY_MAX_BACKOFF_S = 0.8

#: Commands safe to retry transparently after a shard connection dies:
#: pure reads plus idempotent writes (replaying the same absolute write
#: converges to the same state). Counters, pushes, pops, getset and
#: transactions are NOT here — a lost reply makes their effect
#: ambiguous, so they surface ``ShardUnavailableError`` instead.
_RETRY_SAFE = frozenset({
    "get", "mget", "exists", "ttl", "type_of", "keys", "dbsize", "info",
    "getrange", "strlen", "llen", "lindex", "lrange",
    "hget", "hmget", "hgetall", "hlen", "hkeys", "hvals", "hexists",
    "smembers", "scard", "sismember", "bllen",
    "set", "mset", "setrange", "msetrange", "delete", "expire", "persist",
    "lset", "ltrim", "hset", "hdel", "sadd", "srem", "flushall",
    # lease bookkeeping is fenced by (field, attempt): replaying a renew
    # or release whose fence no longer matches is a no-op returning
    # False, so a lost reply cannot corrupt lease state
    "lease_renew", "lease_release",
})


def _retry_safe(cmd: str, args: tuple, kwargs: dict) -> bool:
    if cmd not in _RETRY_SAFE:
        return False
    if cmd == "set" and (kwargs.get("nx")
                         or (len(args) > 3 and args[3])):
        return False  # nx: a lost reply flips the answer on retry
    return True


# ---------------------------------------------------------------------------
# Shard child supervision
# ---------------------------------------------------------------------------


class _ShardProc:
    """One supervised shard process: handshake, stderr tail, liveness.

    ``role`` is ``"primary"`` or ``"replica"``; a primary spawned with
    ``replicate_to`` (one endpoint-url list per replica) starts
    streaming its command log to those replicas immediately."""

    def __init__(self, index: int, host: str, port: int,
                 name: Optional[str] = None, role: str = "primary",
                 replicate_to: Sequence[Sequence[str]] = (),
                 ack: str = "primary", quorum_timeout: float = 5.0):
        self.index = index
        self.role = role
        self.name = name or f"shard{index}"
        self.proc: Optional[subprocess.Popen] = None
        self.address: Optional[Tuple[str, int]] = None
        #: every carrier the shard serves, as endpoint urls (PR 6); a
        #: pre-endpoint child that reports only host/port degrades to
        #: its tcp url, so mixed-version supervision keeps working
        self.endpoints: List[str] = []
        self._stderr_tail: deque = deque(maxlen=200)
        self._spawn(host, port, replicate_to, ack, quorum_timeout)

    def _spawn(self, host: str, port: int,
               replicate_to: Sequence[Sequence[str]], ack: str,
               quorum_timeout: float) -> None:
        env = os.environ.copy()
        # children must import repro even when the parent runs from an
        # uninstalled checkout
        src_root = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
        argv = [sys.executable, "-m", "repro.core.kvcluster",
                "--serve-shard", "--host", host, "--port", str(port),
                "--name", self.name, "--shard-index", str(self.index),
                "--ack", ack, "--quorum-timeout", str(quorum_timeout)]
        if self.role == "replica":
            argv.append("--replica")
        for urls in replicate_to:
            argv += ["--replicate-to", ",".join(urls)]
        self.proc = subprocess.Popen(
            argv,
            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, env=env, text=True)
        threading.Thread(target=self._drain_stderr, daemon=True,
                         name=f"kvshard{self.index}-stderr").start()
        line: List[str] = []

        def read_handshake() -> None:
            line.append(self.proc.stdout.readline())

        t = threading.Thread(target=read_handshake, daemon=True,
                             name=f"kvshard{self.index}-handshake")
        t.start()
        t.join(_SPAWN_TIMEOUT_S)
        words = line[0].split() if line and line[0] else []
        if len(words) < 3 or words[0] != "KVSHARD":
            self.terminate()
            raise RuntimeError(
                f"kv shard {self.index} failed to start "
                f"(got {line[0]!r} on stdout)\n{self.stderr_tail()}"
                if line else
                f"kv shard {self.index} did not report an address within "
                f"{_SPAWN_TIMEOUT_S}s\n{self.stderr_tail()}")
        self.address = (words[1], int(words[2]))
        self.endpoints = words[3:] or [f"tcp://{words[1]}:{words[2]}"]

    def _drain_stderr(self) -> None:
        # keep the pipe drained (a crashing child must not wedge writing
        # its traceback) and keep the tail for diagnostics
        proc = self.proc
        try:
            for ln in proc.stderr:
                self._stderr_tail.append(ln)
        except ValueError:
            pass  # pipe closed during teardown

    def stderr_tail(self) -> str:
        return "".join(self._stderr_tail)

    def alive(self) -> bool:
        return self.proc is not None and self.proc.poll() is None

    def kill(self) -> None:
        """SIGKILL the child (the chaos harness's primary weapon): no
        orderly shutdown, no uds unlink by the child — exactly a crash.
        Stale rendezvous paths are removed here in the parent."""
        proc = self.proc
        if proc is None:
            return
        try:
            proc.kill()
        except OSError:
            pass
        try:
            proc.wait(timeout=5)
        except subprocess.TimeoutExpired:  # pragma: no cover
            pass
        self._remove_stale_paths()

    def terminate(self, grace_s: float = 5.0) -> None:
        proc = self.proc
        if proc is None:
            return
        try:
            if proc.stdin:
                proc.stdin.close()  # EOF = orderly shutdown request
        except OSError:
            pass
        try:
            proc.wait(timeout=grace_s)
        except subprocess.TimeoutExpired:
            proc.terminate()
            try:
                proc.wait(timeout=grace_s)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait()
        self._remove_stale_paths()

    def _remove_stale_paths(self) -> None:
        """Unlink the dead child's uds rendezvous socket (and its temp
        dir). An orderly child removes them itself in ``KVServer.stop``;
        this covers SIGKILL/crash so a respawned shard — or a client
        walking the old descriptor — never trips over a stale path
        (connecting to one fails with ECONNREFUSED, which the endpoint
        fallback turns into a silent downgrade to tcp; removing the
        corpse keeps the preference order honest)."""
        for url in self.endpoints:
            try:
                ep = _transport.parse_endpoint(url)
            except ValueError:
                continue
            if ep.scheme != "uds" or not ep.path:
                continue
            for path in (ep.path, os.path.dirname(ep.path)):
                try:
                    (os.rmdir if os.path.isdir(path) else os.unlink)(path)
                except OSError:
                    pass


class KVCluster:
    """N ``KVServer`` shard processes + a control endpoint, supervised.

    Use as a context manager (or ``start()``/``stop()``)::

        with KVCluster(shards=4) as cluster:
            client = cluster.client()          # a ClusterClient
            ...                                # or ClusterClient(cluster.address)

    ``address`` is the control endpoint; clients bootstrap from it alone
    (see module docstring for the handshake). Shard stores are empty on
    (re)start — a restarted shard loses its partition's data, exactly
    like a crashed cache node, so ``restart_shard`` is explicit rather
    than automatic.
    """

    def __init__(self, shards: int = 2, host: str = "127.0.0.1",
                 control_port: int = 0, hash_seed: int = 0,
                 replicas: int = 0, ack: str = "primary",
                 watchdog: bool = False, heartbeat_s: float = 0.5,
                 quorum_timeout: float = 5.0,
                 lease_sweep_s: float = 0.0):
        if shards < 1:
            raise ValueError("need at least one shard")
        if replicas < 0:
            raise ValueError("replicas must be >= 0")
        if ack not in ("primary", "quorum"):
            raise ValueError(f"unknown ack policy {ack!r}")
        self.n_shards = int(shards)
        self.host = host
        self.hash_seed = hash_seed
        self.replicas = int(replicas)
        self.ack = ack
        self.watchdog = bool(watchdog)
        self.heartbeat_s = float(heartbeat_s)
        self.quorum_timeout = float(quorum_timeout)
        self.lease_sweep_s = float(lease_sweep_s)
        self._sweep_thread: Optional[threading.Thread] = None
        self._sweep_stop = threading.Event()
        self._sweep_client: Optional["ClusterClient"] = None
        self._control_port = control_port
        self._procs: List[_ShardProc] = []
        self._replicas: List[List[_ShardProc]] = []
        self._epoch = 1
        self._topo_lock = threading.RLock()
        self._watchdog_thread: Optional[threading.Thread] = None
        self._watchdog_stop = threading.Event()
        self._control: Optional[KVServer] = None
        self._started = False

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "KVCluster":
        if self._started:
            return self
        try:
            for i in range(self.n_shards):
                # append as we go: if a later spawn fails, _teardown must
                # reach the shards already running. Replicas spawn first
                # (the primary needs their endpoints to start streaming).
                reps: List[_ShardProc] = []
                self._replicas.append(reps)
                for j in range(self.replicas):
                    reps.append(_ShardProc(i, self.host, 0,
                                           name=f"shard{i}r{j}",
                                           role="replica"))
                self._procs.append(_ShardProc(
                    i, self.host, 0, name=f"shard{i}",
                    replicate_to=[r.endpoints for r in reps],
                    ack=self.ack, quorum_timeout=self.quorum_timeout))
            store = KVStore(name="cluster-control")
            store.set(DESCRIPTOR_KEY, self.describe())
            self._control = KVServer(store, host=self.host,
                                     port=self._control_port).start()
        except BaseException:
            self._teardown()
            raise
        self._started = True
        if self.watchdog:
            self._watchdog_stop.clear()
            self._watchdog_thread = threading.Thread(
                target=self._watch, daemon=True, name="kvcluster-watchdog")
            self._watchdog_thread.start()
        if self.lease_sweep_s > 0:
            self._sweep_stop.clear()
            self._sweep_thread = threading.Thread(
                target=self._lease_sweep, daemon=True,
                name="kvcluster-lease-sweep")
            self._sweep_thread.start()
        return self

    def stop(self) -> None:
        self._started = False
        self._watchdog_stop.set()
        self._sweep_stop.set()
        if self._watchdog_thread is not None:
            self._watchdog_thread.join(timeout=2 * self.heartbeat_s + 5)
            self._watchdog_thread = None
        if self._sweep_thread is not None:
            self._sweep_thread.join(timeout=2 * self.lease_sweep_s + 5)
            self._sweep_thread = None
        if self._sweep_client is not None:
            try:
                self._sweep_client.close()
            except Exception:
                pass
            self._sweep_client = None
        self._teardown()

    def _teardown(self) -> None:
        if self._control is not None:
            self._control.stop()
            self._control = None
        for p in self._procs:
            p.terminate()
        self._procs = []
        for reps in self._replicas:
            for r in reps:
                r.terminate()
        self._replicas = []

    def __enter__(self) -> "KVCluster":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- topology ------------------------------------------------------------

    @property
    def address(self) -> Tuple[str, int]:
        """Control endpoint: the ONE address clients bootstrap from."""
        if self._control is None:
            raise RuntimeError("cluster is not started")
        return self._control.address

    @property
    def shard_addresses(self) -> List[Tuple[str, int]]:
        return [p.address for p in self._procs]

    @property
    def shard_endpoints(self) -> List[List[str]]:
        """Per-shard endpoint urls, every carrier the shard serves."""
        return [list(p.endpoints) for p in self._procs]

    def describe(self) -> Dict[str, Any]:
        """The cluster descriptor served under :data:`DESCRIPTOR_KEY`.

        Version 3 (PR 7): ``"epoch"`` is a monotonically increasing
        topology version bumped on every promotion or restart — clients
        compare it to decide whether a refetch changed anything;
        ``"replicas"`` carries one endpoint-url list per replica per
        shard and ``"ack"`` names the acknowledgement policy. Version 2
        (PR 6) added ``"endpoints"`` (one url list per shard, tcp/uds/
        shm); ``"shards"`` keeps the bare host/port pairs so pre-endpoint
        clients bootstrap unchanged."""
        with self._topo_lock:
            return {
                "version": 3,
                "epoch": self._epoch,
                "shards": [list(p.address) for p in self._procs],
                "endpoints": self.shard_endpoints,
                "replicas": [[list(r.endpoints) for r in reps]
                             for reps in self._replicas],
                "ack": self.ack,
                "n_shards": len(self._procs),
                "hash": "fnv1a-hashtag",
                "hash_seed": self.hash_seed,
            }

    def _republish(self) -> None:
        """Push the current descriptor to the control store (clients
        refetch it on redirect or connection death)."""
        if self._control is not None:
            self._control.store.set(DESCRIPTOR_KEY, self.describe())

    def client(self, **kwargs: Any) -> "ClusterClient":
        if not self._started:
            raise RuntimeError("cluster is not started")
        # hand the control address too so the client can refresh its
        # view of the topology after a promotion or restart
        return ClusterClient(address=self.address,
                             shard_addresses=self.shard_endpoints,
                             hash_seed=self.hash_seed, **kwargs)

    # -- supervision ---------------------------------------------------------

    def poll(self) -> List[bool]:
        """Per-shard liveness, in shard order."""
        return [p.alive() for p in self._procs]

    def ensure_alive(self) -> None:
        """Raise RuntimeError naming any dead shard, with its stderr tail."""
        dead = [p for p in self._procs if not p.alive()]
        if dead:
            detail = "; ".join(
                f"shard {p.index} exited with code {p.proc.returncode}"
                for p in dead)
            tails = "\n".join(t for t in (p.stderr_tail() for p in dead) if t)
            raise RuntimeError(f"kv cluster degraded: {detail}"
                               + (f"\n{tails}" if tails else ""))

    def kill_shard(self, index: int) -> None:
        """SIGKILL shard ``index``'s primary (chaos-harness hook). The
        watchdog — or an explicit ``promote_shard``/``supervise_once`` —
        is responsible for recovery."""
        self._procs[index].kill()

    def kill_replica(self, index: int, replica: int = 0) -> None:
        """SIGKILL one replica of shard ``index`` (chaos-harness hook)."""
        self._replicas[index][replica].kill()

    def promote_shard(self, index: int) -> Tuple[str, int]:
        """Fail shard ``index`` over to its freshest live replica.

        Picks the replica with the highest applied sequence (ties broken
        by replica order), tells it to become a primary (it seeds its
        replication log from its retained entries and attaches the
        surviving peers, which catch up from their own positions), bumps
        the topology epoch and republishes the descriptor. Returns the
        new primary's address. Raises RuntimeError when no live replica
        exists — that shard's partition is lost and only an explicit
        ``restart_shard`` (empty store) can bring it back."""
        with self._topo_lock:
            old = self._procs[index]
            old.kill()  # no-op on a corpse, but always clears stale paths
            reps = self._replicas[index]
            infos = []
            for r in reps:
                if not r.alive():
                    continue
                try:
                    c = KVClient(r.endpoints)
                    try:
                        info = c.repl_info()
                    finally:
                        c.close()
                except Exception:
                    continue
                infos.append((int(info.get("seq", 0)), r))
            if not infos:
                raise RuntimeError(
                    f"shard {index}: no live replica to promote "
                    f"(primary stderr: {old.stderr_tail()!r})")
            # freshest replica wins; key= because _ShardProc is unorderable
            infos.sort(key=lambda t: t[0], reverse=True)
            _, winner = infos[0]
            reps.remove(winner)
            peers = [list(r.endpoints) for r in reps if r.alive()]
            self._epoch += 1
            c = KVClient(winner.endpoints)
            try:
                c.repl_promote(peers=peers, ack=self.ack,
                               quorum_timeout=self.quorum_timeout,
                               epoch=self._epoch)
            finally:
                c.close()
            winner.role = "primary"
            self._procs[index] = winner
            self._republish()
            return winner.address

    def supervise_once(self) -> bool:
        """One supervision pass: promote any dead primary, detach any
        dead replica from its primary's streamer set. Returns True when
        the pass changed the topology (and republished)."""
        changed = False
        with self._topo_lock:
            for i, p in enumerate(self._procs):
                if not p.alive():
                    try:
                        self.promote_shard(i)
                        changed = True
                    except RuntimeError:
                        sys.stderr.write(
                            f"[kvcluster] shard {i} is down and has no "
                            "promotable replica\n")
            for i, reps in enumerate(self._replicas):
                dead = [r for r in reps if not r.alive()]
                for r in dead:
                    reps.remove(r)
                    self._detach_replica(i, r)
                    changed = True
            if changed:
                self._republish()
        return changed

    def _detach_replica(self, index: int, rep: "_ShardProc") -> None:
        """Tell shard ``index``'s primary to stop streaming to a dead
        replica (under quorum ack this shrinks the vote set — a degraded
        primary keeps accepting writes rather than stalling forever)."""
        primary = self._procs[index]
        if not primary.alive():
            return
        try:
            c = KVClient(primary.endpoints)
            try:
                c.repl_detach(list(rep.endpoints))
            finally:
                c.close()
        except Exception:
            pass  # primary died between the liveness check and the call

    def _watch(self) -> None:
        """Watchdog loop (``watchdog=True``): heartbeat liveness checks
        every ``heartbeat_s`` seconds, promoting/detaching as needed."""
        while not self._watchdog_stop.wait(self.heartbeat_s):
            try:
                self.supervise_once()
            except Exception as exc:  # pragma: no cover - defensive
                sys.stderr.write(f"[kvcluster] watchdog pass failed: "
                                 f"{exc!r}\n")

    def lease_sweep_once(self) -> int:
        """One pass of the cluster-side lease reaper: walk the
        :data:`~repro.core.kvstore.LEASE_REGISTRY_KEY` registrations
        (one per lease-enabled ``Pool``) and ``lease_reap`` each
        registered in-flight hash, re-enqueueing expired leases onto
        their source queue (attempt bumped) or dead-lettering exhausted
        ones. This is the safety net for POOLS WHOSE OWNER DIED — a live
        pool's supervisor reaps its own leases faster; for a dead owner
        this sweep is the only thing that stops its orphaned leases from
        pinning tasks forever. Registrations are never pruned here (only
        ``Pool.close`` unregisters): a stale entry costs one no-op reap
        per pass. Returns the number of entries reclaimed."""
        if self._sweep_client is None:
            self._sweep_client = self.client()
        client = self._sweep_client
        reclaimed = 0
        registry = client.hgetall(LEASE_REGISTRY_KEY) or {}
        for dst, spec in registry.items():
            try:
                src, max_attempts, dead_key = spec
                requeued, dead = client.lease_reap(
                    dst, src, max_attempts, None, dead_key)
            except (ConnectionError, OSError, ValueError, TypeError):
                continue  # shard mid-failover or malformed registration
            reclaimed += len(requeued) + len(dead)
        return reclaimed

    def _lease_sweep(self) -> None:
        """Reaper loop (``lease_sweep_s > 0``)."""
        while not self._sweep_stop.wait(self.lease_sweep_s):
            try:
                self.lease_sweep_once()
            except Exception as exc:  # pragma: no cover - defensive
                sys.stderr.write(f"[kvcluster] lease sweep failed: "
                                 f"{exc!r}\n")

    def restart_shard(self, index: int) -> Tuple[str, int]:
        """Respawn shard ``index`` on a FRESH ephemeral OS-assigned port
        and republish the descriptor. Rebinding the previous fixed port
        was a race — the dead child's socket can linger (TIME_WAIT, or
        the OS hands the port to someone else between death and respawn),
        which made the CI cluster smoke flaky with retry-on-EADDRINUSE
        noise. Ephemeral binding cannot collide; the cost is that
        already-bootstrapped clients must re-bootstrap from the control
        endpoint (which always serves the current descriptor). The
        shard's partition restarts EMPTY — callers own the data-loss
        consequences, which is why restart is explicit. When the cluster
        runs with replicas the old replica set is torn down and a fresh
        one spawned (their logs describe the dead primary's history —
        useless to the empty respawn). Bumps the topology epoch and
        republishes; returns the shard's new address."""
        with self._topo_lock:
            old = self._procs[index]
            host = old.address[0] if old.address else self.host
            old.terminate()
            for r in self._replicas[index]:
                r.terminate()
            reps: List[_ShardProc] = []
            for j in range(self.replicas):
                reps.append(_ShardProc(index, host, 0,
                                       name=f"shard{index}r{j}",
                                       role="replica"))
            self._replicas[index] = reps
            self._procs[index] = _ShardProc(
                index, host, 0, name=f"shard{index}",
                replicate_to=[r.endpoints for r in reps],
                ack=self.ack, quorum_timeout=self.quorum_timeout)
            self._epoch += 1
            self._republish()
            return self._procs[index].address


# ---------------------------------------------------------------------------
# Cluster client
# ---------------------------------------------------------------------------


class _FailoverShard:
    """Stable per-index shard handle held in ``ClusterClient.shards``.

    The router (``_ShardRouter``) keeps references to ``self.shards[i]``
    across calls — including inside a parked blocking pop — so the entry
    for shard ``i`` must survive a failover. The proxy is that stable
    identity: it looks up the CURRENT ``KVClient`` for its index on every
    command (``owner._clients[index]``, rebound by ``refresh``) and
    routes through ``owner._shard_call``, which owns redirect handling,
    refresh-on-disconnect and the bounded retry policy."""

    __slots__ = ("_owner", "index")

    def __init__(self, owner: "ClusterClient", index: int):
        self._owner = owner
        self.index = index

    @property
    def mux_enabled(self) -> bool:
        return self._owner._clients[self.index].mux_enabled

    def __getattr__(self, cmd: str):
        if cmd.startswith("_"):
            raise AttributeError(cmd)
        owner, index = self._owner, self.index

        def call(*args: Any, **kwargs: Any) -> Any:
            return owner._shard_call(index, cmd, args, kwargs)
        call.__name__ = cmd
        return call

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"_FailoverShard({self.index})"


class ClusterClient(_ShardRouter):
    """The ``KVClient`` method surface, hash-routed over cluster shards.

    Bootstraps from a single control ``address`` (one descriptor GET) or
    from explicit ``shard_addresses``. Single-key commands are one
    command on one shard; multi-key commands split per shard; pipeline
    batches flush as concurrent per-shard ``execute_batch`` frames
    (scatter/gather — see ``execute_batch``). The ``shards`` attribute
    holds one ``_FailoverShard`` handle per shard, which is also what the
    IPC layer's ``hasattr(store, "shards")`` probes key on to pass
    transaction key hints.

    Failover (PR 7): when a command hits a replica redirect or the shard
    connection dies, the client refetches the cluster descriptor from
    the control ``address`` and rebinds the affected shard's connection.
    Redirected commands were never executed and always retry; commands
    that may have executed retry only when idempotent (``_RETRY_SAFE``),
    with exponential backoff bounded by ``failover_timeout_s``.
    Everything else surfaces as :class:`ShardUnavailableError` carrying
    the shard index and last-seen descriptor epoch, so callers (e.g. the
    executor's collector) can refresh and re-issue deliberately. A
    client built from a bare ``shard_addresses`` list has no control
    endpoint to refresh from and fails fast with the typed error.
    """

    def __init__(self, address: Any = None,
                 shard_addresses: Optional[Sequence[Any]] = None,
                 legacy_protocol: Any = _copts.UNSET, hash_seed: int = 0,
                 mux: Any = _copts.UNSET, raw: Any = _copts.UNSET,
                 transport: Any = _copts.UNSET,
                 failover_timeout_s: Any = _copts.UNSET,
                 options: Optional[_copts.ClientOptions] = None):
        # Unified construction surface: the historical kwargs are
        # aliases over one ClientOptions (see repro.core.clientopts).
        opts = _copts.resolve_client_options(
            options, legacy_protocol=legacy_protocol, mux=mux, raw=raw,
            transport=transport, failover_timeout_s=failover_timeout_s)
        self.options = opts
        self._control_address = address
        self._legacy = opts.legacy_protocol
        self._mux_opt = opts.mux
        self._raw_opt = opts.raw
        self.transport = opts.transport
        self.failover_timeout_s = float(opts.failover_timeout_s)
        self._desc_epoch = 0
        self._refresh_lock = threading.Lock()
        self._clients: List[KVClient] = []
        self._client_keys: List[Tuple[str, ...]] = []
        if shard_addresses is None:
            if address is None:
                raise ValueError("need a control address or shard addresses")
            desc = self._fetch_descriptor()
            shard_addresses = (desc.get("endpoints")
                               or [tuple(a) for a in desc["shards"]])
            hash_seed = desc.get("hash_seed", hash_seed)
            self._desc_epoch = desc.get("epoch", 0)
        if not shard_addresses:
            raise ValueError("need at least one shard address")
        self.hash_seed = hash_seed
        self._bind(shard_addresses)
        self.shards = [_FailoverShard(self, i)
                       for i in range(len(self._clients))]
        # client-side counters only (server-side metrics live per shard and
        # are readable via info()): fanout records scatter widths, which no
        # single shard can observe
        self.metrics = Metrics()
        self.name = f"cluster[{len(self.shards)}]"

    # -- topology refresh ----------------------------------------------------

    def _fetch_descriptor(self) -> Dict[str, Any]:
        boot = KVClient(self._control_address)
        try:
            desc = boot.get(DESCRIPTOR_KEY)
        finally:
            boot.close()
        if not isinstance(desc, dict) or "shards" not in desc:
            raise ConnectionError(
                f"{self._control_address!r} is not a cluster control "
                "endpoint (no descriptor; use KVClient for a plain "
                "KVServer)")
        return desc

    def _bind(self, shard_addresses: Sequence[Any]) -> None:
        """(Re)bind per-shard ``KVClient`` connections.

        Shards at the same address share ONE KVClient (hence one mux
        connection): their scatter sub-batches coalesce into one frame.
        Co-residency is keyed on the NORMALIZED endpoint set, so two
        entries naming the same server through any address shape still
        share a client. On a rebind, shards whose endpoint set did not
        change KEEP their existing client — a parked blocking pop on a
        healthy shard must survive another shard's failover — and
        clients whose endpoints vanished from the topology are closed
        (resolving their pending futures with ``ConnectionError``)."""
        by_key: Dict[Tuple[str, ...], KVClient] = {}
        for key, cl in zip(self._client_keys, self._clients):
            by_key.setdefault(key, cl)
        new_clients: List[KVClient] = []
        new_keys: List[Tuple[str, ...]] = []
        for a in shard_addresses:
            eps = _transport.normalize_endpoints(a)
            key = tuple(sorted(e.url for e in eps))
            if key not in by_key:
                by_key[key] = KVClient(eps, options=self.options)
            new_clients.append(by_key[key])
            new_keys.append(key)
        live = set(new_keys)
        stale = {id(cl): cl
                 for key, cl in zip(self._client_keys, self._clients)
                 if key not in live}
        self._clients, self._client_keys = new_clients, new_keys
        for cl in stale.values():
            try:
                cl.close()
            except Exception:
                pass

    def refresh(self, force: bool = False) -> bool:
        """Refetch the cluster descriptor and rebind changed shards.

        Returns True when the topology changed (the descriptor epoch
        moved, or ``force`` re-applied it). No-op (returns False) for
        clients built from a bare shard list — they have no control
        endpoint to ask."""
        if self._control_address is None:
            return False
        with self._refresh_lock:
            desc = self._fetch_descriptor()
            epoch = desc.get("epoch", 0)
            if not force and epoch == self._desc_epoch:
                return False
            shard_addresses = (desc.get("endpoints")
                               or [tuple(a) for a in desc["shards"]])
            self._bind(shard_addresses)
            self.hash_seed = desc.get("hash_seed", self.hash_seed)
            self._desc_epoch = epoch
            if len(self.shards) != len(self._clients):
                self.shards = [_FailoverShard(self, i)
                               for i in range(len(self._clients))]
            return True

    def _try_refresh(self, force: bool = False) -> bool:
        """Best-effort refresh: a briefly unreachable control endpoint
        must not mask the original shard failure."""
        try:
            return self.refresh(force=force)
        except Exception:
            return False

    # -- per-command failover ------------------------------------------------

    def _shard_call(self, index: int, cmd: str, args: tuple,
                    kwargs: dict) -> Any:
        deadline = time.monotonic() + self.failover_timeout_s
        delay = _RETRY_MIN_BACKOFF_S
        while True:
            client = self._clients[index]
            try:
                return client._call(cmd, *args, **kwargs)
            except ShardRedirectError:
                # the replica refused without executing: always safe to
                # retry once the descriptor names the new primary
                self._try_refresh(force=True)
            except ShardUnavailableError:
                raise  # server-side quorum verdict; not ours to retry
            except EndpointConnectError as exc:
                # no byte left the client: retry regardless of
                # idempotence once the descriptor names a live primary —
                # unless there is no control endpoint to refresh from
                if self._control_address is None:
                    raise ShardUnavailableError(
                        f"shard {index}: {cmd} failed ({exc!r}) and this "
                        "client has no control endpoint to refresh from",
                        shard=index,
                        descriptor_version=self._desc_epoch) from exc
                self._try_refresh(force=True)
            except (ConnectionError, OSError) as exc:
                if (self._control_address is None
                        or not _retry_safe(cmd, args, kwargs)):
                    self._try_refresh(force=True)  # help the NEXT call
                    raise ShardUnavailableError(
                        f"shard {index}: {cmd} failed ({exc!r}) and is "
                        "not safe to retry automatically",
                        shard=index,
                        descriptor_version=self._desc_epoch) from exc
                self._try_refresh(force=True)
            if time.monotonic() >= deadline:
                raise ShardUnavailableError(
                    f"shard {index}: {cmd} retries exhausted after "
                    f"{self.failover_timeout_s:.1f}s",
                    shard=index, descriptor_version=self._desc_epoch)
            time.sleep(delay)
            delay = min(delay * 2, _RETRY_MAX_BACKOFF_S)

    def execute_batch(self, commands: List[Tuple[str, tuple, dict]]
                      ) -> List[Tuple[bool, Any]]:
        """Scatter/gather batch: route commands per shard
        (``_route_batch``, which preserves submission order around
        multi-key commands), ENQUEUE every shard's ``execute_batch`` on
        its mux, flush each involved connection once, then gather the
        per-shard futures. The flushes overlap on the wire and in the
        shard processes, so N involved shards cost ~one wall-clock round
        trip instead of N; concurrent threads' scatters group-commit into
        the same per-shard frames, and co-resident shard batches (one
        connection) coalesce into one frame.

        Framing safety under errors matches the single-connection
        pipeline contract: every scattered batch's future is awaited even
        when another shard fails, so no connection is left holding an
        uncorrelated response; a connection that dies is torn down by its
        mux (every pending future resolves with the error) and is
        re-established on next use.

        Failover: a scatter that hits a replica redirect or a dead
        connection retries the WHOLE batch (after a descriptor refresh)
        only when every command in it is idempotent — a partial scatter
        may already have executed some shards' sub-batches, so a batch
        containing a non-idempotent command surfaces
        :class:`ShardUnavailableError` instead."""
        cmds = [_debatch(c) for c in commands]
        retryable = (self._control_address is not None
                     and all(_retry_safe(c, a, k) for c, a, k in cmds))
        deadline = time.monotonic() + self.failover_timeout_s
        delay = _RETRY_MIN_BACKOFF_S
        while True:
            try:
                return self._route_batch(cmds, self._scatter_groups)
            except ShardUnavailableError:
                raise
            except (ShardRedirectError, ConnectionError, OSError) as exc:
                self._try_refresh(force=True)
                if not retryable:
                    raise ShardUnavailableError(
                        f"batch of {len(cmds)} failed ({exc!r}) and "
                        "contains non-idempotent commands",
                        descriptor_version=self._desc_epoch) from exc
            if time.monotonic() >= deadline:
                raise ShardUnavailableError(
                    f"batch retries exhausted after "
                    f"{self.failover_timeout_s:.1f}s",
                    descriptor_version=self._desc_epoch)
            time.sleep(delay)
            delay = min(delay * 2, _RETRY_MAX_BACKOFF_S)

    def _scatter_groups(self, groups, out) -> None:
        self.metrics.record_fanout(len(groups))
        if not all(self._clients[idx].mux_enabled for idx in groups):
            return self._scatter_groups_sockets(groups, out)
        first_err: Optional[BaseException] = None
        pending = []
        flushes = []
        # Phase 1: merge shard groups per CONNECTION (co-resident shards
        # share a client/mux — their sub-batches become one frame) and
        # enqueue each connection's batch without flushing yet.
        by_mux: Dict[int, List[int]] = {}
        for idx in sorted(groups):
            by_mux.setdefault(id(self._clients[idx]), []).append(idx)
        for idxs in by_mux.values():
            client = self._clients[idxs[0]]
            numbered = [nc for idx in idxs for nc in groups[idx]]
            cmds = [c for _, c in numbered]
            try:
                fut = client._mux().submit(
                    "batch", ("execute_batch", (cmds,), {}),
                    ncmds=len(cmds), flush=False, coalesce=False)
            except Exception as exc:
                if first_err is None:
                    first_err = exc
                continue
            flushes.append(fut)
            pending.append((fut, numbered))
        # Phase 2: one flush per involved connection (the scatter). The
        # flush is keyed on that connection's pending: if another
        # thread's flat-combining leader already shipped our frame, this
        # returns without ever contending the write lock.
        for fut in flushes:
            try:
                fut.mux.flush(fut)
            except Exception as exc:  # pragma: no cover - submit raised first
                if first_err is None:
                    first_err = exc
        # Phase 3: gather. Every future is awaited — a shard error never
        # leaves another shard's response unconsumed.
        for fut, numbered in pending:
            ok, value = fut.result()
            if not ok:
                if first_err is None:
                    first_err = value
                continue
            for (i, _), res in zip(numbered, value):
                out[i] = res
        if first_err is not None:
            raise first_err

    def _scatter_groups_sockets(self, groups, out) -> None:
        """PR 3 transport (``mux=False``/legacy): write every shard's
        frame on this thread's per-shard socket before reading any
        response, then drain — kept for A/B benchmarking."""
        first_err: Optional[BaseException] = None
        pending = []
        for idx in sorted(groups):
            client = self._clients[idx]
            try:
                sock = client._sock()
                _sendv(sock, client._request_frames(
                    ("execute_batch", ([c for _, c in groups[idx]],), {})))
            except Exception as exc:
                if first_err is None:
                    first_err = exc
                # a partial frame would desync this thread's connection;
                # other threads' sockets to the shard are untouched
                client.close_connection()
                continue
            pending.append((client, sock, groups[idx]))
        for client, sock, numbered in pending:
            try:
                ok, value = client._read_response(sock)
            except Exception as exc:
                if first_err is None:
                    first_err = exc
                client.close_connection()  # mid-frame state is unrecoverable
                continue
            if not ok:
                if first_err is None:
                    first_err = value
                continue
            for (i, _), res in zip(numbered, value):
                out[i] = res
        if first_err is not None:
            raise first_err

    def close(self) -> None:
        seen = set()
        for c in self._clients:
            if id(c) not in seen:  # co-resident shards share one client
                seen.add(id(c))
                c.close()


def connect(address: Any, legacy_protocol: Any = _copts.UNSET,
            transport: Any = _copts.UNSET, mux: Any = _copts.UNSET,
            raw: Any = _copts.UNSET,
            failover_timeout_s: Any = _copts.UNSET,
            options: Optional[_copts.ClientOptions] = None
            ) -> Union[KVClient, "ClusterClient"]:
    """Bootstrap from one address: a cluster control endpoint answers the
    descriptor GET and yields a ``ClusterClient``; a plain ``KVServer``
    answers None and a ``KVClient`` is returned. ``address`` takes any
    shape ``KVClient`` does — a ``(host, port)`` tuple, an endpoint url,
    or a url list.

    Configuration rides one :class:`~repro.core.clientopts.ClientOptions`
    (``options=``, with the historical kwargs kept as aliases — see that
    module for the conflict rules). ``transport`` pins the SHARD/server
    carriers; the bootstrap GET itself uses whatever ``address``
    advertises (a bare control tuple is tcp-only, and pinning one
    round trip buys nothing)."""
    opts = _copts.resolve_client_options(
        options, legacy_protocol=legacy_protocol, transport=transport,
        mux=mux, raw=raw, failover_timeout_s=failover_timeout_s)
    client = KVClient(address, options=opts.replace(transport=None))
    try:
        desc = client.get(DESCRIPTOR_KEY)
    except Exception:
        client.close()
        raise
    if isinstance(desc, dict) and "shards" in desc:
        client.close()
        # the control address rides along so the client can refetch the
        # descriptor after a failover
        return ClusterClient(
            address=address,
            shard_addresses=(desc.get("endpoints")
                             or [tuple(a) for a in desc["shards"]]),
            hash_seed=desc.get("hash_seed", 0),
            options=opts)
    if opts.transport is not None:
        # plain server: re-open with the pin (raises if unadvertised)
        client.close()
        return KVClient(address, options=opts)
    return client


# ---------------------------------------------------------------------------
# Shard child entry point
# ---------------------------------------------------------------------------


def _serve_shard(host: str, port: int, name: str, replica: bool = False,
                 replicate_to: Sequence[Sequence[str]] = (),
                 ack: str = "primary", quorum_timeout: float = 5.0,
                 shard_index: int = -1) -> int:
    server = KVServer(KVStore(name=name), host=host, port=port,
                      replica=replica, shard_index=shard_index)
    server.start()
    for urls in replicate_to:
        server.attach_replica(list(urls), ack=ack,
                              quorum_timeout=quorum_timeout)
    # host/port first (pre-endpoint parents read exactly those), then
    # every endpoint url the server actually serves
    sys.stdout.write(f"KVSHARD {server.address[0]} {server.address[1]} "
                     + " ".join(server.endpoints) + "\n")
    sys.stdout.flush()
    try:
        sys.stdin.read()  # parent holds our stdin; EOF means shut down
    except (KeyboardInterrupt, OSError):
        pass
    server.stop()
    return 0


def _main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="KV cluster shard process (spawned by KVCluster)")
    ap.add_argument("--serve-shard", action="store_true", required=True)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--name", default="shard")
    ap.add_argument("--shard-index", type=int, default=-1)
    ap.add_argument("--replica", action="store_true",
                    help="start in replica mode (mutators redirect)")
    ap.add_argument("--replicate-to", action="append", default=[],
                    metavar="URLS",
                    help="comma-joined endpoint urls of one replica; "
                         "repeat per replica")
    ap.add_argument("--ack", default="primary",
                    choices=("primary", "quorum"))
    ap.add_argument("--quorum-timeout", type=float, default=5.0)
    args = ap.parse_args(argv)
    return _serve_shard(
        args.host, args.port, args.name, replica=args.replica,
        replicate_to=[u.split(",") for u in args.replicate_to],
        ack=args.ack, quorum_timeout=args.quorum_timeout,
        shard_index=args.shard_index)


if __name__ == "__main__":
    sys.exit(_main())
