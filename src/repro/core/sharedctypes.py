"""Shared memory abstractions over KV values (paper §3.2 "Shared state").

``Array``/``Value`` hold only basic C-typed scalars. Two storage layouts
are available per array via ``layout=``; they trade paper fidelity
against remote round trips:

* ``layout="block"`` (default) — elements are struct-packed
  (little-endian) into fixed-size binary segments of ``SEGMENT_BYTES``
  stored as KV string values, addressed with byte-range commands.
  Cost model:

  - single element read / write  -> 1 GETRANGE / 1 SETRANGE
  - slice read (any stride)      -> 1 MGET of the covered segments
  - slice write (any stride)     -> 1 MSETRANGE of coalesced byte runs
  - under the array's lock       -> ~0 commands after first touch: while
    ``with arr.get_lock():`` is held, reads are served from a local
    segment cache (misses fetched one MGET at a time) and writes are
    write-combined locally, then flushed as ONE MSETRANGE of the dirty
    byte runs at release (only bytes this scope stored — no segment
    write-back false sharing). Acquire invalidates the cache. This is
    release consistency
    — exactly the semantics holding the lock already promises — and it
    is what makes the paper's "did not finish remotely" in-place shared
    array sort (Table 3) complete: O(segments) commands instead of
    O(elements²).

* ``layout="list"`` — the paper-faithful layout: the array is a KV LIST,
  one element per index ("each element of the list will be at most
  sizeof(long double)"), so **every index access is one KV command**.
  Slice reads/writes map to LRANGE / per-index LSET inside one
  transaction. This is deliberately the cost model that makes the
  paper's in-place sort prohibitively slow remotely; it is kept for A/B
  measurement (``benchmarks/bench_sort.py`` runs both layouts).

Wire dialect: the block layout's whole command set — ``getrange`` /
``setrange`` / ``msetrange`` / ``strlen`` / ``mget`` / ``mset`` plus the
``expire``/``delete`` lifecycle — is raw-eligible
(``serialization.RAW_COMMANDS``), so single-element accesses and small
dirty-run flushes travel pickle-free over TCP (v4); segment-sized
(>= 4 KiB) values per command automatically take the pickle-5
out-of-band zero-copy path instead.
"""

from __future__ import annotations

import struct
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Union

from .reference import RemoteResource
from .synchronize import RLock

__all__ = ["Value", "Array", "RawValue", "RawArray", "typecode_to_type",
           "SEGMENT_BYTES"]

#: Bytes per block-layout segment. 4 KiB rides the serialization layer's
#: out-of-band threshold (segments cross the wire zero-copy) while keeping
#: single-segment fetches well under one bandwidth-dominated round trip.
SEGMENT_BYTES = 4096

# typecode -> (python cast, struct fmt) ; mirrors ctypes/array typecodes
_TYPECODES = {
    "b": int, "B": int, "h": int, "H": int, "i": int, "I": int,
    "l": int, "L": int, "q": int, "Q": int,
    "f": float, "d": float,
    "c": bytes,
}
typecode_to_type = {k: v for k, v in _TYPECODES.items()}


def _cast(typecode: str, v: Any) -> Any:
    if typecode == "c":
        # ctypes c_char semantics: a length-1 bytes/bytearray, or an int
        # in [0, 256). bytes(65) would silently yield 65 NUL bytes.
        if isinstance(v, int) and 0 <= v < 256:
            return bytes([v])
        if isinstance(v, (bytes, bytearray)) and len(v) == 1:
            return bytes(v)
        raise TypeError("one character bytes, bytearray or integer expected")
    py = _TYPECODES[typecode]
    v = py(v)
    if typecode in ("f",):  # round-trip float32 precision like ctypes
        v = struct.unpack("f", struct.pack("f", v))[0]
    return v


def _zero(typecode: str) -> Any:
    return b"\x00" if typecode == "c" else _cast(typecode, 0)


#: typecode -> struct format. Standard-size "<l"/"<L" are 4 bytes, but
#: ctypes c_long/c_ulong are 8 on LP64 — pack them as 8 bytes so every
#: value a native multiprocessing.Array("l") accepts fits, on every
#: worker architecture.
_STRUCT_FMT = {"l": "q", "L": "Q"}


class _Codec:
    """struct-based element <-> bytes packing for one typecode.

    Fixed little-endian layout so an array created on one architecture
    reads identically from any worker.
    """

    __slots__ = ("typecode", "itemsize", "_fmt", "_one")

    def __init__(self, typecode: str):
        self.typecode = typecode
        self._fmt = _STRUCT_FMT.get(typecode, typecode)
        self._one = struct.Struct("<" + self._fmt)
        self.itemsize = self._one.size

    def pack_one(self, v: Any) -> bytes:
        return self._one.pack(v)

    def unpack_one(self, buf: Any, offset: int = 0) -> Any:
        return self._one.unpack_from(buf, offset)[0]

    def pack_many(self, vals: Sequence[Any]) -> bytes:
        return struct.pack(f"<{len(vals)}{self._fmt}", *vals)

    def unpack_many(self, buf: Any, count: int, offset: int = 0) -> List[Any]:
        return list(struct.unpack_from(f"<{count}{self._fmt}", buf, offset))


# ---------------------------------------------------------------------------
# Backings: how an array's elements map onto KV commands
# ---------------------------------------------------------------------------


class _ListBacking:
    """Paper-faithful: one LIST element per index, one command per access."""

    layout = "list"

    def __init__(self, store: Any, keyfn: Callable[[str], str],
                 typecode: str, length: int):
        self._store = store
        self._typecode = typecode
        self._length = length
        self._data_key = keyfn("data")

    def initialize(self, vals: Sequence[Any]) -> None:
        self._store.rpush(self._data_key, *vals)

    def kv_keys(self) -> List[str]:
        return [self._data_key]

    def read_one(self, i: int) -> Any:
        return self._store.lindex(self._data_key, i)

    def read_slice(self, start: int, stop: int, step: int) -> List[Any]:
        idxs = range(start, stop, step)
        if not len(idxs):
            return []
        if step == 1:
            return self._store.lrange(self._data_key, start, stop - 1)
        batch = getattr(self._store, "execute_batch", None)
        if batch is not None and len(idxs) > 1:
            # strided read: one batched round trip, not one per index
            out = []
            for ok, v in batch([("lindex", (self._data_key, j), {})
                                for j in idxs]):
                if not ok:
                    raise v
                out.append(v)
            return out
        return [self._store.lindex(self._data_key, j) for j in idxs]

    def write_one(self, i: int, v: Any) -> None:
        self._store.lset(self._data_key, i, v)

    def write_slice(self, idxs: Sequence[int], vals: Sequence[Any]) -> None:
        data_key = self._data_key
        idxs, vals = list(idxs), list(vals)

        def txn(s):  # one atomic command batch (closes over plain data)
            for j, v in zip(idxs, vals):
                s.lset(data_key, j, v)
        if hasattr(self._store, "shards"):
            self._store.transaction(txn, key_hint=data_key)
        else:
            self._store.transaction(txn)

    # lock-scope hooks: the faithful layout has no client cache
    def cache_begin(self) -> None:
        pass

    def cache_end(self) -> None:
        pass


class _BlockBacking:
    """Struct-packed fixed-size segments + lock-scoped client cache."""

    layout = "block"

    def __init__(self, store: Any, keyfn: Callable[[str], str],
                 typecode: str, length: int):
        self._store = store
        self._keyfn = keyfn
        self._codec = _Codec(typecode)
        self._length = length
        self._eps = max(1, SEGMENT_BYTES // self._codec.itemsize)
        self._nsegs = -(-length // self._eps) if length else 0
        # lock-scoped cache: seg index -> local mutable copy of its bytes.
        # Scoped to the lock-HOLDING thread (recorded at cache_begin): a
        # sibling thread touching this proxy without the lock must bypass
        # the cache and go straight to the store — consulting another
        # thread's scope would race its invalidation/flush.
        # Dirtiness is tracked per element byte offset, not per segment:
        # the flush writes only bytes this scope actually stored, so it
        # cannot clobber a concurrent lock-free writer's elements that
        # merely share a segment (no write-back false sharing).
        self._cache: Dict[int, bytearray] = {}
        self._dirty: Dict[int, Set[int]] = {}  # seg -> dirty byte offsets
        self._owner_tid: Optional[int] = None

    def _cache_on(self) -> bool:
        return self._owner_tid == threading.get_ident()

    def _seg_key(self, k: int) -> str:
        return self._keyfn(f"seg:{k}")

    def _seg_nbytes(self, k: int) -> int:
        n_elems = min(self._eps, self._length - k * self._eps)
        return n_elems * self._codec.itemsize

    def kv_keys(self) -> List[str]:
        return [self._seg_key(k) for k in range(self._nsegs)]

    def initialize(self, vals: Sequence[Any]) -> None:
        blob = self._codec.pack_many(vals)
        seg_b = self._eps * self._codec.itemsize
        self._store.mset({self._seg_key(k): blob[k * seg_b:(k + 1) * seg_b]
                          for k in range(self._nsegs)})

    # -- segment materialization --------------------------------------------

    def _normalize(self, k: int, raw: Any) -> bytes:
        """Missing / short segment bytes read as zeros (a key that was only
        partially SETRANGEd, or expired under the TTL backstop)."""
        want = self._seg_nbytes(k)
        raw = bytes(raw or b"")
        return raw if len(raw) >= want else raw + b"\x00" * (want - len(raw))

    def _segments(self, segs: Sequence[int]) -> Dict[int, Any]:
        """Buffers for every segment in ``segs``: cache hits are free, all
        misses arrive in ONE MGET. In the lock-holder's scope, fetched
        segments stay cached (as mutable local copies) until release."""
        cache_on = self._cache_on()
        out: Dict[int, Any] = {}
        missing: List[int] = []
        for k in segs:
            buf = self._cache.get(k) if cache_on else None
            if buf is None:
                missing.append(k)
            else:
                out[k] = buf
        if missing:
            fetched = self._store.mget([self._seg_key(k) for k in missing])
            for k, raw in zip(missing, fetched):
                buf: Any = self._normalize(k, raw)
                if cache_on:
                    buf = bytearray(buf)
                    self._cache[k] = buf
                out[k] = buf
        return out

    # -- element access ------------------------------------------------------

    def read_one(self, i: int) -> Any:
        isz = self._codec.itemsize
        k, off = divmod(i, self._eps)
        if self._cache_on():
            return self._codec.unpack_one(self._segments([k])[k], off * isz)
        lo = off * isz
        raw = self._store.getrange(self._seg_key(k), lo, lo + isz - 1)
        if len(raw) < isz:
            raw = bytes(raw) + b"\x00" * (isz - len(raw))
        return self._codec.unpack_one(raw)

    def read_slice(self, start: int, stop: int, step: int) -> List[Any]:
        idxs = range(start, stop, step)
        if not len(idxs):
            return []
        isz = self._codec.itemsize
        segs = sorted({j // self._eps for j in idxs})
        bufs = self._segments(segs)
        if step == 1 and segs == list(range(segs[0], segs[-1] + 1)):
            # contiguous: join covered segments, unpack the run in one go
            blob = b"".join(bytes(bufs[k]) for k in segs)
            return self._codec.unpack_many(
                blob, len(idxs), (start - segs[0] * self._eps) * isz)
        return [self._codec.unpack_one(bufs[j // self._eps],
                                       (j % self._eps) * isz)
                for j in idxs]

    def write_one(self, i: int, v: Any) -> None:
        isz = self._codec.itemsize
        k, off = divmod(i, self._eps)
        packed = self._codec.pack_one(v)
        if self._cache_on():
            buf = self._segments([k])[k]
            buf[off * isz:(off + 1) * isz] = packed
            self._dirty.setdefault(k, set()).add(off * isz)
            return
        self._store.setrange(self._seg_key(k), off * isz, packed)

    def write_slice(self, idxs: Sequence[int], vals: Sequence[Any]) -> None:
        isz = self._codec.itemsize
        if self._cache_on():
            bufs = self._segments(sorted({j // self._eps for j in idxs}))
            for j, v in zip(idxs, vals):
                k, off = divmod(j, self._eps)
                bufs[k][off * isz:(off + 1) * isz] = self._codec.pack_one(v)
                self._dirty.setdefault(k, set()).add(off * isz)
            return
        # Uncached: ONE MSETRANGE of byte runs, coalescing adjacent
        # elements (a contiguous slice write becomes one run per segment).
        entries: List[tuple] = []
        cur_key: Optional[str] = None
        cur_start = 0
        cur = bytearray()
        for j, v in zip(idxs, vals):
            k, off = divmod(j, self._eps)
            key, boff = self._seg_key(k), off * isz
            packed = self._codec.pack_one(v)
            if key == cur_key and boff == cur_start + len(cur):
                cur += packed
            else:
                if cur_key is not None:
                    entries.append((cur_key, cur_start, bytes(cur)))
                cur_key, cur_start, cur = key, boff, bytearray(packed)
        entries.append((cur_key, cur_start, bytes(cur)))
        self._store.msetrange(entries)

    # -- lock-scope hooks ----------------------------------------------------

    def cache_begin(self) -> None:
        """Outermost lock acquire: drop anything stale, open the scope for
        the acquiring thread."""
        self._cache.clear()
        self._dirty.clear()
        self._owner_tid = threading.get_ident()

    def cache_end(self) -> None:
        """Outermost lock release (still holding it): flush every dirty
        byte run as ONE MSETRANGE, then close the scope. Only bytes this
        scope stored are written back (dirty offsets coalesced into runs),
        never whole segments."""
        try:
            if self._dirty:
                isz = self._codec.itemsize
                entries = []
                for k in sorted(self._dirty):
                    buf = self._cache[k]
                    run_start = run_end = None
                    for boff in sorted(self._dirty[k]):
                        if run_end is not None and boff == run_end:
                            run_end += isz
                            continue
                        if run_start is not None:
                            entries.append((self._seg_key(k), run_start,
                                            bytes(buf[run_start:run_end])))
                        run_start, run_end = boff, boff + isz
                    entries.append((self._seg_key(k), run_start,
                                    bytes(buf[run_start:run_end])))
                self._store.msetrange(entries)
        finally:
            self._owner_tid = None
            self._cache.clear()
            self._dirty.clear()


_LAYOUTS = {"block": _BlockBacking, "list": _ListBacking}


# ---------------------------------------------------------------------------
# Public proxies
# ---------------------------------------------------------------------------


class RawArray(RemoteResource):
    """Lock-free shared array of basic C values (no cache: every access
    pays its KV commands; see the module docstring for the cost model)."""

    _RESOURCE_KIND = "array"

    def __init__(self, typecode: str, size_or_init: Union[int, Sequence[Any]],
                 layout: str = "block", _adopt: bool = False, **kw):
        if typecode not in _TYPECODES:
            raise ValueError(f"bad typecode {typecode!r}")
        if layout not in _LAYOUTS:
            raise ValueError(f"bad layout {layout!r} (want 'block' or 'list')")
        super().__init__(_adopt=_adopt, **kw)
        if isinstance(size_or_init, int):
            init: List[Any] = [_zero(typecode)] * size_or_init
        else:
            init = [_cast(typecode, v) for v in size_or_init]
        self._rebuild(typecode, len(init), layout)
        if not _adopt and init:
            self._backing.initialize(init)
            self._touch_ttl()  # segment keys exist only after initialize()

    def _rebuild(self, typecode: str, length: int,
                 layout: str = "block") -> None:
        self._typecode = typecode
        self._length = length
        self._layout = layout
        self._backing = _LAYOUTS[layout](self._store, self._key,
                                         typecode, length)

    def _reduce_state(self):
        return (self._typecode, self._length, self._layout)

    @property
    def typecode(self) -> str:
        return self._typecode

    @property
    def layout(self) -> str:
        return self._layout

    def _kv_keys(self):
        # RemoteResource.__init__ touches TTLs before _rebuild has built
        # the backing; at that point only the refcount key exists.
        backing = getattr(self, "_backing", None)
        return [self._refs_key] + (backing.kv_keys() if backing else [])

    def __len__(self) -> int:
        return self._length

    def _index(self, i: int) -> int:
        if i < 0:
            i += self._length
        if not (0 <= i < self._length):
            raise IndexError("array index out of range")
        return i

    def __getitem__(self, i):
        if isinstance(i, slice):
            start, stop, step = i.indices(self._length)
            return self._backing.read_slice(start, stop, step)
        return self._backing.read_one(self._index(i))

    def __setitem__(self, i, value):
        if isinstance(i, slice):
            start, stop, step = i.indices(self._length)
            idxs = range(start, stop, step)
            vals = [_cast(self._typecode, v) for v in value]
            if len(idxs) != len(vals):
                raise ValueError("slice assignment length mismatch")
            if idxs:
                self._backing.write_slice(idxs, vals)
            return
        self._backing.write_one(self._index(i), _cast(self._typecode, value))

    def __iter__(self):
        return iter(self[:])

    def tolist(self) -> List[Any]:
        return self[:]


class Array(RawArray):
    """RawArray + an RLock (multiprocessing's default lock=True). Under
    ``layout="block"`` the lock scopes the client cache (module docstring)."""

    def __init__(self, typecode: str, size_or_init, lock: bool = True,
                 layout: str = "block", _adopt: bool = False, **kw):
        super().__init__(typecode, size_or_init, layout=layout,
                         _adopt=_adopt, **kw)
        self._lock_obj: Optional[RLock] = (
            RLock(store=kw.get("store")) if lock else None)
        self._attach_cache()

    def _reduce_state(self):
        return (self._typecode, self._length, self._layout, self._lock_obj)

    def _rebuild(self, typecode: str, length: int, layout: str = "block",
                 lock_obj=None) -> None:
        super()._rebuild(typecode, length, layout)
        self._lock_obj = lock_obj
        self._attach_cache()

    def _attach_cache(self) -> None:
        """Scope this proxy's segment cache to this proxy's lock."""
        if self._lock_obj is not None and self._backing.layout == "block":
            self._lock_obj._register_scope_hooks(
                self._backing.cache_begin, self._backing.cache_end)

    def get_lock(self) -> RLock:
        if self._lock_obj is None:
            raise AttributeError("array created with lock=False")
        return self._lock_obj

    def get_obj(self) -> "Array":
        return self

    def acquire(self, *a, **kw):
        return self.get_lock().acquire(*a, **kw)

    def release(self):
        return self.get_lock().release()

    def __enter__(self):
        self.get_lock().acquire()
        return self

    def __exit__(self, *exc):
        self.get_lock().release()


class RawValue(RawArray):
    """A Value is an Array of size 1 (paper §3.2)."""

    _RESOURCE_KIND = "value"

    def __init__(self, typecode: str, value: Any = 0, layout: str = "block",
                 _adopt: bool = False, **kw):
        super().__init__(typecode, [value], layout=layout, _adopt=_adopt, **kw)

    @property
    def value(self):
        return self[0]

    @value.setter
    def value(self, v):
        self[0] = v


class Value(RawValue):
    def __init__(self, typecode: str, value: Any = 0, lock: bool = True,
                 layout: str = "block", _adopt: bool = False, **kw):
        super().__init__(typecode, value, layout=layout, _adopt=_adopt, **kw)
        self._lock_obj: Optional[RLock] = (
            RLock(store=kw.get("store")) if lock else None)
        self._attach_cache()

    def _reduce_state(self):
        return (self._typecode, self._length, self._layout, self._lock_obj)

    def _rebuild(self, typecode: str, length: int, layout: str = "block",
                 lock_obj=None) -> None:
        RawArray._rebuild(self, typecode, length, layout)
        self._lock_obj = lock_obj
        self._attach_cache()

    _attach_cache = Array._attach_cache

    def get_lock(self) -> RLock:
        if self._lock_obj is None:
            raise AttributeError("value created with lock=False")
        return self._lock_obj

    def acquire(self, *a, **kw):
        return self.get_lock().acquire(*a, **kw)

    def release(self):
        return self.get_lock().release()

    def __enter__(self):
        self.get_lock().acquire()
        return self

    def __exit__(self, *exc):
        self.get_lock().release()
