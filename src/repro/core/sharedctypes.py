"""Shared memory abstractions over KV lists (paper §3.2 "Shared state").

Array/Value hold only basic C-typed scalars and are backed by the LIST
type — "each element of the list will be at most sizeof(long double)" —
so **every index access is one KV command**. This is deliberately faithful:
it is exactly the cost model that makes the paper's in-place shared-array
sort prohibitively slow remotely (Table 3), which our
``benchmarks/bench_sort.py`` reproduces. Slice reads/writes map to
LRANGE / per-index LSET inside one transaction.
"""

from __future__ import annotations

import struct
from typing import Any, Iterable, List, Optional, Sequence, Union

from .reference import RemoteResource
from .synchronize import RLock

__all__ = ["Value", "Array", "RawValue", "RawArray", "typecode_to_type"]

# typecode -> (python cast, struct fmt) ; mirrors ctypes/array typecodes
_TYPECODES = {
    "b": int, "B": int, "h": int, "H": int, "i": int, "I": int,
    "l": int, "L": int, "q": int, "Q": int,
    "f": float, "d": float,
    "c": bytes,
}
typecode_to_type = {k: v for k, v in _TYPECODES.items()}


def _cast(typecode: str, v: Any) -> Any:
    py = _TYPECODES[typecode]
    v = py(v)
    if typecode in ("f",):  # round-trip float32 precision like ctypes
        v = struct.unpack("f", struct.pack("f", v))[0]
    return v


class RawArray(RemoteResource):
    """Lock-free shared array of basic C values, one LIST element each."""

    _RESOURCE_KIND = "array"

    def __init__(self, typecode: str, size_or_init: Union[int, Sequence[Any]],
                 _adopt: bool = False, **kw):
        if typecode not in _TYPECODES:
            raise ValueError(f"bad typecode {typecode!r}")
        super().__init__(_adopt=_adopt, **kw)
        if isinstance(size_or_init, int):
            init: List[Any] = [_cast(typecode, 0) if typecode != "c" else b"\x00"
                               for _ in range(size_or_init)]
        else:
            init = [_cast(typecode, v) for v in size_or_init]
        self._rebuild(typecode, len(init))
        if not _adopt and init:
            self._store.rpush(self._data_key, *init)

    def _rebuild(self, typecode: str, length: int) -> None:
        self._typecode = typecode
        self._length = length

    def _reduce_state(self):
        return (self._typecode, self._length)

    @property
    def typecode(self) -> str:
        return self._typecode

    @property
    def _data_key(self) -> str:
        return self._key("data")

    def _kv_keys(self):
        return [self._refs_key, self._data_key]

    def __len__(self) -> int:
        return self._length

    def _index(self, i: int) -> int:
        if i < 0:
            i += self._length
        if not (0 <= i < self._length):
            raise IndexError("array index out of range")
        return i

    def __getitem__(self, i):
        if isinstance(i, slice):
            start, stop, step = i.indices(self._length)
            if step == 1:
                return self._store.lrange(self._data_key, start, stop - 1)
            idxs = range(start, stop, step)
            batch = getattr(self._store, "execute_batch", None)
            if batch is not None and len(idxs) > 1:
                # strided read: one batched round trip, not one per index
                out = []
                for ok, v in batch([("lindex", (self._data_key, j), {})
                                    for j in idxs]):
                    if not ok:
                        raise v
                    out.append(v)
                return out
            return [self._store.lindex(self._data_key, j) for j in idxs]
        return self._store.lindex(self._data_key, self._index(i))

    def __setitem__(self, i, value):
        if isinstance(i, slice):
            start, stop, step = i.indices(self._length)
            idxs = list(range(start, stop, step))
            vals = [_cast(self._typecode, v) for v in value]
            if len(idxs) != len(vals):
                raise ValueError("slice assignment length mismatch")
            data_key = self._data_key

            def txn(s):  # one atomic command batch (closes over plain data)
                for j, v in zip(idxs, vals):
                    s.lset(data_key, j, v)
            if hasattr(self._store, "shards"):
                self._store.transaction(txn, key_hint=data_key)
            else:
                self._store.transaction(txn)
            return
        self._store.lset(self._data_key, self._index(i),
                         _cast(self._typecode, value))

    def __iter__(self):
        return iter(self[:])

    def tolist(self) -> List[Any]:
        return self[:]


class Array(RawArray):
    """RawArray + an RLock (multiprocessing's default lock=True)."""

    def __init__(self, typecode: str, size_or_init, lock: bool = True,
                 _adopt: bool = False, **kw):
        super().__init__(typecode, size_or_init, _adopt=_adopt, **kw)
        self._lock_obj: Optional[RLock] = RLock() if lock else None

    def _reduce_state(self):
        return (self._typecode, self._length, self._lock_obj)

    def _rebuild(self, typecode: str, length: int, lock_obj=None) -> None:
        super()._rebuild(typecode, length)
        self._lock_obj = lock_obj

    def get_lock(self) -> RLock:
        if self._lock_obj is None:
            raise AttributeError("array created with lock=False")
        return self._lock_obj

    def get_obj(self) -> "Array":
        return self

    def acquire(self, *a, **kw):
        return self.get_lock().acquire(*a, **kw)

    def release(self):
        return self.get_lock().release()

    def __enter__(self):
        self.get_lock().acquire()
        return self

    def __exit__(self, *exc):
        self.get_lock().release()


class RawValue(RawArray):
    """A Value is an Array of size 1 (paper §3.2)."""

    _RESOURCE_KIND = "value"

    def __init__(self, typecode: str, value: Any = 0, _adopt: bool = False, **kw):
        super().__init__(typecode, [value], _adopt=_adopt, **kw)

    @property
    def value(self):
        return self[0]

    @value.setter
    def value(self, v):
        self[0] = v


class Value(RawValue):
    def __init__(self, typecode: str, value: Any = 0, lock: bool = True,
                 _adopt: bool = False, **kw):
        super().__init__(typecode, value, _adopt=_adopt, **kw)
        self._lock_obj: Optional[RLock] = RLock() if lock else None

    def _reduce_state(self):
        return (self._typecode, self._length, self._lock_obj)

    def _rebuild(self, typecode: str, length: int, lock_obj=None) -> None:
        RawArray._rebuild(self, typecode, length)
        self._lock_obj = lock_obj

    def get_lock(self) -> RLock:
        if self._lock_obj is None:
            raise AttributeError("value created with lock=False")
        return self._lock_obj

    def acquire(self, *a, **kw):
        return self.get_lock().acquire(*a, **kw)

    def release(self):
        return self.get_lock().release()

    def __enter__(self):
        self.get_lock().acquire()
        return self

    def __exit__(self, *exc):
        self.get_lock().release()
