"""``repro.core.mp`` — the drop-in ``multiprocessing`` module (paper §3).

    -  import multiprocessing as mp
    +  from repro.core import mp

Everything else in the application stays unchanged: that is the paper's
access-transparency claim, and tests/test_transparency.py runs the same
application code against both this module and the stdlib to enforce it.
"""

from __future__ import annotations

import os
from typing import Optional

from .managers import Manager, SyncManager
from .pool import Pool, ProcessError, TimeoutError
from .process import Process, active_children, current_process, parent_process
from .queues import Empty, Full, JoinableQueue, Pipe, Queue, SimpleQueue
from .sharedctypes import Array, RawArray, RawValue, Value
from .synchronize import (Barrier, BoundedSemaphore, BrokenBarrierError,
                          Condition, Event, Lock, RLock, Semaphore)
from . import session as _session

__all__ = [
    "Process", "Pool", "Queue", "SimpleQueue", "JoinableQueue", "Pipe",
    "Lock", "RLock", "Semaphore", "BoundedSemaphore", "Condition", "Event",
    "Barrier", "Value", "Array", "RawValue", "RawArray", "Manager",
    "current_process", "parent_process", "active_children", "cpu_count",
    "get_context", "get_start_method", "set_start_method", "Empty", "Full",
    "BrokenBarrierError", "ProcessError", "TimeoutError",
]


def cpu_count() -> int:
    """Local API returns machine cores; transparently we return the
    configured serverless parallelism ceiling when one is set (this is
    how unmodified ``Pool(processes=cpu_count())`` code scales out)."""
    sess = _session.get_session()
    configured = sess.executor_defaults.get("default_parallelism")
    return int(configured) if configured else (os.cpu_count() or 1)


_start_method = "spawn"  # serverless functions are always fresh => spawn


def get_start_method(allow_none: bool = False) -> str:
    return _start_method


def set_start_method(method: str, force: bool = False) -> None:
    # spawn/fork/forkserver all map to function invocation; accepted for
    # API fidelity (POET uses spawn, Pandaral·lel uses fork — §6).
    if method not in ("spawn", "fork", "forkserver"):
        raise ValueError(f"unknown start method {method!r}")


class _Context:
    """multiprocessing context object. Start method is cosmetic here —
    every 'process' is a serverless function invocation either way."""

    def __init__(self, method: str = "spawn"):
        self._method = method
        # re-export the full API surface on the context, like stdlib
        self.Process = Process
        self.Pool = Pool
        self.Queue = Queue
        self.SimpleQueue = SimpleQueue
        self.JoinableQueue = JoinableQueue
        self.Pipe = staticmethod(Pipe)
        self.Lock = Lock
        self.RLock = RLock
        self.Semaphore = Semaphore
        self.BoundedSemaphore = BoundedSemaphore
        self.Condition = Condition
        self.Event = Event
        self.Barrier = Barrier
        self.Value = Value
        self.Array = Array
        self.Manager = staticmethod(Manager)
        self.cpu_count = staticmethod(cpu_count)

    def get_start_method(self, allow_none: bool = False) -> str:
        return self._method

    def get_context(self, method: Optional[str] = None) -> "_Context":
        return get_context(method)


def get_context(method: Optional[str] = None) -> _Context:
    return _Context(method or _start_method)
