"""TCP front-end for :class:`repro.core.kvstore.KVStore` (the "real Redis" mode).

The paper's workers are AWS Lambda containers that reach Redis over TCP in
the same VPC subnet. This module provides the equivalent remote mode: a
framed protocol served by a thread-per-connection server over a shared
``KVStore`` — whose global lock preserves Redis's single-threaded
atomicity — plus a client exposing the same method surface, so every IPC
primitive runs unchanged against a genuinely remote store.

Wire format (version 4: zero-pickle raw command frames; v3 multiplexed
tagged frames, v2 multi-part zero-copy, and v1 legacy kept for interop)::

    frame    := u32 word, rest
    word bit29 (with MSB) set -> RAW (v4): the frame's single part is a
                      struct-packed binary command/reply body
                      (``serialization.encode_command``/``encode_reply``
                      — type-tagged scalars, u8 dispatch id), NOT a
                      pickle. Composes with bit30: a tagged raw frame
                      carries a request id like v3. Requests outside the
                      raw vocabulary fall back per command to the
                      pickle dialects below; replies to raw requests
                      fall back per reply (exceptions, OOB-sized
                      values), each frame self-describing via its flags.
    word MSB set, bit30 set -> tagged multi-part (v3):
                      nparts = word & 0x1FFFFFFF, then a u32 request id,
                      then nparts x u32 part lengths, then the parts.
                      Responses carry the request id of the request they
                      answer and may arrive OUT OF ORDER: the server
                      parks blocking commands (BLPOP & friends) on
                      dedicated threads and keeps serving the socket, so
                      many client threads multiplex one connection
                      without head-of-line blocking.
    word MSB set, bit30 clear -> multi-part (v2): nparts = word &
                      0x1FFFFFFF, then nparts x u32 part lengths, then
                      the parts. part[0] = pickle-5 payload (out-of-band
                      descriptors), part[1:] = raw buffers (numpy
                      arrays, large bytes) referenced by the payload —
                      never copied into it. Responses are in-order.
    word MSB clear -> legacy (v1): word = length of a single in-band
                      pickled payload. The server answers each request in
                      the dialect it arrived in, so old clients interop.

    request  := (cmd: str, args: tuple, kwargs: dict)
    response := (ok: bool, value_or_exception)

v4 per-command cost model: a raw small command costs a u8 dispatch-table
index + a few fixed-width struct reads on the server (no ``getattr``, no
Unpickler) and a type-tag append loop on the client (no Pickler, no
memo), executed at submit time so the mux's flush lock only ever
concatenates ready-made buffers. Pickle remains the capability dialect:
anything the codec does not cover — including every >= 4 KiB value,
which keeps the pickle-5 out-of-band zero-copy path — transparently
ships as v2/v3 frames on the same connection.

Frames are written with scatter-gather ``sendmsg`` (header + payload +
buffers in one syscall, no concatenation copy) and read with ``recv_into``
into preallocated buffers (no quadratic ``+=`` reassembly).

Client-side I/O mux (v3): ``KVClient`` no longer opens one socket per
thread. A :class:`_SockMux` owns ONE persistent connection per server
(plus one *blocking lane* connection for commands that may park
server-side); worker threads submit requests and block on per-request
futures, correlated by tag. Writes use flat combining — the thread that
wins the flush lock drains everything queued behind it in one gather
write — and coalescible submissions that pile up during an in-flight
flush are **micro-batched** into one ``execute_batch`` frame (group
commit), so an N-thread burst of small commands costs ~1-2 frames per
socket instead of N. Reads are leader/follower — the waiters themselves
take turns owning the socket's read side (see :class:`_SockMux`), so a
solo command keeps the zero-handoff latency of a private socket.

Round-trip / frame accounting on this transport:

* one command               = 1 RTT (unchanged);
* ``KVClient.pipeline()``   = 1 RTT for N commands — transactional mode
  ships one ``execute_batch`` frame the server runs under a single
  take-all-stripes acquisition; non-transactional mode group-commits the
  N commands in byte-bounded chunks, awaiting (= draining) each chunk
  before the next is written, so bulk requests with bulk responses never
  outgrow the socket buffering;
* an N-thread burst of single small commands = ~1-2 ``execute_batch``
  frames per commit window (group commit), down from N frames — N
  pickles still happen, but the per-frame syscall tax is amortized;
* a ``ClusterClient`` pipeline (see ``repro.core.kvcluster``) splits the
  batch into one ``execute_batch`` submission per involved shard on the
  shard's mux — co-resident shards (same connection) merge into one
  frame; different threads' batches stay separate frames (uncoupled
  latencies) but share gather writes, corked server responses, and
  burst-drained reads — then gathers the per-shard futures: N shards,
  still ~1 wall-clock RTT; the in-process ``LatencyModel`` mirrors this
  by billing a scatter as the max per-shard cost, not the sum;
* an exception mid-batch never desyncs framing: every queued command
  yields exactly one result and the first error is raised only after all
  responses are drained (merged group-commit frames always resolve every
  constituent future, in both the success and the whole-frame-error
  paths);
* byte-range commands (``getrange``/``setrange``/``msetrange`` — the
  block-backed shared-array primitives) need no client-side support
  code: they flow through the generic dispatch, and segment-sized
  (>= 4 KiB) values ride the out-of-band zero-copy path in both
  directions.

Cluster bootstrap handshake (implemented in ``repro.core.kvcluster``):
a ``KVCluster`` supervisor process serves a *control* ``KVServer`` whose
store holds the cluster descriptor — shard count, per-shard addresses,
and the consistent-hash seed — under the well-known key
``__cluster__``. A client bootstraps from the single control address
with a plain ``GET __cluster__`` (one RTT over this very protocol),
then opens one ``KVClient`` per shard and hash-routes keys with the
same hash-tag rules as ``ShardedKVStore``. A plain ``KVServer`` answers
that GET with None, which is how ``kvcluster.connect`` auto-detects
whether one address names a cluster or a single server.

Receive-side memory: each connection leases its receive buffers from a
small per-connection :class:`_BufferPool` instead of allocating a fresh
``bytearray`` per frame segment (header, part-length vector, body). A
leased body is recycled right after decode whenever the decoded object
cannot alias it (legacy frames are copied by unpickling; multi-part
frames with no out-of-band parts likewise); bodies carrying out-of-band
buffers are never pooled, because the decoded values reference them
zero-copy. The pool is bounded (buffer count and per-buffer size) and
tracks its retained-bytes high-water mark, so a burst of huge frames
can neither pin megabytes on an idle connection nor hide that it tried.

Transports (PR 6): everything above is carrier-independent. The same
v1-v4 frames flow over three interchangeable carriers described by
self-describing endpoint urls (see ``repro.core.transport``):
``tcp://host:port`` (cross-host), ``uds:///path`` (same-host Unix
stream), and ``shm:///path`` (same-host shared-memory SPSC rings with
spin-then-doorbell wakeup — zero syscalls per frame on the hot path).
``KVServer`` listens on TCP and a Unix socket simultaneously and
advertises every endpoint; a connection on the Unix socket that opens
with the ring magic word upgrades to shm (the rendezvous socket then
carries only doorbell bytes and EOF). Clients auto-select the cheapest
reachable carrier (shm > uds > tcp) with connect-time fallback, or pin
one via ``transport=`` for A/B runs; plain ``(host, port)`` addresses
still mean TCP everywhere. ``RingConn`` duck-types the socket surface,
so the framing, mux, reader, and server code paths are IDENTICAL on
every carrier — only the bytes' vehicle changes.
"""

from __future__ import annotations

import os
import pickle
import queue as _stdqueue
import socket
import socketserver
import struct
import sys
import threading
import time
from collections import deque
from itertools import islice as _islice
from typing import Any, Dict, List, Optional, Sequence, Tuple

from . import clientopts as _copts
from . import serialization
from . import transport as _transport
from .errors import ShardRedirectError, ShardUnavailableError
from .kvstore import KVStore, Pipeline, _blocks

__all__ = ["KVServer", "KVClient"]

_HDR = struct.Struct("!I")
_MULTI = 0x80000000
_TAGGED = 0x40000000        # v3: a request-id tag follows the header word
_RAW = 0x20000000           # v4: part[0] is a raw-codec body, not pickle
_FLAGS = _MULTI | _TAGGED | _RAW
_RID = serialization.FRAME_TAG
_MAX_PARTS = 1 << 20        # sanity bound on frame part count
_IOV_CHUNK = 64             # buffers per sendmsg call (stay under IOV_MAX)
_SOCK_BUF = 1 << 20         # SO_SNDBUF/SO_RCVBUF: size for 1MB+ payloads
#: max request bytes written per non-transactional pipeline chunk before
#: draining responses; must stay below the combined in-flight socket
#: buffering so a chunk's tail can never wedge behind unread responses.
_PIPELINE_CHUNK_BYTES = 512 * 1024
_PIPELINE_CHUNK_BYTES_LEGACY = 48 * 1024   # legacy sockets keep OS defaults


#: socket families carrying TCP underneath (the only ones where
#: IPPROTO_TCP options are legal — AF_UNIX raises OSError on them)
_INET_FAMILIES = tuple(
    f for f in (socket.AF_INET, getattr(socket, "AF_INET6", None))
    if f is not None)


def _tune(sock: Any) -> None:
    """Transport-aware socket tuning for the non-legacy dialects:
    TCP_NODELAY only where there IS a TCP underneath (AF_UNIX sockets
    raise on IPPROTO_TCP options; ring connections have no kernel socket
    on the data path at all), deep buffers wherever the carrier accepts
    them (rings no-op — their buffering is the ring itself)."""
    if getattr(sock, "family", None) in _INET_FAMILIES:
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    try:
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, _SOCK_BUF)
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, _SOCK_BUF)
    except OSError:
        pass  # platform cap; defaults still work

#: Dialect spoken by ``legacy_protocol=True`` clients — the seed's exact
#: wire behavior (single in-band frame, default pickle protocol), kept so
#: benchmarks can measure before/after on one server.
_LEGACY_PICKLE_PROTOCOL = pickle.DEFAULT_PROTOCOL

# Cached pid for the mux fork guard: ``os.getpid()`` is a real syscall
# (tens of microseconds under syscall-filtering sandboxes) and the guard
# runs on every command. ``register_at_fork`` keeps the cache honest in
# forked children; spawn-style workers re-import and re-cache anyway.
_CUR_PID = os.getpid()


def _refresh_pid() -> None:
    global _CUR_PID
    _CUR_PID = os.getpid()


if hasattr(os, "register_at_fork"):
    os.register_at_fork(after_in_child=_refresh_pid)


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------


def _sendv(sock: socket.socket, buffers: Sequence[Any]) -> None:
    """Gather-write every buffer, handling partial sends, without ever
    concatenating the payload (the zero-copy half of the protocol)."""
    bufs: List[memoryview] = []
    for b in buffers:
        m = memoryview(b)
        if m.nbytes:
            bufs.append(m.cast("B") if m.format != "B" or m.ndim != 1 else m)
    i = 0  # index advance, not pop(0): many-buffer flushes stay linear
    while i < len(bufs):
        sent = sock.sendmsg(bufs[i:i + _IOV_CHUNK])
        while sent:
            b = bufs[i]
            if sent >= b.nbytes:
                sent -= b.nbytes
                i += 1
            else:
                bufs[i] = b[sent:]
                sent = 0


def _frame_parts(parts: Sequence[Any], rid: Optional[int] = None,
                 raw: bool = False) -> List[Any]:
    """Header + parts, ready for one `_sendv` gather write. ``rid`` tags
    the frame with a request id (v3 multiplexed dialect); None emits an
    untagged v2 frame. ``raw`` flags part[0] as a v4 raw-codec body."""
    word = _MULTI | len(parts)
    if rid is not None:
        word |= _TAGGED
    if raw:
        word |= _RAW
    hdr = bytearray(_HDR.pack(word))
    if rid is not None:
        hdr += _RID.pack(rid)
    for p in parts:
        n = memoryview(p).nbytes
        if n >= _MULTI:
            # the MSB of a length word is the dialect flag; fail loudly
            # instead of desyncing the peer's framing
            raise ValueError(f"frame part of {n} bytes exceeds the 2 GiB "
                             "wire limit — split the payload")
        hdr += _HDR.pack(n)
    return [hdr, *parts]


def _encode_frames(obj: Any, rid: Optional[int] = None) -> List[Any]:
    payload, buffers = serialization.dumps_oob(obj)
    return _frame_parts([payload, *buffers], rid)


def _encode_request_frames(request: Tuple[str, tuple, dict],
                           rid: Optional[int] = None,
                           raw: bool = True) -> List[Any]:
    """Request frame: the raw v4 body when the command is in the hot
    vocabulary, else the pickle (v2/v3) dialect — per-command fallback."""
    if raw:
        body = serialization.encode_command(*request)
        if body is not None:
            return _frame_parts([body], rid, raw=True)
    return _encode_frames(request, rid)


def _encode_reply_frames(resp: Tuple[bool, Any], rid: Optional[int],
                         raw: bool) -> List[Any]:
    """Response frame in the dialect the request arrived in; a raw
    request whose reply is not raw-codable (exceptions, OOB-sized
    values) answers in pickle, flagged per frame, and the client decodes
    by flag."""
    if raw:
        body = serialization.encode_reply(*resp)
        if body is not None:
            return _frame_parts([body], rid, raw=True)
    payload, buffers = serialization.dumps_oob(resp)
    return _frame_parts([payload, *buffers], rid)


class _BufferPool:
    """Per-connection free-list of receive buffers.

    Without it, every frame costs three fresh ``bytearray`` allocations
    (header word, part-length vector, body); on the small-command hot
    path the allocator round trips dominate the byte copying. Buffers are
    leased for one receive + decode and recycled — but only when the
    decoded object cannot alias them (see ``_recv_frames``). Never shared
    across threads: each server handler and each client thread owns one,
    so acquire/release need no lock.

    Retention is bounded on BOTH axes — at most ``_MAX_BUFS`` free
    buffers, each at most ``_MAX_BUF_BYTES`` (oversized buffers are
    dropped on release, so a burst of huge frames cannot pin its buffers
    on an idle connection forever) — and audited: ``high_water`` is the
    max total free bytes ever retained, so tests (and a curious
    operator) can see the worst case a workload actually reached instead
    of trusting the caps.
    """

    __slots__ = ("_free", "_retained", "high_water")

    #: keep at most this many free buffers / bytes-per-buffer
    _MAX_BUFS = 8
    _MAX_BUF_BYTES = 1 << 18

    def __init__(self) -> None:
        self._free: List[bytearray] = []
        self._retained = 0      # total free bytes currently held
        self.high_water = 0     # max ever _retained (see class docstring)

    @property
    def retained_bytes(self) -> int:
        return self._retained

    def acquire(self, n: int) -> bytearray:
        """A buffer with capacity >= n (possibly larger — callers slice a
        memoryview to the exact length)."""
        best = -1
        for i, b in enumerate(self._free):
            if len(b) >= n and (best < 0 or len(b) < len(self._free[best])):
                best = i
        if best >= 0 and len(self._free[best]) <= max(4 * n, 1024):
            # best fit, unless it over-allocates grossly (a segment-sized
            # buffer must not get pinned serving 4-byte headers)
            buf = self._free.pop(best)
            self._retained -= len(buf)
            return buf
        return bytearray(n)

    def release(self, buf: bytearray) -> None:
        if len(self._free) < self._MAX_BUFS and len(buf) <= self._MAX_BUF_BYTES:
            self._free.append(buf)
            self._retained += len(buf)
            if self._retained > self.high_water:
                self.high_water = self._retained


class _ConnReader:
    """Per-connection buffered frame reader.

    The exact-read receive path cost three ``recv`` syscalls per frame
    (header word, part-length vector, body); on a hot loopback path the
    syscalls dominate the byte copying, and a scatter/gather client pays
    them per *shard*. This reader drains the socket in chunk-sized
    ``recv_into`` calls instead: a small frame usually costs ONE syscall,
    and back-to-back pipelined/gathered responses already sitting in the
    socket buffer parse out of a single chunk with ZERO further syscalls.

    The chunk is leased from the connection's :class:`_BufferPool`.
    Memoryviews served from the chunk are valid only until the next
    ``read`` on this reader — callers decode each frame before reading
    the next (both the server loop and the client response drain do), and
    bodies whose decoded values outlive the frame (out-of-band parts,
    ``recycle=False``) are never chunk-served or pooled.
    """

    __slots__ = ("sock", "pool", "_chunk", "_view", "_start", "_end")

    _CHUNK = 64 * 1024

    def __init__(self, sock: socket.socket, pool: Optional[_BufferPool] = None):
        self.sock = sock
        self.pool = pool if pool is not None else _BufferPool()
        self._chunk = self.pool.acquire(self._CHUNK)
        self._view = memoryview(self._chunk)
        self._start = 0
        self._end = 0

    @property
    def buffered(self) -> int:
        """Bytes already drained from the socket but not yet consumed —
        when positive, more frames are (probably) pending and a ``read``
        will not block. The server's response-corking uses this to decide
        whether flushing can wait for one more request."""
        return self._end - self._start

    def _fill(self, n: int) -> bool:
        """Buffer at least ``n`` contiguous bytes (n <= chunk size);
        False on EOF."""
        if len(self._chunk) - self._start < n:
            # move the partial tail to the front to make room
            tail = bytes(self._view[self._start:self._end])
            self._view[:len(tail)] = tail
            self._start, self._end = 0, len(tail)
        while self._end - self._start < n:
            r = self.sock.recv_into(self._view[self._end:])
            if not r:
                return False
            self._end += r
        return True

    def read(self, n: int, recycle: bool = True
             ) -> Optional[Tuple[Optional[bytearray], memoryview]]:
        """Exactly ``n`` bytes as ``(lease, view)``, or None on EOF.

        ``recycle=True`` (data is dead after the caller's decode): served
        from the chunk when it fits (``lease`` None — valid until the
        next read) or from a pool lease the caller must release.
        ``recycle=False`` (decoded values may alias the data): always a
        fresh private buffer, never pooled, ``lease`` None."""
        if recycle and n <= len(self._chunk):
            if not self._fill(n):
                return None
            view = self._view[self._start:self._start + n]
            self._start += n
            if self._start == self._end:
                self._start = self._end = 0
            return None, view
        owner = self.pool.acquire(n) if recycle else bytearray(n)
        view = memoryview(owner)[:n]
        got = min(self._end - self._start, n)
        if got:
            view[:got] = self._view[self._start:self._start + got]
            self._start += got
            if self._start == self._end:
                self._start = self._end = 0
        while got < n:
            r = self.sock.recv_into(view[got:], n - got, socket.MSG_WAITALL)
            if not r:
                if recycle:
                    self.pool.release(owner)
                return None
            got += r
        return (owner if recycle else None), view


def _recv_frames(reader: _ConnReader
                 ) -> Optional[Tuple[List[Any], bool, bool,
                                     Optional[bytearray], Optional[int]]]:
    """Read one frame. Returns ``(parts, is_legacy, is_raw, lease, rid)``
    or None on EOF. ``rid`` is the v3/v4 request id, or None for untagged
    (v1/v2, or untagged-raw) frames; ``is_raw`` marks a v4 raw-codec
    body. ``parts`` are valid until the next read on ``reader`` unless
    backed by ``lease`` (a pool buffer the caller must release once the
    parts are decoded) or fresh-allocated (frames with out-of-band parts,
    nparts > 1, whose decoded values alias the body zero-copy and must
    never be recycled). Raw bodies are always copied by decode, so they
    always recycle.

    A multi-part frame's whole body lands in ONE buffer; parts are
    memoryview slices of it — per-part buffers would pay an mmap + page
    faults each for large payloads."""
    got = reader.read(_HDR.size)
    if got is None:
        return None
    lease, view = got
    (word,) = _HDR.unpack(view)
    if lease is not None:
        reader.pool.release(lease)
    if not word & _MULTI:
        got = reader.read(word)
        if got is None:
            return None
        lease, view = got
        return [view], True, False, lease, None
    rid: Optional[int] = None
    if word & _TAGGED:
        got = reader.read(_RID.size)
        if got is None:
            return None
        lease, view = got
        (rid,) = _RID.unpack(view)
        if lease is not None:
            reader.pool.release(lease)
    raw = bool(word & _RAW)
    nparts = word & ~_FLAGS
    if not 1 <= nparts <= _MAX_PARTS or (raw and nparts != 1):
        raise ConnectionError(f"bad frame: {nparts} parts (raw={raw})")
    got = reader.read(_HDR.size * nparts)
    if got is None:
        return None
    lease, view = got
    lens = [ln for (ln,) in _HDR.iter_unpack(bytes(view))]
    if lease is not None:
        reader.pool.release(lease)
    got = reader.read(sum(lens), recycle=nparts == 1)
    if got is None:
        return None
    lease, view = got
    parts: List[Any] = []
    offset = 0
    for ln in lens:
        parts.append(view[offset:offset + ln])
        offset += ln
    return parts, False, raw, lease, rid


def _decode(parts: List[Any], legacy: bool) -> Any:
    if legacy:
        return serialization.loads(bytes(parts[0]))
    return serialization.loads_oob(parts[0], parts[1:])


def _decode_reply(parts: List[Any], legacy: bool, raw: bool
                  ) -> Tuple[bool, Any]:
    """Client-side response decode: raw v4 replies through the binary
    codec, everything else through pickle."""
    if raw:
        return serialization.decode_reply(parts[0])
    return _decode(parts, legacy)


def _recv_decode(reader: _ConnReader) -> Optional[Tuple[Any, bool]]:
    """Read one RESPONSE frame, decode it, and recycle any lease (decode
    copied everything a recyclable buffer held — see ``_recv_frames``).
    Returns ``(obj, is_legacy)`` or None on EOF. Used by the untagged
    (v1/v2/untagged-raw) in-order response paths, which never see tagged
    frames."""
    got = _recv_frames(reader)
    if got is None:
        return None
    parts, legacy, raw, lease, _ = got
    try:
        return _decode_reply(parts, legacy, raw), legacy
    finally:
        if lease is not None:
            reader.pool.release(lease)


# legacy (v1) single-frame send, used by the legacy dialect paths
# (reads go through _recv_frames, which speaks both dialects)
def _send_frame(sock: socket.socket, payload: bytes) -> None:
    if len(payload) >= _MULTI:
        raise ValueError(f"legacy frame of {len(payload)} bytes exceeds the "
                         "2 GiB wire limit — split the payload")
    sock.sendall(_HDR.pack(len(payload)) + payload)


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------


#: flush corked v3 responses once they accumulate this many bytes, even
#: if more requests are still buffered (bounds client-side wait + memory)
_CORK_MAX_BYTES = 256 * 1024

#: idle seconds before a parked-command worker thread retires
_BLOCKING_WORKER_IDLE_S = 5.0


def _build_dispatch(store: KVStore) -> Tuple[Any, ...]:
    """Precomputed cid -> bound-method table, the v4 fast path: a raw
    command executes as ``table[cid](*args, **kwargs)`` — no per-request
    ``getattr``, no underscore/name checks, no generic arg unpacking.
    Built once per server; index order is ``serialization.RAW_COMMANDS``."""
    return tuple(getattr(store, name, None)
                 for name in serialization.RAW_COMMANDS)


#: raw dispatch ids of commands that may park server-side (same predicate
#: as ``_blocks``, resolved to wire ids once at import)
_RAW_BLOCKING_NAMES = {
    serialization.RAW_COMMAND_IDS[c]: c
    for c in ("blpop", "brpop", "bllen", "blpop_rpush", "blpop_lease")
    if c in serialization.RAW_COMMAND_IDS
}


def _raw_request_blocks(request: Tuple[int, tuple, dict]) -> bool:
    cid, args, kwargs = request
    name = _RAW_BLOCKING_NAMES.get(cid)
    return name is not None and _blocks(name, args, kwargs)


class _BlockingWorkers:
    """Reusable worker threads for parked (blocking) commands on one
    connection. A steady-state poller — the executor collector blpops
    every 0.5 s forever — must not create and destroy one thread per
    request; a worker serves, re-idles, and retires only after
    ``_BLOCKING_WORKER_IDLE_S`` without work. Concurrency is unbounded
    by design (each PARKED command needs its own thread, exactly like
    the pre-mux one-blocked-command-per-connection model — there are
    just as many threads, now keyed by parked command instead of by
    client thread)."""

    __slots__ = ("_serve", "_idle", "_lock")

    def __init__(self, serve):
        self._serve = serve
        self._idle: List[Any] = []      # single-slot handoff queues
        self._lock = threading.Lock()

    def dispatch(self, task: tuple) -> None:
        with self._lock:
            slot = self._idle.pop() if self._idle else None
        if slot is None:
            slot = _stdqueue.Queue(1)
            threading.Thread(target=self._run, args=(slot,), daemon=True,
                             name="kvserver-blocking").start()
        slot.put(task)

    def _run(self, slot) -> None:
        while True:
            try:
                task = slot.get(timeout=_BLOCKING_WORKER_IDLE_S)
            except _stdqueue.Empty:
                with self._lock:
                    if slot in self._idle:
                        self._idle.remove(slot)
                        return
                # a dispatcher claimed this slot between our timeout and
                # the lock: its task is already on the way — take it
                task = slot.get()
            if not self._serve(*task):
                return  # connection gone; let peers idle out naturally
            with self._lock:
                self._idle.append(slot)


class _Handler(socketserver.BaseRequestHandler):
    """Thread-per-connection request loop.

    v1/v2 frames execute inline in arrival order (one pending command per
    connection — the pre-mux contract). v3 tagged frames are the
    multiplexed dialect: non-blocking commands still execute inline (a
    striped-store command is microseconds — a thread handoff would cost
    more than it saves), but commands that may PARK (``_blocks``) are
    dispatched to a dedicated thread and answered whenever they complete,
    out of order, so one parked BLPOP never head-of-line blocks the other
    threads multiplexed onto this socket. Response writes from the inline
    loop and parked-command threads interleave under a per-connection
    send lock (a torn frame would desync the whole connection).

    **Response corking (v3).** When the reader still holds buffered
    request bytes, more frames are about to be processed — so instead of
    one ``sendmsg`` per response, inline v3 responses are CORKED and
    flushed in one gather write when the buffered input runs dry (or at
    ``_CORK_MAX_BYTES``). A burst of N multiplexed requests then costs
    the server ~1 recv + 1 sendmsg instead of N of each — the receive
    side of the same amortization the client's group commit does on the
    send side. Tagged responses may be reordered by corking relative to
    parked-command completions, which the v3 contract already allows;
    untagged (v1/v2) responses are never corked, and any corked output is
    flushed before an untagged response is written (those clients expect
    strict request/response alternation).

    **Transport upgrade (shm).** A connection arriving on the server's
    Unix socket MAY open with the ring magic word
    (``transport.SHM_MAGIC`` — an impossible frame header in every
    dialect) instead of a frame: the handler peeks 4 bytes (one extra
    syscall, paid once per UDS accept, never on TCP), attaches the
    client's shared-memory segment, and swaps ``self.request`` for the
    :class:`repro.core.transport.RingConn` — after which THIS EXACT LOOP
    runs unchanged, reading frames out of shared memory. The ring is
    tracked on the server so ``KVServer.stop()`` can wake a parked
    handler and release the mapping."""

    def handle(self) -> None:
        ring = None
        if (getattr(self.server, "allow_shm", False)
                and getattr(self.request, "family", None)
                == getattr(socket, "AF_UNIX", None)):
            try:
                peek = self.request.recv(4, socket.MSG_PEEK
                                         | socket.MSG_WAITALL)
            except OSError:
                return
            if len(peek) < 4:
                return  # EOF before a full header: nothing to serve
            if peek == _transport.SHM_MAGIC:
                try:
                    ring = _transport.accept_ring(self.request)
                except (OSError, ConnectionError):
                    return  # client sees EOF = upgrade rejected
                self.request = ring
                self.server.track_ring(ring)  # type: ignore[attr-defined]
        try:
            self._serve_connection()
        finally:
            if ring is not None:
                self.server.untrack_ring(ring)  # type: ignore[attr-defined]
                ring.close()

    def _serve_connection(self) -> None:
        store: KVStore = self.server.store  # type: ignore[attr-defined]
        table = getattr(self.server, "raw_dispatch", None)
        if table is None:  # bare _Server without a KVServer wrapper
            table = _build_dispatch(store)
        kv = getattr(self.server, "kv", None)  # replication-aware wrapper
        tuned = False
        reader = _ConnReader(self.request)  # connection-private: no lock
        pool = reader.pool
        send_lock = threading.Lock()
        workers: Optional[_BlockingWorkers] = None  # parked-command pool
        cork: List[Any] = []     # response frame buffers awaiting one sendv
        cork_bytes = 0

        def flush_cork() -> bool:
            nonlocal cork, cork_bytes
            if not cork:
                return True
            frames, cork, cork_bytes = cork, [], 0
            try:
                with send_lock:
                    _sendv(self.request, frames)
                return True
            except OSError:
                return False

        while True:
            if reader.buffered == 0 and not flush_cork():
                return
            try:
                got = _recv_frames(reader)
            except (OSError, ConnectionError):
                return
            if got is None:
                return
            parts, legacy, raw, lease, rid = got
            if not tuned and not legacy:
                # v2/v3 connections get NODELAY + deep buffers. Legacy
                # (v1) connections keep the seed's untuned socket so the
                # before/after benchmark measures the seed transport.
                _tune(self.request)
                tuned = True
            # Decode BEFORE the next read: parts may alias the reader's
            # chunk, which the next _recv_frames overwrites.
            try:
                try:
                    if raw:
                        request = serialization.decode_command_id(parts[0])
                    else:
                        request = _decode(parts, legacy)
                finally:
                    # decode copied everything a pooled lease held (bodies
                    # with aliasing out-of-band parts are never leased)
                    if lease is not None:
                        pool.release(lease)
            except Exception as exc:
                # undecodable frame: answer if we can still frame a
                # response, then keep serving (framing itself is intact)
                request = None
                resp = (False, exc)
            else:
                blocks = (_raw_request_blocks(request) if raw
                          else _request_blocks(request))
                if rid is not None and blocks:
                    # parked commands respond from their own (reused)
                    # worker thread; any corked output flushes on the
                    # next loop turn
                    if workers is None:
                        workers = _BlockingWorkers(self._serve_one)
                    workers.dispatch((store, table, request, legacy, raw,
                                      rid, send_lock, kv))
                    continue
                if kv is not None and kv._augmented:
                    resp = kv.execute_request(store, table, request, raw)
                else:
                    resp = (self._execute_raw(store, table, request) if raw
                            else self._execute(store, request))
            if rid is not None:
                try:
                    frames = _encode_reply_frames(resp, rid, raw)
                except Exception:
                    return
                cork.extend(frames)
                cork_bytes += sum(memoryview(f).nbytes for f in frames)
                if cork_bytes >= _CORK_MAX_BYTES and not flush_cork():
                    return
                continue
            if not flush_cork():  # in-order dialects: nothing may pass them
                return
            if not self._respond(resp, legacy, raw, rid, send_lock):
                return

    @staticmethod
    def _execute(store: KVStore, request: Any) -> Tuple[bool, Any]:
        try:
            cmd, args, kwargs = request
            if cmd.startswith("_") or not hasattr(store, cmd):
                raise AttributeError(f"unknown command {cmd!r}")
            return True, getattr(store, cmd)(*args, **kwargs)
        except Exception as exc:  # propagate to client
            return False, exc

    @staticmethod
    def _execute_raw(store: KVStore, table: Tuple[Any, ...],
                     request: Tuple[int, tuple, dict]) -> Tuple[bool, Any]:
        """The v4 fast path: dispatch-id indexing into the precomputed
        bound-method table — no getattr, no name checks. A raw
        ``execute_batch`` runs its id-dispatched entries under ONE
        take-all-stripes ``transaction`` (same EVAL accounting and same
        blocking-clamp semantics as ``KVStore.execute_batch``: the
        store's in-transaction guard forces blocking entries
        non-blocking)."""
        cid, args, kwargs = request
        try:
            if cid == serialization.RAW_EXEC_ID:
                entries = args[0]

                def run(s: KVStore) -> List[Tuple[bool, Any]]:
                    out: List[Tuple[bool, Any]] = []
                    for ecid, ea, ek in entries:
                        try:
                            fn = table[ecid]
                            if fn is None:
                                raise AttributeError(
                                    "unknown command "
                                    f"{serialization.RAW_COMMANDS[ecid]!r}")
                            out.append((True, fn(*ea, **ek)))
                        except Exception as exc:
                            out.append((False, exc))
                    return out

                return True, store.transaction(run)
            fn = table[cid]
            if fn is None:
                raise AttributeError(
                    f"unknown command {serialization.RAW_COMMANDS[cid]!r}")
            return True, fn(*args, **kwargs)
        except Exception as exc:  # propagate to client
            return False, exc

    def _serve_one(self, store: KVStore, table: Tuple[Any, ...],
                   request: Any, legacy: bool, raw: bool,
                   rid: Optional[int], send_lock: threading.Lock,
                   kv: Any = None) -> bool:
        if kv is not None and kv._augmented:
            resp = kv.execute_request(store, table, request, raw)
        else:
            resp = (self._execute_raw(store, table, request) if raw
                    else self._execute(store, request))
        return self._respond(resp, legacy, raw, rid, send_lock)

    def _respond(self, resp: Tuple[bool, Any], legacy: bool, raw: bool,
                 rid: Optional[int], send_lock: threading.Lock) -> bool:
        try:
            if legacy:
                payload = serialization.dumps(
                    resp, protocol=_LEGACY_PICKLE_PROTOCOL)
                with send_lock:
                    _send_frame(self.request, payload)
            else:
                frames = _encode_reply_frames(resp, rid, raw)
                with send_lock:
                    _sendv(self.request, frames)
            return True
        except OSError:
            return False


def _request_blocks(request: Any) -> bool:
    try:
        cmd, args, kwargs = request
        return _blocks(cmd, args, kwargs)
    except Exception:
        return False


class _Server(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True
    allow_shm = False  # rings rendezvous on the Unix listener only


if hasattr(socketserver, "ThreadingUnixStreamServer"):
    class _UnixServer(socketserver.ThreadingUnixStreamServer):
        """Unix-socket listener sharing :class:`_Handler` with the TCP
        server — and the shm rendezvous listener: with ``allow_shm``,
        magic-word connections upgrade to rings (see ``_Handler``),
        tracked here so ``KVServer.stop()`` can wake parked handlers and
        release the mappings."""

        daemon_threads = True
        allow_shm = False

        def __init__(self, *args: Any, **kwargs: Any):
            self._rings: set = set()
            self._rings_lock = threading.Lock()
            super().__init__(*args, **kwargs)

        def track_ring(self, ring: Any) -> None:
            with self._rings_lock:
                self._rings.add(ring)

        def untrack_ring(self, ring: Any) -> None:
            with self._rings_lock:
                self._rings.discard(ring)

        def close_rings(self) -> None:
            with self._rings_lock:
                rings, self._rings = list(self._rings), set()
            for r in rings:
                r.close()
else:  # pragma: no cover - platform without AF_UNIX
    _UnixServer = None  # type: ignore[assignment,misc]


# ---------------------------------------------------------------------------
# Replication (PR 7): command-log streaming from a primary to replicas
# ---------------------------------------------------------------------------

#: every store command that mutates state — the replication predicate
#: (logged on a primary, redirected on a replica). Read-only commands
#: never enter the replicated path and keep the striped fast path even
#: when replication is attached.
_MUTATING_COMMANDS = frozenset({
    "set", "setnx", "getset", "incr", "incrby", "decr",
    "mset", "setrange", "msetrange",
    "lpush", "rpush", "lpop", "rpop", "rpoplpush", "lset", "ltrim",
    "blpop", "brpop", "blpop_rpush",
    "blpop_lease", "lease_renew", "lease_release", "lease_reap",
    "hset", "hsetnx", "hdel", "hincrby",
    "sadd", "srem",
    "delete", "expire", "persist", "flushall",
    "execute_batch", "transaction",
})

#: blocking mutators need the park-then-log treatment (see
#: ``_Replicator._run_blocking``): the realized EFFECT is what gets
#: logged, as its non-blocking equivalent, so replicas never park.
_REPL_BLOCKING = frozenset({"blpop", "brpop", "blpop_rpush", "blpop_lease"})

#: the realized-effect rewrite for blocking pops: a blpop that popped
#: key k replays on replicas as lpop(k) — per-key log order makes it
#: pop the same element.
_REPL_POP_EFFECT = {"blpop": "lpop", "brpop": "rpop"}

_REPL_CHUNK = 256            # max log entries per repl_apply delivery
_REPL_LOG_CAP = 1 << 16      # primary log entries retained for laggards
_REPL_LOG_TAIL = 1024        # entries always kept for late (re)attaches
_REPL_RETAIN = 1 << 16       # replica-side retention (promotion catch-up)
_REPL_BLOCK_SLICE_S = 0.05   # parked-primary poll slice under replication
_REPL_RECONNECT_MIN_S = 0.05
_REPL_RECONNECT_MAX_S = 1.0


class _ReplicaLink:
    """One replica's streamer: a daemon thread that tails the primary's
    command log and ships it as ``repl_apply(first_seq, entries)``
    batches over a normal :class:`KVClient` — replication rides the
    same wire dialects (v4 raw for scalar entries, pickle + OOB for
    everything else) and the same pluggable transports as client
    traffic. ``acked`` is the highest sequence the replica confirmed
    applied; quorum waiters read it under the replicator's lock."""

    def __init__(self, rep: "_Replicator", urls: Sequence[str]):
        self.rep = rep
        self.urls = [str(u) for u in urls]
        self.key = frozenset(self.urls)
        self.acked = 0
        self._stop = False
        self._thread = threading.Thread(
            target=self._run, daemon=True, name="kv-repl-stream")

    def start(self) -> None:
        self._thread.start()

    def _close(self, client: Any) -> None:
        if client is not None:
            try:
                client.close()
            except Exception:
                pass

    def _run(self) -> None:
        rep = self.rep
        client: Optional["KVClient"] = None
        backoff = _REPL_RECONNECT_MIN_S
        # chaos knob: duplicate every Nth delivery (cross-process — the
        # harness sets this in the supervisor's environment and shard
        # children inherit it); replicas dedup by sequence number.
        try:
            dup_every = int(os.environ.get("REPRO_REPL_DUP_EVERY", "0") or 0)
        except ValueError:
            dup_every = 0
        nsent = 0
        while not (self._stop or rep._stopped):
            if client is None:
                try:
                    client = KVClient(self.urls)
                    info = client.repl_info()
                    with rep._cond:
                        self.acked = max(self.acked,
                                         int(info.get("seq", 0) or 0))
                        rep._cond.notify_all()
                    backoff = _REPL_RECONNECT_MIN_S
                except (ConnectionError, OSError, ValueError, EOFError):
                    self._close(client)
                    client = None
                    time.sleep(backoff)
                    backoff = min(backoff * 2, _REPL_RECONNECT_MAX_S)
                    continue
            with rep._cond:
                while not (self._stop or rep._stopped) \
                        and rep._seq <= self.acked:
                    rep._cond.wait(0.5)
                if self._stop or rep._stopped:
                    break
                first = self.acked + 1
                if first < rep._base:
                    chunk = None  # truncated past us: cannot catch up
                else:
                    i0 = first - rep._base
                    chunk = list(_islice(rep._log, i0, i0 + _REPL_CHUNK))
            if chunk is None:
                sys.stderr.write(
                    f"[kv-repl] replica {self.urls[0]} lags behind the "
                    f"log retention window; detaching\n")
                rep.detach_link(self)
                break
            if not chunk:
                continue
            try:
                newseq = client.repl_apply(first, chunk)
                nsent += 1
                fi = _transport.get_fault_injector()
                if ((fi is not None and fi.should_duplicate())
                        or (dup_every and nsent % dup_every == 0)):
                    # duplicate delivery: replicas ignore seq <= applied
                    client.repl_apply(first, chunk)
            except (ConnectionError, OSError, EOFError):
                self._close(client)
                client = None
                continue
            except Exception:
                # e.g. a gap error after a missed ack: resync from the
                # replica's authoritative applied sequence
                try:
                    info = client.repl_info()
                    newseq = int(info.get("seq", 0) or 0)
                except Exception:
                    self._close(client)
                    client = None
                    continue
            with rep._cond:
                if int(newseq) > self.acked:
                    self.acked = int(newseq)
                rep._cond.notify_all()
            rep.truncate()
        self._close(client)


class _Replicator:
    """The primary half of shard replication.

    Owns the command log (a bounded deque of ``(cmd, args, kwargs)``
    name-form entries), one :class:`_ReplicaLink` streamer per attached
    replica, and the ack policy. Mutating commands execute under ONE
    ``_exec_lock`` so the log order equals the execution order — the
    invariant replicas rely on to converge by pure replay. That global
    ordering is the throughput price of replication; it is only paid
    when a replicator is attached (``replicas=0`` keeps the striped
    lock-free-reader fast path untouched).

    Lock order: ``_exec_lock`` (execution serialization, outermost) may
    take ``_cond``'s lock (log/links/acks, innermost); streamer threads
    and ack waiters only ever take ``_cond``'s lock. Quorum waits happen
    OUTSIDE ``_exec_lock`` so replication latency pipelines across
    connections instead of serializing them."""

    def __init__(self, kv: "KVServer", ack: str = "primary",
                 quorum_timeout: float = 5.0):
        if ack not in ("primary", "quorum"):
            raise ValueError(f"unknown ack policy {ack!r}")
        self.kv = kv
        self.ack = ack
        self.quorum_timeout = float(quorum_timeout)
        self._exec_lock = threading.Lock()
        self._cond = threading.Condition(threading.Lock())
        self._log: deque = deque()
        self._base = 1           # seq of _log[0]
        self._seq = 0            # last appended seq
        self._links: List[_ReplicaLink] = []
        self._stopped = False

    # -- log ----------------------------------------------------------------

    def seed(self, applied_seq: int, retained: Sequence[Tuple[int, Any]]
             ) -> None:
        """Adopt a promoted replica's applied history as this log, so
        surviving peers can catch up from their own acked position."""
        with self._cond:
            ents = [e for s, e in retained if s <= applied_seq]
            self._seq = int(applied_seq)
            self._log = deque(ents)
            self._base = self._seq - len(ents) + 1

    def append(self, entry: Tuple[str, tuple, dict]) -> int:
        with self._cond:
            self._seq += 1
            self._log.append(entry)
            drop = len(self._log) - _REPL_LOG_CAP
            for _ in range(max(0, drop)):
                self._log.popleft()
                self._base += 1
            self._cond.notify_all()
            return self._seq

    def truncate(self) -> None:
        """Drop entries every live replica has acked (keeping a fixed
        tail for late re-attaches)."""
        with self._cond:
            if not self._links:
                return
            floor = min(l.acked for l in self._links)
            drop = min(floor - self._base + 1,
                       len(self._log) - _REPL_LOG_TAIL)
            for _ in range(max(0, drop)):
                self._log.popleft()
                self._base += 1

    def head_seq(self) -> int:
        return self._seq

    # -- membership ---------------------------------------------------------

    def attach(self, urls: Sequence[str]) -> bool:
        key = frozenset(str(u) for u in urls)
        with self._cond:
            if self._stopped or any(l.key == key for l in self._links):
                return False
            link = _ReplicaLink(self, urls)
            self._links.append(link)
        link.start()
        return True

    def detach(self, urls: Sequence[str]) -> bool:
        key = frozenset(str(u) for u in urls)
        with self._cond:
            found = [l for l in self._links if l.key == key]
            for l in found:
                self._links.remove(l)
                l._stop = True
            self._cond.notify_all()
        return bool(found)

    def detach_link(self, link: _ReplicaLink) -> None:
        with self._cond:
            if link in self._links:
                self._links.remove(link)
            link._stop = True
            self._cond.notify_all()

    def n_links(self) -> int:
        with self._cond:
            return len(self._links)

    def stop(self) -> None:
        with self._cond:
            self._stopped = True
            for l in self._links:
                l._stop = True
            self._links = []
            self._cond.notify_all()

    # -- ack policy ---------------------------------------------------------

    def wait_ack(self, seq: int) -> bool:
        """``ack="primary"``: return immediately (async replication).
        ``ack="quorum"``: block until a majority of the replica set
        (primary included) holds the entry, so an acknowledged write
        survives any minority of failures."""
        if self.ack != "quorum":
            return True
        deadline = time.monotonic() + self.quorum_timeout
        with self._cond:
            while True:
                need = (len(self._links) + 1) // 2  # replica acks needed
                have = sum(1 for l in self._links if l.acked >= seq)
                if have >= need:
                    return True
                left = deadline - time.monotonic()
                if left <= 0 or self._stopped:
                    return False
                self._cond.wait(min(left, 0.5))

    # -- replicated execution ----------------------------------------------

    def run(self, store: KVStore, name: str, args: tuple, kwargs: dict,
            raw: bool) -> Tuple[bool, Any]:
        if name in _REPL_BLOCKING and _blocks(name, args, kwargs):
            return self._run_blocking(store, name, args, kwargs)
        with self._exec_lock:
            try:
                if name == "execute_batch":
                    entries = args[0]
                    if raw:
                        entries = [(serialization.RAW_COMMANDS[ecid], ea, ek)
                                   for ecid, ea, ek in entries]
                    value = store.execute_batch(entries)
                    sub = [e for e, (ok, _v) in zip(entries, value)
                           if ok and e[0] in _MUTATING_COMMANDS]
                    entry = ("execute_batch", (sub,), {}) if sub else None
                elif name == "transaction":
                    value = store.transaction(*args, **kwargs)
                    # the fn crossed the wire to us, so it crosses to
                    # replicas the same way (pickle dialect)
                    entry = ("transaction", args, kwargs)
                elif name in _REPL_BLOCKING:
                    # non-blocking form (timeout<=0) of a blocking pop
                    value = getattr(store, name)(*args, **kwargs)
                    entry = self._pop_effect(name, args, value)
                else:
                    value = getattr(store, name)(*args, **kwargs)
                    entry = (name, args, kwargs)
            except Exception as exc:
                return False, exc
            seq = self.append(entry) if entry is not None else 0
        if seq and not self.wait_ack(seq):
            return False, ShardUnavailableError(
                f"write applied on primary but {self.ack!r} ack not "
                f"reached within {self.quorum_timeout}s",
                shard=self.kv.shard_index)
        return True, value

    @staticmethod
    def _pop_effect(name: str, args: tuple, value: Any
                    ) -> Optional[Tuple[str, tuple, dict]]:
        """Log a blocking pop as its realized non-blocking effect."""
        if value is None:
            return None  # timed out: nothing mutated, nothing to log
        if name == "blpop_rpush":
            return ("blpop_rpush", (args[0], args[1], args[2], 0.0), {})
        if name == "blpop_lease":
            # the replica replays the non-blocking form and pops the same
            # element (per-key log order); the lease DEADLINE is stamped
            # with the replica's own clock at apply time — approximate,
            # which the attempt fence keeps safe across a failover
            return ("blpop_lease",
                    (args[0], args[1], args[2], args[3], 0.0), {})
        return (_REPL_POP_EFFECT[name], (value[0],), {})

    def _run_blocking(self, store: KVStore, name: str, args: tuple,
                      kwargs: dict) -> Tuple[bool, Any]:
        """Primary-side parked pops under replication: attempt the
        non-blocking form under ``_exec_lock`` (so a successful pop and
        its log entry are atomic), park on ``bllen`` between attempts
        (wakeup-driven, read-only, no lock held), repeat until the
        deadline. Replicas therefore only ever see the realized effect
        and never park themselves."""
        if name == "blpop_rpush":
            wait_key = args[0]
            timeout = args[3] if len(args) > 3 else kwargs.get("timeout")
            attempt_args = (args[0], args[1], args[2], 0.0)

            def attempt() -> Any:
                return store.blpop_rpush(*attempt_args)
        elif name == "blpop_lease":
            wait_key = args[0]
            timeout = args[4] if len(args) > 4 else kwargs.get("timeout")
            lease_args = (args[0], args[1], args[2], args[3], 0.0)

            def attempt() -> Any:
                return store.blpop_lease(*lease_args)
        else:
            keys = [args[0]] if isinstance(args[0], str) else list(args[0])
            wait_key = keys[0]
            timeout = args[1] if len(args) > 1 else kwargs.get("timeout")

            def attempt() -> Any:
                return getattr(store, name)(keys, 0.0)
        deadline = (None if timeout is None
                    else time.monotonic() + float(timeout))
        while True:
            with self._exec_lock:
                try:
                    value = attempt()
                except Exception as exc:
                    return False, exc
                seq = 0
                if value is not None:
                    entry = self._pop_effect(name, args, value)
                    if entry is not None:
                        seq = self.append(entry)
            if value is not None:
                if seq and not self.wait_ack(seq):
                    return False, ShardUnavailableError(
                        f"pop applied on primary but {self.ack!r} ack "
                        f"not reached within {self.quorum_timeout}s",
                        shard=self.kv.shard_index)
                return True, value
            left = (None if deadline is None
                    else deadline - time.monotonic())
            if left is not None and left <= 0:
                return True, None
            park = _REPL_BLOCK_SLICE_S if left is None \
                else min(_REPL_BLOCK_SLICE_S, left)
            try:
                store.bllen(wait_key, park)
            except Exception:
                time.sleep(park)


class KVServer:
    """Serve a KVStore over every same-host carrier at once.

    Listens on TCP and (where the platform supports it) a Unix-domain
    socket simultaneously — the SAME store, dispatch table, and handler
    behind both — with the Unix socket doubling as the shared-memory
    ring rendezvous (``shm://``). ``endpoints`` advertises all carriers
    as self-describing urls; ``address`` stays the ``(host, port)``
    tuple, so existing callers (and old clients that only understand
    tuples) keep working over TCP unchanged.

    The Unix socket binds at a FRESH per-instance path under a private
    ``tempfile.mkdtemp`` directory, unlinked on ``stop()`` — a
    (re)spawned server never contends for a stale path, so there is no
    EADDRINUSE analogue to race on restart. Use as a context manager or
    ``start()``/``stop()``.
    """

    def __init__(self, store: Optional[KVStore] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 uds: bool = True, shm: bool = True,
                 replica: bool = False, shard_index: int = -1):
        self.store = store or KVStore(name="kvserver")
        # -- replication state (PR 7) --------------------------------------
        # A server is always repl-capable: the repl_* admin commands are
        # installed on the store (so both dispatch paths see them) BEFORE
        # the dispatch table is built. ``_augmented`` gates the per-request
        # replication/redirect check — False (one attribute read) unless
        # this server is a replica or has a replicator attached.
        self.shard_index = int(shard_index)
        self._epoch = 0
        self._replica_mode = bool(replica)
        self.replicator: Optional[_Replicator] = None
        self._augmented = self._replica_mode
        self._role_lock = threading.Lock()
        self._applied_seq = 0
        self._retained: deque = deque(maxlen=_REPL_RETAIN)
        self._repl_ack = "primary"
        self._repl_quorum_timeout = 5.0
        st = self.store
        st.repl_apply = self.repl_apply        # replica apply loop
        st.repl_info = self.repl_info          # freshness/role probe
        st.repl_attach = self.repl_attach      # wire (re)attach
        st.repl_detach = self.repl_detach      # wire detach (watchdog)
        st.repl_promote = self.repl_promote    # replica -> primary flip
        self._server = _Server((host, port), _Handler)
        self._server.store = self.store  # type: ignore[attr-defined]
        self._server.kv = self  # type: ignore[attr-defined]
        # v4 fast path: cid -> bound method, built once for every handler
        self._server.raw_dispatch = _build_dispatch(  # type: ignore[attr-defined]
            self.store)
        self._uds_server: Optional[Any] = None
        self._uds_path: Optional[str] = None
        self._uds_dir: Optional[str] = None
        self._shm_enabled = False
        if uds and _UnixServer is not None:
            import tempfile
            self._uds_dir = tempfile.mkdtemp(prefix="repro-kv-")
            self._uds_path = os.path.join(self._uds_dir, "kv.sock")
            try:
                usrv = _UnixServer(self._uds_path, _Handler)
            except OSError:
                self._remove_uds_path()  # pathological tmpdir: TCP-only
            else:
                usrv.store = self.store  # type: ignore[attr-defined]
                usrv.kv = self  # type: ignore[attr-defined]
                usrv.raw_dispatch = (  # type: ignore[attr-defined]
                    self._server.raw_dispatch)
                self._shm_enabled = shm and _transport.ring_supported()
                usrv.allow_shm = self._shm_enabled
                self._uds_server = usrv
        self._thread: Optional[threading.Thread] = None
        self._uds_thread: Optional[threading.Thread] = None

    @property
    def address(self) -> Tuple[str, int]:
        return self._server.server_address  # type: ignore[return-value]

    @property
    def endpoints(self) -> List[str]:
        """Every carrier this server answers on, as endpoint urls in
        advertisement order (tcp first — reachable from anywhere — then
        the same-host carriers). Feed the whole list to ``KVClient`` to
        let it pick; cheapest-first selection is the client's job."""
        host, port = self.address[0], self.address[1]
        eps = [f"tcp://{host}:{port}"]
        if self._uds_server is not None and self._uds_path:
            eps.append(f"uds://{self._uds_path}")
            if self._shm_enabled:
                eps.append(f"shm://{self._uds_path}")
        return eps

    # -- replication (PR 7) -------------------------------------------------

    def execute_request(self, store: KVStore, table: Tuple[Any, ...],
                        request: Any, raw: bool) -> Tuple[bool, Any]:
        """Replication-aware execution, entered only when ``_augmented``:
        replicas redirect mutating commands (typed, epoch-carrying
        refusal — the client's cue to refetch the descriptor); a primary
        with a replicator routes mutators through the log. Everything
        else falls through to the exact non-replicated dispatch."""
        try:
            name = (serialization.RAW_COMMANDS[request[0]] if raw
                    else request[0])
        except Exception:
            name = ""
        if isinstance(name, str) and name in _MUTATING_COMMANDS:
            if self._replica_mode:
                return False, ShardRedirectError(
                    f"replica cannot serve {name!r}; refetch the cluster "
                    f"descriptor", self._epoch, self.shard_index)
            rep = self.replicator
            if rep is not None:
                return rep.run(store, name, request[1], request[2], raw)
        return (_Handler._execute_raw(store, table, request) if raw
                else _Handler._execute(store, request))

    def attach_replica(self, urls: Sequence[str],
                       ack: Optional[str] = None,
                       quorum_timeout: Optional[float] = None) -> bool:
        """Attach one replica (endpoint url list) and start streaming
        the command log to it. Creates the replicator on first use."""
        with self._role_lock:
            if ack is not None:
                self._repl_ack = ack
            if quorum_timeout is not None:
                self._repl_quorum_timeout = float(quorum_timeout)
            rep = self.replicator
            if rep is None:
                rep = _Replicator(self, ack=self._repl_ack,
                                  quorum_timeout=self._repl_quorum_timeout)
                self.replicator = rep
                self._augmented = True
            else:
                rep.ack = self._repl_ack
                rep.quorum_timeout = self._repl_quorum_timeout
        return rep.attach(urls)

    # wire admin commands (installed as store attributes so both the
    # pickle path's getattr dispatch and the v4 table reach them)

    def repl_apply(self, first_seq: int, entries: Sequence[Any]) -> int:
        """Replica apply loop: replay ``entries`` (seq ``first_seq``..)
        in order, ignoring already-applied sequences — duplicate
        deliveries (retries, chaos injection) are harmless — and
        raising on a gap so the streamer resyncs from ``repl_info``."""
        store = self.store
        with self._role_lock:
            seq = self._applied_seq
            for i, ent in enumerate(entries):
                s = first_seq + i
                if s <= seq:
                    continue  # duplicate delivery: already applied
                if s != seq + 1:
                    raise ValueError(
                        f"replication gap: applied {seq}, got {s}")
                cmd, cargs, ckwargs = ent
                if (type(cmd) is not str or cmd.startswith("_")
                        or cmd.startswith("repl_")):
                    raise ValueError(f"illegal replicated command {cmd!r}")
                try:
                    getattr(store, cmd)(*cargs, **(ckwargs or {}))
                except Exception as exc:
                    # replay of a command that succeeded on the primary
                    # is deterministic; a failure here means state has
                    # diverged — surface it, but keep the stream moving
                    sys.stderr.write(
                        f"[kv-repl] apply {cmd!r} at seq {s} failed: "
                        f"{exc!r}\n")
                self._retained.append((s, ent))
                seq = s
            self._applied_seq = seq
            return seq

    def repl_info(self) -> Dict[str, Any]:
        rep = self.replicator
        if rep is not None:
            return {"seq": rep.head_seq(), "role": "primary",
                    "epoch": self._epoch, "replicas": rep.n_links()}
        role = "replica" if self._replica_mode else "primary"
        return {"seq": self._applied_seq, "role": role,
                "epoch": self._epoch, "replicas": 0}

    def repl_attach(self, urls: Sequence[str],
                     ack: Optional[str] = None,
                     quorum_timeout: Optional[float] = None) -> bool:
        return self.attach_replica(urls, ack=ack,
                                   quorum_timeout=quorum_timeout)

    def repl_detach(self, urls: Sequence[str]) -> bool:
        rep = self.replicator
        return rep.detach(urls) if rep is not None else False

    def repl_promote(self, peers: Sequence[Sequence[str]] = (),
                      ack: str = "primary", quorum_timeout: float = 5.0,
                      epoch: int = 0) -> Dict[str, Any]:
        """Flip this replica into a primary: stop redirecting, adopt the
        retained apply history as the new command log, and start
        streaming to the surviving ``peers`` (each an endpoint url
        list), which catch up from their own acked positions."""
        with self._role_lock:
            self._replica_mode = False
            self._epoch = int(epoch)
            self._repl_ack = ack
            self._repl_quorum_timeout = float(quorum_timeout)
            rep = self.replicator
            if rep is None:
                rep = _Replicator(self, ack=ack,
                                  quorum_timeout=float(quorum_timeout))
                rep.seed(self._applied_seq, list(self._retained))
                self.replicator = rep
            self._augmented = True
        for urls in peers:
            rep.attach(urls)
        return {"seq": self._applied_seq, "role": "primary",
                "epoch": self._epoch}

    def start(self) -> "KVServer":
        self._thread = threading.Thread(
            target=self._server.serve_forever, kwargs={"poll_interval": 0.05},
            daemon=True, name="kvserver")
        self._thread.start()
        if self._uds_server is not None:
            self._uds_thread = threading.Thread(
                target=self._uds_server.serve_forever,
                kwargs={"poll_interval": 0.05},
                daemon=True, name="kvserver-uds")
            self._uds_thread.start()
        return self

    def stop(self) -> None:
        rep = self.replicator
        if rep is not None:
            rep.stop()
        self._server.shutdown()
        self._server.server_close()
        if self._uds_server is not None:
            self._uds_server.shutdown()
            self._uds_server.server_close()
            # wake handlers parked in ring reads and release the mappings
            self._uds_server.close_rings()
        if self._thread is not None:
            self._thread.join(timeout=5)
        if self._uds_thread is not None:
            self._uds_thread.join(timeout=5)
        self._remove_uds_path()

    def _remove_uds_path(self) -> None:
        """Unlink the socket file and its private directory (idempotent;
        also the failure-path cleanup when the Unix bind never
        happened)."""
        if self._uds_path is not None:
            try:
                os.unlink(self._uds_path)
            except OSError:
                pass
        if self._uds_dir is not None:
            try:
                os.rmdir(self._uds_dir)
            except OSError:
                pass
        self._uds_path = self._uds_dir = None

    def __enter__(self) -> "KVServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


# ---------------------------------------------------------------------------
# Client-side I/O mux (v3)
# ---------------------------------------------------------------------------

#: max commands merged into one group-commit ``execute_batch`` frame
#: (generous: a 4-thread x 2-shard scatter burst of 50-command batches
#: must merge into ONE frame per shard, not split at the cap)
_MUX_COALESCE_MAX = 512
#: rough payload bytes per merged frame before starting a new one (keeps
#: a burst of large blobs from coupling into one giant server-side batch)
_MUX_COALESCE_BYTES = 1 << 20
#: commands never merged into a group-commit batch: they manage their own
#: transactional/latency accounting and nest poorly inside execute_batch
_MUX_NO_COALESCE = frozenset({"transaction", "execute_batch"})


class _MuxPending:
    """One queued submission and its completion slot. ``kind`` is
    "single" (one command, resolves to its ``(ok, value)``) or "batch"
    (an execute_batch of ``ncmds`` commands, resolving to
    ``(ok, [(ok, value), ...])``). The submitting thread blocks in
    ``result()`` until the response is correlated back — or until the
    connection dies, which resolves every pending with the error.

    ``event`` doubles as the reader-baton signal: it fires either because
    the pending RESOLVED (``resolved`` is set first) or because this
    waiter was NOMINATED to take over reading the shared socket (see
    ``_SockMux._await``).

    ``raw_entries``/``raw_body`` hold the v4 pre-encoding, produced AT
    SUBMIT on the submitting thread (outside every mux lock): the
    per-command raw bodies (one for a single, one per batch entry) and
    the standalone frame body. A flat-combined flush then ships them
    as-is, and a group commit merges them by byte concatenation — no
    pickling, no re-encoding under the flush lock. None means the
    request is outside the raw vocabulary and flushes via pickle."""

    __slots__ = ("kind", "request", "ncmds", "coalesce", "sent",
                 "resolved", "ok", "value", "event", "nominated", "mux",
                 "raw_entries", "raw_body", "est")

    def __init__(self, mux: "_SockMux", kind: str, request: Any, ncmds: int,
                 coalesce: bool):
        self.mux = mux
        self.kind = kind
        self.request = request
        self.ncmds = ncmds
        self.coalesce = coalesce
        self.sent = False
        self.resolved = False
        self.nominated = False
        self.ok = False
        self.value: Any = None
        self.event = threading.Event()
        self.raw_entries: Optional[List[bytes]] = None
        self.raw_body: Optional[bytes] = None
        self.est = 0

    def _encode_raw(self) -> None:
        """Pre-encode the request (v4) on the submitting thread."""
        if self.kind == "single":
            body = serialization.encode_command(*self.request)
            if body is not None:
                self.raw_entries = [body]
                self.raw_body = body
        else:  # batch: ("execute_batch", (cmds,), {})
            subs: List[bytes] = []
            for c in self.request[1][0]:
                if c[0] == "execute_batch":
                    return  # no EXEC-in-EXEC on the raw wire: pickle it
                b = serialization.encode_command(*c)
                if b is None:
                    return
                subs.append(b)
            self.raw_entries = subs
            self.raw_body = serialization.encode_batch_entries(subs)

    def _resolve(self, ok: bool, value: Any) -> None:
        self.ok, self.value = ok, value
        self.resolved = True
        self.event.set()

    def result(self) -> Tuple[bool, Any]:
        return self.mux._await(self)


def _est_request_bytes(request: Any) -> int:
    """Cheap payload-size estimate for coalescing bounds (bytes-like args
    one container level deep; exact sizing would require serializing)."""
    est = 64
    try:
        _, args, _ = request
        for a in args:
            if isinstance(a, (bytes, bytearray, memoryview)):
                est += len(a)
            elif isinstance(a, (list, tuple)):
                for x in a[:256]:
                    if isinstance(x, (bytes, bytearray, memoryview)):
                        est += len(x)
                    elif isinstance(x, tuple):
                        for y in x:
                            if isinstance(y, (bytes, bytearray, memoryview)):
                                est += len(y)
    except Exception:
        pass
    return est


class _SockMux:
    """One persistent v3 connection shared by every thread of a process.

    **Writes — flat combining.** Submissions enqueue under ``_qlock`` and
    are written by whichever thread wins ``_wlock``: the winner drains
    the WHOLE queue — its own request plus everything that piled up while
    the previous flush was on the wire — registers the request ids, and
    ships all frames in one gather write. Coalescible singles/batches
    that drained together merge into one ``execute_batch`` frame per
    ~_MUX_COALESCE_MAX commands (group commit); everything else goes as
    its own tagged frame in the same write.

    **Reads — leader/follower.** There is NO dedicated reader thread: the
    waiters themselves take turns owning the socket's read side. Exactly
    one waiter at a time is the *reader* (``_reader_active``): it decodes
    frames and resolves whichever futures they answer — in whatever order
    the server replies — until its OWN pending resolves, then hands the
    baton to another waiter (nominating it through its event). A thread
    awaiting a solo request therefore reads its response synchronously
    with zero handoffs — the same syscall path as a private socket —
    while under concurrency one reader wakeup resolves a whole burst of
    futures. (A dedicated reader thread costs two context switches per
    round trip; on a contended box that measured ~2x on single-command
    latency.)

    When the connection dies — EOF, reset, or ``close()`` — every
    in-flight AND still-queued future is failed with ``ConnectionError``:
    no submitting thread is ever left parked on a future whose response
    can no longer arrive.
    """

    def __init__(self, address: Any, name: str = "mux",
                 raw: bool = True):
        # ``address`` is anything normalize_endpoints accepts — a legacy
        # (host, port) tuple, an endpoint url, or a PRE-ORDERED Endpoint
        # list (what KVClient hands us): first carrier that answers wins
        if (isinstance(address, list) and address
                and isinstance(address[0], _transport.Endpoint)):
            eps = address
        else:
            eps = _transport.order_endpoints(
                _transport.normalize_endpoints(address))
        self.name = name
        self.raw = raw  # v4 submit-time encoding (False = pickle v3 A/B)
        self.pid = _CUR_PID  # a forked child must not share the socket
        self.sock, self.endpoint = _transport.connect_endpoints(eps)
        self.address = self.endpoint.url   # diagnostics only
        _tune(self.sock)
        self._qlock = threading.Lock()   # queue, inflight, rid, reader baton
        self._wlock = threading.Lock()   # flush leadership (held across send)
        self._queue: deque = deque()
        self._inflight: Dict[int, Tuple[str, Any]] = {}
        self._rid = 0
        self._dead: Optional[BaseException] = None
        self._reader_active = False
        self._conn_reader = _ConnReader(self.sock)  # active reader only

    # -- submission ----------------------------------------------------------

    @property
    def alive(self) -> bool:
        return self._dead is None

    def submit(self, kind: str, request: Any, ncmds: int = 1,
               coalesce: bool = True, flush: bool = True) -> _MuxPending:
        """Queue one request; returns its pending (await via
        ``.result()``). ``flush=False`` only enqueues — the caller
        promises a later ``flush()`` (the cluster scatter path queues
        every shard's batch first so co-resident shards coalesce into one
        frame)."""
        p = _MuxPending(self, kind, request, ncmds, coalesce)
        if self.raw:
            p._encode_raw()
        p.est = (len(p.raw_body) + 16 if p.raw_body is not None
                 else _est_request_bytes(request))
        with self._qlock:
            if self._dead is not None:
                raise ConnectionError(
                    f"kv mux to {self.address} is closed: {self._dead}")
            self._queue.append(p)
        if flush:
            self.flush(p)
        return p

    def flush(self, pending: Optional[_MuxPending] = None) -> None:
        """Ensure everything queued so far is written. The thread that
        wins the write lock drains the whole queue, so by the time the
        lock is ours either our pending was already shipped by a previous
        leader or we ship it (with everything queued behind it)."""
        if pending is not None and pending.sent:
            return
        with self._wlock:
            if pending is not None and pending.sent:
                return
            self._write_queued()

    def _next_rid_locked(self) -> int:
        rid = self._rid
        self._rid = (self._rid + 1) % serialization.MAX_FRAME_TAG
        return rid

    def _write_queued(self) -> None:
        """Must hold ``_wlock``. Drain the queue, register ids, encode,
        and gather-write every resulting frame in one sendmsg pass."""
        with self._qlock:
            if self._dead is not None:
                self._queue.clear()
                return
            batch = list(self._queue)
            self._queue.clear()
            if not batch:
                return
            # Register BEFORE the write: a response can arrive the instant
            # the frame hits the wire, and the reader must find its entry.
            plans = self._plan_locked(batch)
            for p in batch:
                p.sent = True
            # someone must be reading for these responses; if nobody is,
            # nominate now (the nominee may park in recv before the frame
            # is even written — harmless)
            self._nominate_locked()
        frames: List[Any] = []
        for rid, request, raw_body in plans:
            try:
                if raw_body is not None:
                    # pre-encoded at submit (or a byte-concatenated merge
                    # of pre-encoded entries): nothing to pickle here
                    if not isinstance(raw_body, bytes):
                        raw_body = serialization.encode_batch_entries(raw_body)
                    frames.extend(_frame_parts([raw_body], rid, raw=True))
                else:
                    frames.extend(_encode_frames(request, rid))
            except Exception as exc:
                # encoding failed BEFORE anything hit the wire: fail only
                # this plan's futures (unregistering the rid) and keep
                # the connection — the guilty pending must not strand its
                # co-batched peers in _inflight forever, and an
                # unpicklable argument must not kill everyone's transport
                with self._qlock:
                    entry = self._inflight.pop(rid, None)
                if entry is not None:
                    self._resolve(entry, (False, exc))
        try:
            if frames:
                _sendv(self.sock, frames)
        except Exception as exc:
            # a partial gather write leaves unframeable bytes on the wire:
            # the connection is unrecoverable for everyone multiplexed on it
            self._kill(ConnectionError(f"kv mux send failed: {exc!r}"))

    def _plan_locked(self, batch: List[_MuxPending]
                     ) -> List[Tuple[int, Any, Any]]:
        """Must hold ``_qlock``. Turn drained pendings into wire plans
        ``(rid, request, raw)``: non-coalescible pendings ship as their
        own tagged frame; runs of coalescible pendings merge into
        group-commit ``execute_batch`` frames, bounded by command count
        and estimated bytes. ``raw`` is the pre-encoded v4 body (bytes),
        a list of pre-encoded entry bodies to concatenate outside this
        lock (a merged group where every member pre-encoded), or None
        (pickle the ``request`` at write time — the fallback dialect)."""
        plans: List[Tuple[int, Any, Any]] = []
        group: List[_MuxPending] = []
        group_cmds = 0
        group_bytes = 0

        def close_group() -> None:
            nonlocal group, group_cmds, group_bytes
            if not group:
                return
            if len(group) == 1:
                p = group[0]
                rid = self._next_rid_locked()
                self._inflight[rid] = (p.kind, p)
                plans.append((rid, p.request, p.raw_body))
            else:
                specs: List[Tuple[_MuxPending, int]] = [
                    (p, 1 if p.kind == "single" else p.ncmds) for p in group]
                if all(p.raw_entries is not None for p in group):
                    raw: Any = [s for p in group for s in p.raw_entries]
                    request = None
                else:
                    cmds: List[Any] = []
                    for p in group:
                        if p.kind == "single":
                            cmds.append(p.request)
                        else:
                            cmds.extend(p.request[1][0])
                    raw = None
                    request = ("execute_batch", (cmds,), {})
                rid = self._next_rid_locked()
                self._inflight[rid] = ("merged", specs)
                plans.append((rid, request, raw))
            group, group_cmds, group_bytes = [], 0, 0

        for p in batch:
            if not p.coalesce:
                close_group()
                rid = self._next_rid_locked()
                self._inflight[rid] = (p.kind, p)
                plans.append((rid, p.request, p.raw_body))
                continue
            if group and (group_cmds + p.ncmds > _MUX_COALESCE_MAX
                          or group_bytes + p.est > _MUX_COALESCE_BYTES):
                close_group()
            group.append(p)
            group_cmds += p.ncmds
            group_bytes += p.est
        close_group()
        return plans

    # -- responses (leader/follower reads) -----------------------------------

    def _await(self, p: _MuxPending) -> Tuple[bool, Any]:
        """Block until ``p`` resolves. Wakes either RESOLVED (a reader —
        possibly this thread — correlated our response, or the connection
        died) or NOMINATED (hand the socket's read side to this thread:
        read and resolve frames until our own lands, then pass the baton
        on)."""
        while True:
            p.event.wait()
            if p.resolved:
                if p.nominated:
                    # nominated as reader but resolved before reading a
                    # frame (encode failure, or killed) — the baton must
                    # not die with us, or nobody ever reads again
                    with self._qlock:
                        p.nominated = False
                        self._reader_active = False
                        self._nominate_locked()
                return p.ok, p.value
            p.event.clear()
            p.nominated = False
            self._read_until(p)

    def _read_until(self, p: _MuxPending) -> None:
        """Read side, owned by this thread until ``p`` resolves. Every
        decoded frame resolves whatever future it answers. After our own
        response lands we keep draining frames the reader has ALREADY
        buffered (the server corks a burst's responses into one write, so
        they arrive together) — resolving a whole burst under one baton
        owner instead of waking a new reader per frame — then pass the
        baton to any still-pending waiter."""
        try:
            while not p.resolved or self._conn_reader.buffered > 0:
                got = _recv_frames(self._conn_reader)
                if got is None:
                    raise ConnectionError("server closed the connection")
                parts, legacy, raw, lease, rid = got
                try:
                    resp = _decode_reply(parts, legacy, raw)
                finally:
                    if lease is not None:
                        self._conn_reader.pool.release(lease)
                if rid is None:
                    raise ConnectionError(
                        "untagged response on a multiplexed connection")
                with self._qlock:
                    entry = self._inflight.pop(rid, None)
                if entry is not None:
                    self._resolve(entry, resp)
        except BaseException as exc:
            self._kill(ConnectionError(
                f"kv mux connection to {self.address} died: {exc!r}"))
            return
        with self._qlock:
            self._reader_active = False
            self._nominate_locked()

    def _nominate_locked(self) -> None:
        """Must hold ``_qlock``. If responses are owed and nobody is
        reading, pick any in-flight waiter as the next reader."""
        if (self._reader_active or self._dead is not None
                or not self._inflight):
            return
        kind, target = next(iter(self._inflight.values()))
        nominee = target[0][0] if kind == "merged" else target
        self._reader_active = True
        nominee.nominated = True
        nominee.event.set()

    @staticmethod
    def _resolve(entry: Tuple[str, Any], resp: Tuple[bool, Any]) -> None:
        kind, target = entry
        if kind != "merged":
            target._resolve(*resp)
            return
        ok, value = resp
        if not ok:
            # whole group-commit frame failed (connection/protocol level):
            # every constituent future gets the error — none may hang
            for p, _ in target:
                p._resolve(False, value)
            return
        offset = 0
        for p, n in target:
            chunk = value[offset:offset + n]
            offset += n
            if p.kind == "single":
                p._resolve(*chunk[0])
            else:
                p._resolve(True, chunk)

    def _kill(self, exc: BaseException) -> None:
        """Fail every in-flight and queued future, exactly once."""
        with self._qlock:
            if self._dead is None:
                self._dead = exc
            inflight, self._inflight = self._inflight, {}
            queued = list(self._queue)
            self._queue.clear()
            for p in queued:
                p.sent = True  # nothing left to flush
        try:
            # shutdown, not just close: a reader parked in recv on this
            # socket only wakes reliably on SHUT_RDWR
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass
        for kind, target in inflight.values():
            if kind == "merged":
                for p, _ in target:
                    p._resolve(False, exc)
            else:
                target._resolve(False, exc)
        for p in queued:
            p._resolve(False, exc)

    def close(self) -> None:
        self._kill(ConnectionError("kv mux closed"))


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------


class KVClient:
    """Remote KVStore with the same method interface.

    Default transport (``mux=True``): a per-process **I/O mux** — one
    persistent v3 connection shared by every thread, plus one *blocking
    lane* connection for commands that may park server-side (``blpop``
    and friends), so a parked pop never sits between other threads' fast
    commands. Threads submit requests and block on per-request futures;
    the server answers out of order by request tag. Concurrent small
    commands group-commit into one ``execute_batch`` frame per flush (see
    :class:`_SockMux`), which is what collapses the per-frame syscall tax
    an N-thread scatter used to pay.

    ``mux=False`` keeps the PR 3 transport: one socket **per thread**
    (thread-local connections), blocking commands occupying their
    connection server-side, exactly like one Redis connection per Lambda
    container. Benchmarks A/B the two on the same server.

    ``raw=True`` (default) speaks the v4 **raw dialect** for the hot
    command vocabulary: commands and replies cross the wire through the
    struct-packed binary codec (``serialization.encode_command``) with
    automatic per-command fallback to pickle for anything outside it —
    large/OOB values, exotic types, the long tail of commands. On the
    mux transport the raw body is encoded AT SUBMIT on the submitting
    thread, so flat-combined flushes concatenate ready-made buffers
    instead of pickling under the flush lock. ``raw=False`` keeps the
    pure pickle v3/v2 dialects for A/B benchmarking.

    ``pipeline()`` batches commands into one flush (see module docstring);
    ``legacy_protocol=True`` speaks the seed's v1 wire dialect (one
    in-band pickled frame per command) for A/B benchmarking and implies
    ``mux=False`` and ``raw=False``.

    **Transports.** ``address`` accepts the legacy ``(host, port)``
    tuple (plain TCP, unchanged), one endpoint url, or a list of urls —
    typically ``KVServer.endpoints`` or a cluster descriptor's per-shard
    endpoint list. With several carriers advertised the client
    auto-selects the cheapest reachable one per connection
    (shm > uds > tcp, falling back down the list if a connect fails);
    ``transport="tcp"|"uds"|"shm"`` pins one carrier for A/B runs. Lane
    policy under auto-selection: the main lane takes the ring (it is the
    latency-critical path), while blocking-lane connections — which park
    server-side for long stretches — prefer kernel sockets, whose
    sleeping is free, over dedicating a ring pair to a parked command.
    """

    def __init__(self, address: Any,
                 legacy_protocol: Any = _copts.UNSET,
                 mux: Any = _copts.UNSET,
                 raw: Any = _copts.UNSET,
                 transport: Any = _copts.UNSET,
                 failover_timeout_s: Any = _copts.UNSET,
                 options: Optional[_copts.ClientOptions] = None):
        # One resolved ClientOptions backs every knob: the historical
        # kwargs remain as aliases (see repro.core.clientopts for the
        # conflict/back-compat contract).
        opts = _copts.resolve_client_options(
            options, legacy_protocol=legacy_protocol, mux=mux, raw=raw,
            transport=transport, failover_timeout_s=failover_timeout_s)
        self.options = opts
        self.endpoints = _transport.normalize_endpoints(address)
        self.transport = opts.transport
        # .address keeps its historical (host, port) meaning wherever a
        # TCP carrier exists (old callers index into it)
        tcp = next((e for e in self.endpoints if e.scheme == "tcp"), None)
        self.address = (tcp.host, tcp.port) if tcp is not None else address
        self.legacy_protocol = opts.legacy_protocol
        self.mux_enabled = opts.mux and not opts.legacy_protocol
        self.raw_enabled = opts.raw and not opts.legacy_protocol
        self._tls = threading.local()
        # thread ident -> (thread, socket): lets close() reach every live
        # connection and lets _sock() prune entries of exited threads
        # (mux=False transport only)
        self._socks: dict = {}
        self._socks_lock = threading.Lock()
        self._gen = 0  # bumped by close(): invalidates thread-local socks
        self._muxes: Dict[str, _SockMux] = {}   # lane -> connection
        self._mux_lock = threading.Lock()
        self.name = f"kvclient@{self.endpoints[0].url}"

    # -- transports ----------------------------------------------------------

    def _ordered_endpoints(self, lane: str = "main"
                           ) -> List[_transport.Endpoint]:
        """Connection-attempt order for one lane: the pinned transport,
        or cheapest-first auto-selection — except that auto mode keeps
        blocking lanes off the rings (see class docstring)."""
        eps = _transport.order_endpoints(self.endpoints, self.transport)
        if lane != "main" and self.transport is None:
            socks = [e for e in eps if e.scheme != "shm"]
            if socks:
                eps = socks
        return eps

    # -- mux lanes -----------------------------------------------------------

    def _mux(self, lane: str = "main") -> _SockMux:
        """The lane's live mux, (re)connecting if it is absent, died, or
        was inherited across a fork (a child must never share the
        parent's socket — the tags would interleave)."""
        m = self._muxes.get(lane)
        if m is not None and m.alive and m.pid == _CUR_PID:
            return m  # racy peek is safe: replacement only under the lock
        with self._mux_lock:
            m = self._muxes.get(lane)
            if m is not None and m.alive and m.pid == _CUR_PID:
                return m
            if m is not None and m.pid == _CUR_PID:
                m.close()
            m = _SockMux(self._ordered_endpoints(lane),
                         name=f"{lane}@{self.endpoints[0].url}",
                         raw=self.raw_enabled)
            self._muxes[lane] = m
            return m

    def _submit(self, cmd: str, args: tuple, kwargs: dict,
                flush: bool = True) -> _MuxPending:
        """Route one command onto the right lane and submit it. Blocking
        commands (nonzero timeout) ride the blocking lane as standalone
        frames; everything else is a coalescible main-lane submission."""
        if _blocks(cmd, args, kwargs):
            return self._mux("block").submit(
                "single", (cmd, args, kwargs), coalesce=False, flush=flush)
        return self._mux().submit(
            "single", (cmd, args, kwargs),
            coalesce=cmd not in _MUX_NO_COALESCE, flush=flush)

    def _sock(self) -> socket.socket:
        sock = getattr(self._tls, "sock", None)
        if sock is not None and getattr(self._tls, "gen", -1) == self._gen:
            return sock
        if self.legacy_protocol and self.transport is None:
            # the seed client rides the seed carrier: TCP when it is
            # advertised (A/B baselines must measure the seed transport)
            eps = ([e for e in self.endpoints if e.scheme == "tcp"]
                   or self._ordered_endpoints())
        else:
            eps = self._ordered_endpoints()
        sock, _ = _transport.connect_endpoints(eps)
        if self.legacy_protocol:
            # seed client behavior: NODELAY only, default buffers
            if getattr(sock, "family", None) in _INET_FAMILIES:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._tls.chunk = _PIPELINE_CHUNK_BYTES_LEGACY
        else:
            _tune(sock)
            # The chunked-flush deadlock bound assumes the send buffer
            # took our sizing; derive the limit from what the carrier
            # actually granted in case the platform capped it (a ring
            # answers with its capacity, which the default chunk fits).
            sndbuf = sock.getsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF)
            self._tls.chunk = max(
                _PIPELINE_CHUNK_BYTES_LEGACY,
                min(_PIPELINE_CHUNK_BYTES, sndbuf // 2))
        self._tls.sock = sock
        self._tls.reader = _ConnReader(sock)  # thread-private: no lock
        with self._socks_lock:
            # prune connections whose owning thread exited: the registry
            # must not grow forever in thread-churny workloads (the old
            # append-only list leaked one socket per dead thread)
            dead = [tid for tid, (th, _) in self._socks.items()
                    if not th.is_alive()]
            for tid in dead:
                _, s = self._socks.pop(tid)
                try:
                    s.close()
                except OSError:
                    pass
            self._socks[threading.get_ident()] = (
                threading.current_thread(), sock)
            # generation read under the registry lock: a close() racing
            # this creation either sees our registration (and closes the
            # socket) or completed first — then we register into the
            # fresh era with its generation, never a stale one that would
            # orphan this socket on the next call
            self._tls.gen = self._gen
        return sock

    # -- single command (1 RTT) --------------------------------------------

    def _call(self, cmd: str, *args: Any, **kwargs: Any) -> Any:
        if self.mux_enabled:
            ok, value = self._submit(cmd, args, kwargs).result()
        else:
            ok, value = self._roundtrip((cmd, args, kwargs))
        if not ok:
            raise value
        return value

    def _roundtrip(self, request: Tuple[str, tuple, dict]) -> Tuple[bool, Any]:
        sock = self._sock()
        if self.legacy_protocol:
            _send_frame(sock, serialization.dumps(
                request, protocol=_LEGACY_PICKLE_PROTOCOL))
        else:
            _sendv(sock, _encode_request_frames(request,
                                                raw=self.raw_enabled))
        return self._read_response(sock)

    def _read_response(self, sock: socket.socket) -> Tuple[bool, Any]:
        reader = self._tls.reader
        assert reader.sock is sock, "response reader / socket mismatch"
        got = _recv_decode(reader)
        if got is None:
            raise ConnectionError("kvserver closed the connection")
        return got[0]

    # -- pipelining ---------------------------------------------------------

    def pipeline(self, transactional: bool = True) -> "ClientPipeline":
        """Batch commands into one flush.

        transactional=True (default): the batch ships as a single
        ``execute_batch`` frame and runs server-side under one store lock
        acquisition — one RTT, Redis-MULTI semantics (blocking commands
        are forced non-blocking). transactional=False: frames are
        gather-written in buffer-bounded chunks with responses drained
        between chunks (see ``_flush_pipeline``); commands may interleave
        with other connections and blocking commands block server-side.
        """
        return ClientPipeline(self, transactional)

    def _request_frames(self, cmd: Tuple[str, tuple, dict]) -> List[Any]:
        if self.legacy_protocol:
            payload = serialization.dumps(cmd, protocol=_LEGACY_PICKLE_PROTOCOL)
            return [_HDR.pack(len(payload)) + payload]
        return _encode_request_frames(cmd, raw=self.raw_enabled)

    def _flush_pipeline(self, cmds: List[Tuple[str, tuple, dict]],
                        transactional: bool) -> List[Tuple[bool, Any]]:
        if self.mux_enabled:
            return self._flush_pipeline_mux(cmds, transactional)
        if transactional:
            ok, value = self._roundtrip(("execute_batch", (cmds,), {}))
            if not ok:
                raise value
            return value
        # Multi-frame mode: gather-write frames in chunks and drain the
        # pending responses between chunks. Writing ALL requests before
        # reading ANY response would deadlock once requests + responses
        # outgrow the socket buffers in both directions (server blocked
        # writing a response we aren't reading, us blocked writing requests
        # it isn't reading). A chunk is at most _PIPELINE_CHUNK_BYTES (or a
        # single oversized command, which has no undrained responses in
        # flight), so the unread remainder always fits in kernel buffers.
        # Every queued command still yields exactly one drained response,
        # so an error mid-batch cannot desync the framing.
        sock = self._sock()
        limit = self._tls.chunk
        results: List[Tuple[bool, Any]] = []
        sent = 0
        chunk: List[Any] = []
        chunk_cmds = 0
        chunk_bytes = 0
        for c in cmds:
            frames = self._request_frames(c)
            nbytes = sum(memoryview(f).nbytes for f in frames)
            if chunk and chunk_bytes + nbytes > limit:
                _sendv(sock, chunk)
                sent += chunk_cmds
                chunk, chunk_cmds, chunk_bytes = [], 0, 0
                while len(results) < sent:
                    results.append(self._read_response(sock))
            chunk.extend(frames)
            chunk_cmds += 1
            chunk_bytes += nbytes
        if chunk:
            _sendv(sock, chunk)
            sent += chunk_cmds
        while len(results) < sent:
            results.append(self._read_response(sock))
        return results

    def _flush_pipeline_mux(self, cmds: List[Tuple[str, tuple, dict]],
                            transactional: bool) -> List[Tuple[bool, Any]]:
        """Mux-transport pipeline flush. Transactional: ONE coalescible
        ``execute_batch`` submission (group commit may merge it with
        concurrent threads' batches — the merged frame is still one
        server-side transaction containing this batch contiguously).
        Non-transactional: per-command submissions enqueued and flushed
        in byte-bounded chunks, each chunk's futures awaited before the
        next is written — awaiting IS draining under leader/follower
        reads, so the in-flight request volume stays under the socket
        buffering and a bulk batch with bulk responses cannot wedge the
        connection (same invariant as the per-thread chunked flush).
        Blocking commands route to the blocking lane so they genuinely
        block server-side without stalling the chunk."""
        if transactional:
            fut = self._mux().submit("batch", ("execute_batch", (cmds,), {}),
                                     ncmds=len(cmds))
            ok, value = fut.result()
            if not ok:
                raise value
            return value
        results: List[Optional[Tuple[bool, Any]]] = [None] * len(cmds)
        pending: List[Tuple[int, _MuxPending]] = []
        muxes: Dict[int, _MuxPending] = {}   # lane -> LAST pending queued
        est = 0

        def drain() -> None:
            nonlocal pending, muxes, est
            # flush is keyed on the LAST pending per lane: a leader that
            # shipped it shipped everything queued before it too, whereas
            # an earlier representative could be stale (already sent by a
            # concurrent thread's flush) while later ones sit unsent
            for mp in muxes.values():
                mp.mux.flush(mp)
            for i, p in pending:
                results[i] = p.result()
            pending, muxes, est = [], {}, 0

        for i, (cmd, args, kwargs) in enumerate(cmds):
            p = self._submit(cmd, args, kwargs, flush=False)
            pending.append((i, p))
            muxes[id(p.mux)] = p
            est += p.est  # exact for raw-encoded, estimated for pickle
            if est >= _PIPELINE_CHUNK_BYTES:
                drain()
        drain()
        return results  # type: ignore[return-value]

    def __getattr__(self, cmd: str):
        if cmd.startswith("_"):
            raise AttributeError(cmd)

        def call(*args: Any, **kwargs: Any) -> Any:
            return self._call(cmd, *args, **kwargs)
        call.__name__ = cmd
        return call

    def close_connection(self) -> None:
        """Close only the CALLING thread's connection — after a mid-frame
        send/recv failure it may hold a partial frame, but other threads'
        sockets are healthy and must stay up (a blocked blpop elsewhere
        must not die because this thread's scatter failed). The thread
        reconnects on next use."""
        sock = getattr(self._tls, "sock", None)
        if sock is None:
            return
        self._tls.sock = None
        self._tls.reader = None
        with self._socks_lock:
            ent = self._socks.get(threading.get_ident())
            if ent is not None and ent[1] is sock:
                del self._socks[threading.get_ident()]
        try:
            sock.close()
        except OSError:
            pass

    def close(self) -> None:
        """Close every connection — both mux lanes and any per-thread
        registry sockets. Idempotent and safe under concurrent callers
        (registries are swapped out under their locks, so each connection
        is closed exactly once); threads that keep using the client
        afterwards transparently reconnect — a dead mux is replaced on
        next use and thread-local sockets are invalidated by the
        generation bump. Futures still pending on a closed mux resolve
        with ``ConnectionError`` instead of hanging."""
        with self._mux_lock:
            muxes, self._muxes = self._muxes, {}
        for m in muxes.values():
            if m.pid == _CUR_PID:
                m.close()
        with self._socks_lock:
            socks, self._socks = self._socks, {}
            self._gen += 1
        for _, sock in socks.values():
            try:
                sock.close()
            except OSError:
                pass


class ClientPipeline(Pipeline):
    """Wire-level pipeline: same queueing/drain semantics as the in-process
    :class:`repro.core.kvstore.Pipeline`, flushed over TCP."""

    def __init__(self, client: KVClient, transactional: bool):
        super().__init__(client)
        self._transactional = transactional

    def _flush(self) -> List[Tuple[bool, Any]]:
        return self._store._flush_pipeline(self._cmds, self._transactional)
