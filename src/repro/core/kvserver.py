"""TCP front-end for :class:`repro.core.kvstore.KVStore` (the "real Redis" mode).

The paper's workers are AWS Lambda containers that reach Redis over TCP in
the same VPC subnet. This module provides the equivalent remote mode: a
length-prefixed framed protocol (command name + pickled args) served by a
thread-per-connection server over a shared ``KVStore`` — whose global lock
preserves Redis's single-threaded atomicity — plus a client exposing the
same method surface, so every IPC primitive runs unchanged against a
genuinely remote store (see tests/test_kvserver.py).

Frame format: 4-byte big-endian length, then pickle((cmd, args, kwargs)).
Response: 4-byte length, then pickle((ok: bool, value_or_exception)).
"""

from __future__ import annotations

import socket
import socketserver
import struct
import threading
from typing import Any, Optional, Tuple

from . import serialization
from .kvstore import KVStore

__all__ = ["KVServer", "KVClient"]

_HDR = struct.Struct("!I")


def _send_frame(sock: socket.socket, payload: bytes) -> None:
    sock.sendall(_HDR.pack(len(payload)) + payload)


def _recv_exactly(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def _recv_frame(sock: socket.socket) -> Optional[bytes]:
    hdr = _recv_exactly(sock, _HDR.size)
    if hdr is None:
        return None
    (length,) = _HDR.unpack(hdr)
    return _recv_exactly(sock, length)


class _Handler(socketserver.BaseRequestHandler):
    def handle(self) -> None:
        store: KVStore = self.server.store  # type: ignore[attr-defined]
        while True:
            frame = _recv_frame(self.request)
            if frame is None:
                return
            try:
                cmd, args, kwargs = serialization.loads(frame)
                if cmd.startswith("_") or not hasattr(store, cmd):
                    raise AttributeError(f"unknown command {cmd!r}")
                value = getattr(store, cmd)(*args, **kwargs)
                resp = (True, value)
            except Exception as exc:  # propagate to client
                resp = (False, exc)
            try:
                _send_frame(self.request, serialization.dumps(resp))
            except OSError:
                return


class _Server(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True


class KVServer:
    """Serve a KVStore over TCP. Use as a context manager or start()/stop()."""

    def __init__(self, store: Optional[KVStore] = None,
                 host: str = "127.0.0.1", port: int = 0):
        self.store = store or KVStore(name="kvserver")
        self._server = _Server((host, port), _Handler)
        self._server.store = self.store  # type: ignore[attr-defined]
        self._thread: Optional[threading.Thread] = None

    @property
    def address(self) -> Tuple[str, int]:
        return self._server.server_address  # type: ignore[return-value]

    def start(self) -> "KVServer":
        self._thread = threading.Thread(
            target=self._server.serve_forever, kwargs={"poll_interval": 0.05},
            daemon=True, name="kvserver")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)

    def __enter__(self) -> "KVServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


class KVClient:
    """Remote KVStore with the same method interface.

    One socket **per thread** (thread-local connections): blocking
    commands (``blpop``) occupy their connection server-side, exactly like
    one Redis connection per Lambda container — a shared socket would
    deadlock a thread's LPUSH behind another thread's pending BLPOP.
    """

    def __init__(self, address: Tuple[str, int]):
        self.address = address
        self._tls = threading.local()
        self._all_socks = []
        self._all_lock = threading.Lock()
        self.name = f"kvclient@{address[0]}:{address[1]}"

    def _sock(self) -> socket.socket:
        sock = getattr(self._tls, "sock", None)
        if sock is None:
            sock = socket.create_connection(self.address)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._tls.sock = sock
            with self._all_lock:
                self._all_socks.append(sock)
        return sock

    def _call(self, cmd: str, *args: Any, **kwargs: Any) -> Any:
        sock = self._sock()
        _send_frame(sock, serialization.dumps((cmd, args, kwargs)))
        frame = _recv_frame(sock)
        if frame is None:
            raise ConnectionError("kvserver closed the connection")
        ok, value = serialization.loads(frame)
        if not ok:
            raise value
        return value

    def __getattr__(self, cmd: str):
        if cmd.startswith("_"):
            raise AttributeError(cmd)
        def call(*args: Any, **kwargs: Any) -> Any:
            return self._call(cmd, *args, **kwargs)
        call.__name__ = cmd
        return call

    def close(self) -> None:
        with self._all_lock:
            socks, self._all_socks = self._all_socks, []
        for sock in socks:
            try:
                sock.close()
            except OSError:
                pass
